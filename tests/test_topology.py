"""Graph-topology subsystem: mixing-matrix invariants, Mixer
equivalences, and the spectral-prediction-vs-measured-Gamma contract.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import topology as topolib
from repro.configs.base import TOPOLOGIES, HDOConfig
from repro.core import build_hdo_step, consensus_distance, gossip, init_state
from repro.core.hdo import HDOState

# (the hypothesis property-test versions of the invariants below live
# in tests/test_properties.py, which skips gracefully when hypothesis
# is absent; this file stays deterministic and always runs)


def _static_topologies(n: int):
    out = [topolib.ring(n), topolib.erdos_renyi(n, 0.5, seed=1)]
    if n >= 4 and not (n & (n - 1)):
        out.append(topolib.hypercube(n))
    try:
        out.append(topolib.torus(n))
    except ValueError:
        pass
    return out


# ---------------------------------------------------------------------------
# mixing-matrix invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4, 6, 8, 9, 12, 16])
def test_mixing_matrices_symmetric_doubly_stochastic(n):
    """Metropolis–Hastings weights give a symmetric doubly-stochastic,
    nonnegative W for every topology family and size."""
    for topo in _static_topologies(n):
        W = topo.mixing_matrix()
        np.testing.assert_allclose(W, W.T, atol=1e-12, err_msg=topo.name)
        np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-6, err_msg=topo.name)
        np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-6, err_msg=topo.name)
        assert (W >= 0).all(), topo.name


@pytest.mark.parametrize("n", [4, 6, 8, 10, 16])
def test_tv_topologies_symmetric_doubly_stochastic(n):
    for tv in (topolib.tv_round_robin(n), topolib.tv_erdos_renyi(n, 0.5, seed=0, rounds=3)):
        for topo in tv.rounds:
            W = topo.mixing_matrix()
            np.testing.assert_allclose(W, W.T, atol=1e-12)
            np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-6)


def test_lattice_columns_are_permutations():
    """Ring/torus/hypercube neighbor tables are slot-structured so each
    column is a permutation — the graph_ppermute precondition."""
    for topo in (topolib.ring(8), topolib.torus(12), topolib.hypercube(16),
                 topolib.ring(2), topolib.torus(8)):
        assert topo.columns_are_permutations(), topo.name


def test_erdos_renyi_connected_and_deterministic():
    a = topolib.erdos_renyi(12, 0.3, seed=5)
    b = topolib.erdos_renyi(12, 0.3, seed=5)
    np.testing.assert_array_equal(a.neighbors, b.neighbors)
    # connectivity: lambda_2 strictly below 1
    assert topolib.slem(a) < 1.0 - 1e-9


def test_constructor_validation():
    with pytest.raises(ValueError):
        topolib.hypercube(6)
    with pytest.raises(ValueError):
        topolib.torus(7)  # prime: no rows*cols >= 2x2
    with pytest.raises(ValueError):
        topolib.ring(1)
    with pytest.raises(ValueError):
        topolib.tv_round_robin(5)  # tournament needs an even population
    with pytest.raises(ValueError):
        topolib.make_topology("petersen", 10)


# ---------------------------------------------------------------------------
# spectral diagnostics
# ---------------------------------------------------------------------------


def test_slem_closed_forms():
    """Ring: eigs (1 + 2 cos(2 pi k / n)) / 3; hypercube (k-regular):
    (1 + k - 2m) / (k + 1)."""
    n = 12
    # f32 weight storage: closed forms match to f32 eps, not f64
    assert topolib.slem(topolib.ring(n)) == pytest.approx(
        (1 + 2 * np.cos(2 * np.pi / n)) / 3, abs=1e-6
    )
    assert topolib.slem(topolib.hypercube(8)) == pytest.approx(0.5, abs=1e-6)
    t = topolib.ring(n)
    assert topolib.predicted_contraction(t) == pytest.approx(
        topolib.slem(t) ** 2, abs=1e-12
    )
    assert topolib.spectral_gap(t) == pytest.approx(1 - topolib.slem(t), abs=1e-12)


def test_tv_round_robin_contracts_as_a_cycle():
    """A single matching has slem 1, but the tournament cycle contracts
    (per-round geometric mean < 1)."""
    tv = topolib.tv_round_robin(8)
    single = topolib.slem(tv.rounds[0])
    assert single == pytest.approx(1.0, abs=1e-9)
    assert topolib.slem(tv) < 0.9


# ---------------------------------------------------------------------------
# Mixer invariants (old modes and new topologies)
# ---------------------------------------------------------------------------


def _make_params(key, n):
    return {
        "w": jax.random.normal(key, (n, 7, 3)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (n, 5)),
    }


def _all_mixers(n):
    cfgs = [HDOConfig(n_agents=n, n_zeroth=0, gossip=g)
            for g in ("dense", "all_reduce", "none")]
    if n % 2 == 0:
        cfgs.append(HDOConfig(n_agents=n, n_zeroth=0, gossip="rr_static"))
    for topo in TOPOLOGIES:
        if topo == "hypercube" and (n & (n - 1) or n < 2):
            continue
        if topo == "torus":
            try:
                topolib.torus(n)
            except ValueError:
                continue
        if topo == "tv_round_robin" and n % 2:
            continue
        cfgs.append(HDOConfig(n_agents=n, n_zeroth=0, gossip="graph",
                              topology=topo, topology_p=0.5, topology_rounds=3))
    return [(c.gossip if c.gossip != "graph" else f"graph/{c.topology}",
             topolib.make_mixer(c)) for c in cfgs]


@pytest.mark.parametrize("n,seed,step", [(4, 0, 0), (6, 1, 3), (8, 2, 7),
                                         (12, 3, 11), (16, 4, 20)])
def test_every_mixer_preserves_population_mean(n, seed, step):
    """The load-balancing invariant (Lemma 2) extends to every Mixer:
    doubly-stochastic mixing cannot move the population mean."""
    X = _make_params(jax.random.PRNGKey(seed), n)
    for name, mixer in _all_mixers(n):
        Y = mixer(X, key=jax.random.PRNGKey(seed + 1), step=jnp.int32(step))
        for k in X:
            np.testing.assert_allclose(
                np.asarray(Y[k].mean(0)), np.asarray(X[k].mean(0)),
                atol=1e-5, err_msg=f"{name}/{k}",
            )


@pytest.mark.parametrize("n,seed", [(4, 0), (8, 1), (12, 2)])
def test_graph_mixer_is_matrix_application(n, seed):
    """GraphMixer == W @ X (f64 reference), for every static family."""
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, 6))
    for topo in _static_topologies(n):
        mixer = topolib.GraphMixer(topo)
        got = mixer({"x": X}, key=None, step=None)["x"]
        exp = topo.mixing_matrix() @ np.asarray(X, np.float64)
        np.testing.assert_allclose(np.asarray(got), exp, atol=1e-5,
                                   err_msg=topo.name)


def test_graph_mixer_kernel_path_matches_jnp():
    """use_kernel=True routes leaves through the fused gossip_mix
    Pallas kernel — same mixing, one O(d) pass."""
    topo = topolib.torus(12)
    X = _make_params(jax.random.PRNGKey(3), 12)
    a = topolib.GraphMixer(topo, use_kernel=False)(X, key=None, step=None)
    b = topolib.GraphMixer(topo, use_kernel=True)(X, key=None, step=None)
    for k in X:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]), atol=1e-6)


def test_tv_round_robin_matches_rr_static():
    """The tournament-as-time-varying-graph reproduces rr_static's
    pairwise averaging (MH weights on a matching are exactly 1/2)."""
    n = 8
    mr = topolib.RoundRobinMixer(n)
    mt = topolib.TimeVaryingGraphMixer(topolib.tv_round_robin(n))
    X = _make_params(jax.random.PRNGKey(9), n)
    for s in range(n - 1):
        a = mr(X, key=None, step=jnp.int32(s))
        b = mt(X, key=None, step=jnp.int32(s))
        for k in X:
            np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                       atol=1e-6, err_msg=f"round {s}")


def test_make_mixer_validation():
    with pytest.raises(ValueError):
        topolib.make_mixer(HDOConfig(n_agents=5, n_zeroth=0, gossip="rr_static"))
    with pytest.raises(ValueError):  # ppermute lowerings need a mesh
        topolib.make_mixer(HDOConfig(n_agents=4, n_zeroth=0, gossip="rr_ppermute"))
    with pytest.raises(ValueError):
        topolib.make_mixer(HDOConfig(n_agents=4, n_zeroth=0, gossip="graph_ppermute"))
    # n == 1 degrades to no-op for every mode
    m = topolib.make_mixer(HDOConfig(n_agents=1, n_zeroth=0, gossip="dense"))
    assert isinstance(m, topolib.IdentityMixer)


# ---------------------------------------------------------------------------
# the refactored step: bit-identity and end-to-end behaviour
# ---------------------------------------------------------------------------

D = 16
W_TRUE = jax.random.normal(jax.random.PRNGKey(42), (D,))


def _loss_fn(params, batch):
    return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)


def _batches(key, n, bsz=8):
    X = jax.random.normal(key, (n, bsz, D))
    return {"X": X, "y": X @ W_TRUE}


def test_dense_step_bit_identical_to_pre_refactor():
    """The Mixer refactor must not change the paper-faithful dense path
    by a single bit: a gossip="none" step followed by the pre-refactor
    ``gossip.gossip_step`` primitive on the step's gossip key must equal
    the gossip="dense" step exactly."""
    base = dict(n_agents=8, n_zeroth=4, lr=0.05, momentum=0.9, warmup_steps=0,
                use_cosine=False, rv=2, nu=1e-3)
    cfg_d = HDOConfig(gossip="dense", **base)
    cfg_n = HDOConfig(gossip="none", **base)
    state0 = init_state({"w": jnp.zeros((D,))}, cfg_d)
    batches = _batches(jax.random.PRNGKey(3), 8)
    s_d, _ = jax.jit(build_hdo_step(_loss_fn, cfg_d, param_dim=D))(state0, batches)
    s_n, _ = jax.jit(build_hdo_step(_loss_fn, cfg_n, param_dim=D))(state0, batches)
    gkey = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg_d.seed), jnp.int32(0)), 7
    )
    expected = gossip.gossip_step(s_n.params, mode="dense", key=gkey,
                                  step=jnp.int32(0), n=8)
    np.testing.assert_array_equal(np.asarray(expected["w"]),
                                  np.asarray(s_d.params["w"]))


def test_graph_gossip_population_converges():
    cfg = HDOConfig(n_agents=8, n_zeroth=4, gossip="graph", topology="hypercube",
                    lr=0.05, momentum=0.0, warmup_steps=0, use_cosine=False,
                    rv=4, nu=1e-3)
    step = jax.jit(build_hdo_step(_loss_fn, cfg, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    for t in range(150):
        state, m = step(state, _batches(jax.random.fold_in(jax.random.PRNGKey(9), t), 8))
    Xe = jax.random.normal(jax.random.PRNGKey(5), (256, D))
    mu = state.params["w"].mean(0)
    assert float(jnp.mean((Xe @ mu - Xe @ W_TRUE) ** 2)) < 1e-2
    assert float(consensus_distance(state.params)) < 1e-2


def test_spectral_metrics_surface_in_step():
    cfg = HDOConfig(n_agents=8, n_zeroth=4, gossip="graph", topology="ring",
                    lr=0.05, momentum=0.0, warmup_steps=0, use_cosine=False,
                    rv=1, nu=1e-3)
    step = jax.jit(build_hdo_step(_loss_fn, cfg, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    _, m = step(state, _batches(jax.random.PRNGKey(0), 8))
    topo = topolib.ring(8)
    assert float(m["gossip_lambda2"]) == pytest.approx(topolib.slem(topo), abs=1e-6)
    assert float(m["gossip_spectral_gap"]) == pytest.approx(
        topolib.spectral_gap(topo), abs=1e-6)
    assert float(m["gossip_gamma_contraction"]) == pytest.approx(
        topolib.predicted_contraction(topo), abs=1e-6)


# ---------------------------------------------------------------------------
# acceptance: measured Gamma contraction == spectral prediction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo_name,n,kw", [
    ("ring", 12, {}),
    ("torus", 12, {}),
    ("erdos_renyi", 12, dict(topology_p=0.45, topology_seed=3)),
    ("hypercube", 16, {}),
])
def test_measured_gamma_contraction_matches_spectral_prediction(topo_name, n, kw):
    """On a quadratic task with lr=0 (pure interaction), the measured
    per-round Gamma_t ratio through the full jitted HDO step converges
    to the topology module's predicted slem^2 — the consensus half of
    the paper's convergence bound, validated per topology."""
    cfg = HDOConfig(n_agents=n, n_zeroth=n // 2, gossip="graph", topology=topo_name,
                    lr=0.0, momentum=0.0, warmup_steps=0, use_cosine=False,
                    rv=1, nu=1e-3, **kw)
    step = jax.jit(build_hdo_step(_loss_fn, cfg, param_dim=D))
    st = init_state({"w": jnp.zeros((D,))}, cfg)
    # diverse start so Gamma_0 > 0 (init_state replicates one point)
    st = HDOState(params={"w": jax.random.normal(jax.random.PRNGKey(7), (n, D))},
                  opt_state=st.opt_state, step=st.step)
    gammas = []
    for t in range(17):
        st, _ = step(st, _batches(jax.random.fold_in(jax.random.PRNGKey(1), t), n, 4))
        gammas.append(float(consensus_distance(st.params)))
    g = np.array(gammas)
    assert g[-1] > 1e-18, "Gamma hit the float noise floor; shorten the run"
    # rounds 9..17: transient modes (lambda_3 and below) have decayed,
    # asymptotic ratio is slem^2
    measured = np.exp(np.mean(np.log(g[9:] / g[8:-1])))
    topo = topolib.make_topology(topo_name, n, p=kw.get("topology_p", 0.3),
                                 seed=kw.get("topology_seed", 0))
    predicted = topolib.predicted_contraction(topo)
    assert measured == pytest.approx(predicted, rel=0.05), (topo_name, measured, predicted)


# ---------------------------------------------------------------------------
# shard_map/ppermute lowering parity (multi-device subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_graph_ppermute_parity_subprocess():
    """graph_ppermute == graph on a multi-device population, for both
    the jnp combine and the fused gossip_mix kernel combine."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import repro.topology as T
        from repro.configs.base import HDOConfig
        from repro.core import build_hdo_step, init_state
        mesh = jax.make_mesh((8,), ("data",))
        n, d = 8, 12
        w_true = jax.random.normal(jax.random.PRNGKey(42), (d,))
        def loss_fn(params, batch):
            return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)
        topo = T.hypercube(n)
        X = {"w": jax.random.normal(jax.random.PRNGKey(1), (n, 5))}
        exp = T.GraphMixer(topo)(X, key=None, step=None)
        for use_kernel in (False, True):
            pm = T.GraphPpermuteMixer(topo, mesh, ("data",), use_kernel=use_kernel)
            got = jax.jit(lambda p: pm(p, key=None, step=None))(X)
            np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(exp["w"]),
                                       atol=1e-6, err_msg=str(use_kernel))
        outs = {}
        for mode in ("graph", "graph_ppermute"):
            cfg = HDOConfig(n_agents=n, n_zeroth=4, gossip=mode, topology="hypercube",
                            lr=0.05, momentum=0.0, warmup_steps=0, use_cosine=False,
                            rv=2, nu=1e-3)
            step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=d, mesh=mesh,
                                          population_axes=("data",)))
            state = init_state({"w": jnp.zeros((d,))}, cfg)
            for t in range(20):
                k = jax.random.fold_in(jax.random.PRNGKey(9), t)
                Xb = jax.random.normal(k, (n, 8, d))
                state, m = step(state, {"X": Xb, "y": Xb @ w_true})
            outs[mode] = np.asarray(state.params["w"])
        np.testing.assert_allclose(outs["graph"], outs["graph_ppermute"], atol=1e-5)
        print("GRAPH_PPERMUTE_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=420, env=env, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "GRAPH_PPERMUTE_OK" in proc.stdout
