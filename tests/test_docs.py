"""Docs-layer gates that run in tier-1 (cheap, no execution of the
snippet itself — the CI docs lane executes it):

  * the README knob table matches the canonical constants in
    configs.base (regenerate with
    ``PYTHONPATH=src python -m repro.configs.knobs --write README.md``)
  * every relative markdown link resolves
  * the README quickstart snippet parses as a program
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_readme_knob_table_is_current():
    from repro.configs import knobs

    with open(os.path.join(REPO, "README.md")) as f:
        text = f.read()
    assert knobs.inject(text) == text, (
        "README knob table drifted from configs.base — run "
        "`PYTHONPATH=src python -m repro.configs.knobs --write README.md`"
    )


def test_markdown_links_resolve():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "docs", "check_links.py"), REPO],
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stderr + proc.stdout


def test_readme_quickstart_snippet_compiles():
    sys.path.insert(0, os.path.join(REPO, "docs"))
    try:
        from run_readme_snippet import extract
    finally:
        sys.path.pop(0)
    code = extract(os.path.join(REPO, "README.md"))
    compile(code, "README.md:quickstart-snippet", "exec")
    # the snippet must exercise the public API it documents
    assert "HDOConfig" in code and "build_hdo_step" in code


def test_required_docs_exist():
    for rel in ("README.md", os.path.join("docs", "paper_map.md"),
                os.path.join("docs", "observability.md"),
                os.path.join("docs", "serving.md"),
                os.path.join("benchmarks", "README.md")):
        assert os.path.exists(os.path.join(REPO, rel)), rel
