"""Fused flat-parameter ZO engine: statistics, structure, vmap safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flatzo


def quad_loss(A, b):
    def loss(p):
        x = p["x"]
        return 0.5 * x @ A @ x - b @ x

    return loss


@pytest.fixture(scope="module")
def quad():
    key = jax.random.PRNGKey(0)
    d = 12
    A = jax.random.normal(key, (d, d))
    A = A @ A.T / d + jnp.eye(d)
    b = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    p = {"x": jax.random.normal(jax.random.fold_in(key, 2), (d,))}
    return A, b, p, d


@pytest.mark.parametrize("kind", ["biased_1pt", "biased_2pt", "multi_rv", "fwd_grad"])
def test_fused_mean_close_to_grad(quad, kind):
    """E[G] ~ grad f — same statistics as the tree estimators."""
    A, b, p, d = quad
    loss = quad_loss(A, b)
    g_true = A @ p["x"] - b
    est = jax.jit(
        lambda k: flatzo.flat_zo_estimate(loss, p, k, kind=kind, rv=8, nu=1e-4)[1]["x"]
    )
    n = 300
    gs = jnp.stack([est(jax.random.PRNGKey(100 + i)) for i in range(n)])
    rel = float(jnp.linalg.norm(gs.mean(0) - g_true) / jnp.linalg.norm(g_true))
    assert rel < 0.3, (kind, rel)


def test_fused_primal_is_loss0(quad):
    A, b, p, d = quad
    loss = quad_loss(A, b)
    val, _ = flatzo.flat_zo_estimate(loss, p, jax.random.PRNGKey(0), kind="multi_rv", nu=1e-4)
    np.testing.assert_allclose(np.asarray(val), np.asarray(loss(p)), rtol=1e-6)


def test_fused_preserves_structure_and_dtypes():
    tree = {"a": jnp.zeros((3, 4)), "b": {"c": jnp.zeros((5,), jnp.bfloat16)}}
    loss = lambda p: sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(p))
    _, g = flatzo.flat_zo_estimate(loss, tree, jax.random.PRNGKey(1), rv=2, nu=1e-3)
    assert g["a"].shape == (3, 4) and g["a"].dtype == jnp.float32
    assert g["b"]["c"].shape == (5,) and g["b"]["c"].dtype == jnp.bfloat16


def test_fused_vmap_over_agents(quad):
    A, b, p, d = quad
    loss = quad_loss(A, b)
    n = 4
    ps = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), p)
    keys = jax.random.split(jax.random.PRNGKey(2), n)
    losses, g = jax.vmap(
        lambda pi, ki: flatzo.flat_zo_estimate(loss, pi, ki, rv=2, nu=1e-3)
    )(ps, keys)
    assert losses.shape == (n,) and g["x"].shape == (n, d)
    # distinct keys -> distinct estimates
    assert float(jnp.abs(g["x"][0] - g["x"][1]).max()) > 1e-3


def test_fused_fwd_grad_primal_is_loss0(quad):
    """flat_fwd_grad's primal comes from the jvp — still F(x) exactly."""
    A, b, p, d = quad
    loss = quad_loss(A, b)
    val, _ = flatzo.flat_fwd_grad(loss, p, jax.random.PRNGKey(0), rv=3)
    np.testing.assert_allclose(np.asarray(val), np.asarray(loss(p)), rtol=1e-6)


def test_fused_fwd_grad_single_draw_identity():
    """For one draw, flat_fwd_grad gives exactly (u . g) u with u the
    zo_tangent draw — the Baydin forward-gradient identity on the
    counter stream."""
    from repro.kernels import ops

    d = 8
    g = jnp.arange(1.0, d + 1.0)
    loss = lambda p: p["x"] @ g
    p = {"x": jnp.zeros((d,))}
    key = jax.random.PRNGKey(3)
    _, est = flatzo.flat_fwd_grad(loss, p, key, rv=1)
    u = ops.zo_tangent(flatzo.seed_from_key(key), 0, d)
    np.testing.assert_allclose(
        np.asarray(est["x"]), np.asarray((u @ g) * u), rtol=1e-5
    )


def test_fused_fwd_grad_preserves_structure_and_dtypes():
    tree = {"a": jnp.zeros((3, 4)), "b": {"c": jnp.zeros((5,), jnp.bfloat16)}}
    loss = lambda p: sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(p))
    _, g = flatzo.flat_fwd_grad(loss, tree, jax.random.PRNGKey(1), rv=2)
    assert g["a"].shape == (3, 4) and g["a"].dtype == jnp.float32
    assert g["b"]["c"].shape == (5,) and g["b"]["c"].dtype == jnp.bfloat16


def test_fused_fwd_grad_vmap_over_agents(quad):
    A, b, p, d = quad
    loss = quad_loss(A, b)
    n = 4
    ps = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), p)
    keys = jax.random.split(jax.random.PRNGKey(2), n)
    losses, g = jax.vmap(
        lambda pi, ki: flatzo.flat_fwd_grad(loss, pi, ki, rv=2)
    )(ps, keys)
    assert losses.shape == (n,) and g["x"].shape == (n, d)
    assert float(jnp.abs(g["x"][0] - g["x"][1]).max()) > 1e-3


def test_fused_rejects_unknown_kind(quad):
    A, b, p, d = quad
    with pytest.raises(ValueError):
        flatzo.flat_zo_estimate(quad_loss(A, b), p, jax.random.PRNGKey(0), kind="nope")


def test_seed_from_key_nonnegative_int32():
    seeds = jax.vmap(flatzo.seed_from_key)(jax.random.split(jax.random.PRNGKey(0), 64))
    assert seeds.dtype == jnp.int32
    assert bool((seeds >= 0).all())
    assert len(set(np.asarray(seeds).tolist())) == 64
