"""Fused flat-parameter ZO engine: statistics, structure, vmap safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flatzo


def quad_loss(A, b):
    def loss(p):
        x = p["x"]
        return 0.5 * x @ A @ x - b @ x

    return loss


@pytest.fixture(scope="module")
def quad():
    key = jax.random.PRNGKey(0)
    d = 12
    A = jax.random.normal(key, (d, d))
    A = A @ A.T / d + jnp.eye(d)
    b = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    p = {"x": jax.random.normal(jax.random.fold_in(key, 2), (d,))}
    return A, b, p, d


@pytest.mark.parametrize("kind", ["biased_1pt", "biased_2pt", "multi_rv"])
def test_fused_mean_close_to_grad(quad, kind):
    """E[G] ~ grad f — same statistics as the tree estimators."""
    A, b, p, d = quad
    loss = quad_loss(A, b)
    g_true = A @ p["x"] - b
    est = jax.jit(
        lambda k: flatzo.flat_zo_estimate(loss, p, k, kind=kind, rv=8, nu=1e-4)[1]["x"]
    )
    n = 300
    gs = jnp.stack([est(jax.random.PRNGKey(100 + i)) for i in range(n)])
    rel = float(jnp.linalg.norm(gs.mean(0) - g_true) / jnp.linalg.norm(g_true))
    assert rel < 0.3, (kind, rel)


def test_fused_primal_is_loss0(quad):
    A, b, p, d = quad
    loss = quad_loss(A, b)
    val, _ = flatzo.flat_zo_estimate(loss, p, jax.random.PRNGKey(0), kind="multi_rv", nu=1e-4)
    np.testing.assert_allclose(np.asarray(val), np.asarray(loss(p)), rtol=1e-6)


def test_fused_preserves_structure_and_dtypes():
    tree = {"a": jnp.zeros((3, 4)), "b": {"c": jnp.zeros((5,), jnp.bfloat16)}}
    loss = lambda p: sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(p))
    _, g = flatzo.flat_zo_estimate(loss, tree, jax.random.PRNGKey(1), rv=2, nu=1e-3)
    assert g["a"].shape == (3, 4) and g["a"].dtype == jnp.float32
    assert g["b"]["c"].shape == (5,) and g["b"]["c"].dtype == jnp.bfloat16


def test_fused_vmap_over_agents(quad):
    A, b, p, d = quad
    loss = quad_loss(A, b)
    n = 4
    ps = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), p)
    keys = jax.random.split(jax.random.PRNGKey(2), n)
    losses, g = jax.vmap(
        lambda pi, ki: flatzo.flat_zo_estimate(loss, pi, ki, rv=2, nu=1e-3)
    )(ps, keys)
    assert losses.shape == (n,) and g["x"].shape == (n, d)
    # distinct keys -> distinct estimates
    assert float(jnp.abs(g["x"][0] - g["x"][1]).max()) > 1e-3


def test_fused_rejects_fwd_grad(quad):
    A, b, p, d = quad
    with pytest.raises(ValueError):
        flatzo.flat_zo_estimate(quad_loss(A, b), p, jax.random.PRNGKey(0), kind="fwd_grad")


def test_seed_from_key_nonnegative_int32():
    seeds = jax.vmap(flatzo.seed_from_key)(jax.random.split(jax.random.PRNGKey(0), 64))
    assert seeds.dtype == jnp.int32
    assert bool((seeds >= 0).all())
    assert len(set(np.asarray(seeds).tolist())) == 64
