"""MoE routing unit tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import moe as moe_lib

CFG = dataclasses.replace(get_smoke_config("qwen2-moe-a2.7b"), dtype="float32")
KEY = jax.random.PRNGKey(0)


def test_moe_matches_dense_reference_when_dropfree():
    """Sort-based dispatch == naive dense top-k mixture (no drops)."""
    p = moe_lib.init_moe(KEY, CFG, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 8, CFG.d_model))
    y, aux = moe_lib.moe_apply(p, x, CFG)

    # naive: run every expert on every token, mix by top-k normalized gates
    T = 2 * 8
    xt = x.reshape(T, -1)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, CFG.num_experts_per_tok)
    gates = gates / gates.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", xt, p["wi"])
    hg = jnp.einsum("td,edf->tef", xt, p["wg"])
    all_out = jnp.einsum("tef,efd->ted", jax.nn.silu(hg) * h, p["wo"])
    y_ref = jnp.zeros_like(xt)
    for k in range(CFG.num_experts_per_tok):
        y_ref = y_ref + gates[:, k:k+1] * jnp.take_along_axis(
            all_out, idx[:, k][:, None, None], axis=1)[:, 0]
    sp = p["shared"]
    y_ref = y_ref + (jax.nn.silu(xt @ sp["wg"]) * (xt @ sp["wi"])) @ sp["wo"]
    np.testing.assert_allclose(np.asarray(y.reshape(T, -1)), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


def test_aux_loss_bounds():
    """Switch aux loss >= 1 (=1 at perfect balance), finite."""
    p = moe_lib.init_moe(KEY, CFG, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (4, 16, CFG.d_model))
    _, aux = moe_lib.moe_apply(p, x, CFG)
    assert np.isfinite(float(aux))
    assert float(aux) >= 0.9  # E * sum f_e p_e >= ~1 by Cauchy-Schwarz


def test_moe_grads_flow_to_router():
    p = moe_lib.init_moe(KEY, CFG, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 8, CFG.d_model))

    def loss(p):
        y, aux = moe_lib.moe_apply(p, x, CFG)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["wi"]).sum()) > 0


def test_capacity_drops_at_scale_are_bounded():
    """With capacity_factor 1.25 and near-uniform routing, most tokens
    survive (output norm close to drop-free output norm)."""
    p = moe_lib.init_moe(KEY, CFG, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (8, 256, CFG.d_model))
    y_capped, _ = moe_lib.moe_apply(p, x, CFG, capacity_factor=1.25)
    # capacity_factor == num_experts -> cap == T*k (provably drop-free)
    y_free, _ = moe_lib.moe_apply(p, x, CFG, capacity_factor=float(CFG.num_experts))
    ratio = float(jnp.linalg.norm(y_capped) / jnp.linalg.norm(y_free))
    assert ratio > 0.9
