"""Heterogeneous-population subsystem: per-agent config validation,
population resolution, ragged-rv masking, grouped dispatch, and the
all-equal == homogeneous bit-identity collapse contract.

Deterministic counterparts of the hypothesis property in
test_properties.py, so the pinned container (no hypothesis) still
exercises every contract.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HDOConfig
from repro.core import (
    build_hdo_step,
    estimators,
    flatzo,
    init_state,
    resolve_population,
)
from repro.core.population import parse_csv, tile

D = 12
W_TRUE = jax.random.normal(jax.random.PRNGKey(42), (D,))


def loss_fn(params, batch):
    return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)


def make_batches(key, n_agents, bsz=6):
    X = jax.random.normal(key, (n_agents, bsz, D))
    return {"X": X, "y": X @ W_TRUE}


BASE = dict(lr=0.05, momentum=0.9, warmup_steps=0, use_cosine=False,
            nu=1e-3, rv=4, gossip="dense")


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_per_agent_validation():
    ok = dict(n_agents=4, n_zeroth=2)
    # lengths: sigmas/rvs/estimators_zo match the ZO cohort, lrs all agents
    with pytest.raises(ValueError, match="sigmas"):
        HDOConfig(**ok, sigmas=(1e-3,))
    with pytest.raises(ValueError, match="rvs"):
        HDOConfig(**ok, rvs=(2, 2, 2))
    with pytest.raises(ValueError, match="estimators_zo"):
        HDOConfig(**ok, estimators_zo=("multi_rv",))
    with pytest.raises(ValueError, match="lrs"):
        HDOConfig(**ok, lrs=(0.1, 0.1))  # needs n_agents entries
    # positivity
    with pytest.raises(ValueError, match="sigmas"):
        HDOConfig(**ok, sigmas=(1e-3, -1.0))
    with pytest.raises(ValueError, match="rvs"):
        HDOConfig(**ok, rvs=(0, 2))
    with pytest.raises(ValueError, match="lrs"):
        HDOConfig(**ok, lrs=(0.1, 0.1, 0.1, 0.0))
    # kind membership comes from the canonical ZO_ESTIMATORS tuple
    with pytest.raises(ValueError, match="estimators_zo"):
        HDOConfig(**ok, estimators_zo=("multi_rv", "multirv"))
    # nu_from_lr derives the radius from lr — per-agent sigmas conflict
    with pytest.raises(ValueError, match="nu_from_lr"):
        HDOConfig(**ok, nu_from_lr=True, sigmas=(1e-3, 1e-3))
    # valid heterogeneous config constructs (lists normalized to tuples)
    cfg = HDOConfig(**ok, sigmas=[1e-3, 1e-2], rvs=[1, 4],
                    estimators_zo=["multi_rv", "fwd_grad"],
                    lrs=[0.1, 0.1, 0.2, 0.2])
    assert isinstance(cfg.sigmas, tuple) and hash(cfg) is not None


def test_resolve_population_defaults_and_groups():
    pop = resolve_population(HDOConfig(n_agents=4, n_zeroth=2, **BASE))
    assert pop.homogeneous
    assert pop.kinds == ("multi_rv",) * 2 and pop.sigmas == (1e-3,) * 2
    assert pop.rvs == (4, 4) and pop.lrs == (0.05,) * 4
    assert [g.kind for g in pop.groups] == ["multi_rv"]

    het = resolve_population(HDOConfig(
        n_agents=5, n_zeroth=4,
        estimators_zo=("multi_rv", "fwd_grad", "multi_rv", "biased_2pt"),
        rvs=(2, 8, 4, 1), **BASE))
    assert not het.homogeneous
    # groups in first-seen order, indices global, rv padded to group max
    assert [(g.kind, g.indices, g.rv_max) for g in het.groups] == [
        ("multi_rv", (0, 2), 4), ("fwd_grad", (1,), 8), ("biased_2pt", (3,), 1)]

    # uniform per-agent values that differ from the scalar knobs still
    # collapse, onto the overridden effective scalars
    uni = resolve_population(dataclasses.replace(
        HDOConfig(n_agents=3, n_zeroth=2, **BASE), sigmas=(1e-2, 1e-2)))
    assert uni.homogeneous and uni.sigma0 == 1e-2


def test_csv_helpers():
    assert parse_csv(None, float) is None
    assert parse_csv("1e-3, 0.1", float) == (1e-3, 0.1)
    assert parse_csv("multi_rv,fwd_grad", str) == ("multi_rv", "fwd_grad")
    assert tile((1, 2), 5) == (1, 2, 1, 2, 1)  # cycled
    assert tile((7,), 3) == (7, 7, 7)  # broadcast
    assert tile(None, 3) is None
    with pytest.raises(ValueError):
        parse_csv(" ,", float)


# ---------------------------------------------------------------------------
# ragged-rv masking: padded draws are inert, average is over rv_actual
# ---------------------------------------------------------------------------


def test_masked_rv_equals_smaller_rv():
    loss = lambda p: jnp.sum(p["w"] ** 2)
    p = {"w": jax.random.normal(jax.random.PRNGKey(0), (300,))}
    key = jax.random.PRNGKey(5)
    for kind in ("multi_rv", "fwd_grad"):
        # fused path: bit-exact (zero coefficients are exact no-ops in
        # the combine kernel; denominator comes in as an operand)
        _, gm = flatzo.flat_zo_estimate(loss, p, key, kind=kind, rv=4,
                                        nu=1e-3, rv_actual=jnp.int32(2))
        _, gs = flatzo.flat_zo_estimate(loss, p, key, kind=kind, rv=2, nu=1e-3)
        np.testing.assert_array_equal(np.asarray(gm["w"]), np.asarray(gs["w"]))
        # tree path: same estimator, but the masked graph fuses
        # differently under XLA:CPU (FMA contraction) -> allclose
        _, gm = estimators.zo_estimate(loss, p, key, kind=kind, rv=4,
                                       nu=1e-3, rv_actual=jnp.int32(2))
        _, gs = estimators.zo_estimate(loss, p, key, kind=kind, rv=2, nu=1e-3)
        np.testing.assert_allclose(np.asarray(gm["w"]), np.asarray(gs["w"]),
                                   rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# the collapse contract: all-equal per-agent values == homogeneous, bit
# for bit (params, opt_state, and the metrics dict)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("zo_impl", ["tree", "fused"])
@pytest.mark.parametrize("dispatch", ["select", "split"])
def test_all_equal_per_agent_bit_identical_to_homogeneous(zo_impl, dispatch):
    hom = HDOConfig(n_agents=6, n_zeroth=4, zo_impl=zo_impl,
                    dispatch=dispatch, **BASE)
    het = dataclasses.replace(hom, sigmas=(1e-3,) * 4, rvs=(4,) * 4,
                              lrs=(0.05,) * 6, estimators_zo=("multi_rv",) * 4)
    assert resolve_population(het).homogeneous
    s1 = s2 = init_state({"w": jnp.zeros((D,))}, hom)
    step_hom = jax.jit(build_hdo_step(loss_fn, hom, param_dim=D))
    step_het = jax.jit(build_hdo_step(loss_fn, het, param_dim=D))
    for t in range(3):
        b = make_batches(jax.random.fold_in(jax.random.PRNGKey(9), t), 6)
        s1, m1 = step_hom(s1, b)
        s2, m2 = step_het(s2, b)
    assert set(m1) == set(m2)  # incl. NO grad_var_* keys when collapsed
    np.testing.assert_array_equal(np.asarray(s1.params["w"]),
                                  np.asarray(s2.params["w"]))
    np.testing.assert_array_equal(np.asarray(s1.opt_state["w"]),
                                  np.asarray(s2.opt_state["w"]))
    for k in m1:
        np.testing.assert_array_equal(np.asarray(m1[k]), np.asarray(m2[k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# genuinely heterogeneous cohorts train end-to-end through the jitted
# step — per-agent (sigma, rv, lr) + >= 2 estimator kinds, both engines
# ---------------------------------------------------------------------------


HET = dict(
    n_agents=6, n_zeroth=4,
    sigmas=(1e-3, 1e-2, 1e-3, 0.1),  # one "byzantine-ish" high-sigma agent
    rvs=(8, 4, 2, 1),  # ragged draw counts
    lrs=(0.05, 0.05, 0.05, 0.01, 0.05, 0.05),  # noisy agent down-weighted
    estimators_zo=("multi_rv", "fwd_grad", "multi_rv", "biased_2pt"),
)


@pytest.mark.parametrize("zo_impl", ["tree", "fused"])
@pytest.mark.parametrize("dispatch", ["select", "split"])
def test_heterogeneous_trains_end_to_end(zo_impl, dispatch):
    cfg = HDOConfig(zo_impl=zo_impl, dispatch=dispatch, **HET, **BASE)
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    first = None
    for t in range(60):
        state, m = step(state, make_batches(
            jax.random.fold_in(jax.random.PRNGKey(9), t), cfg.n_agents))
        first = float(m["loss_mean"]) if first is None else first
    # converged well below the start, and the per-group diagnostics ride
    # along in the metrics
    assert float(m["loss_mean"]) < 0.2 * first
    for key in ("grad_var_zo_multi_rv", "grad_var_zo_fwd_grad",
                "grad_var_zo_biased_2pt", "grad_var_fo"):
        assert key in m and np.isfinite(float(m[key]))
    # per-group *loss* trajectories ride along with the variance
    # diagnostics; the kind-group means must average back to the ZO
    # cohort mean (groups partition the cohort; sizes 2/1/1 here)
    for key in ("loss_zo_multi_rv_mean", "loss_zo_fwd_grad_mean",
                "loss_zo_biased_2pt_mean"):
        assert key in m and np.isfinite(float(m[key]))
    cohort = (2 * float(m["loss_zo_multi_rv_mean"])
              + float(m["loss_zo_fwd_grad_mean"])
              + float(m["loss_zo_biased_2pt_mean"])) / 4
    np.testing.assert_allclose(cohort, float(m["loss_zo_mean"]), rtol=1e-5)
    # the mean model fits the target
    mu = jax.tree.map(lambda x: x.mean(0), state.params)
    Xe = jax.random.normal(jax.random.PRNGKey(5), (256, D))
    assert float(jnp.mean((Xe @ mu["w"] - Xe @ W_TRUE) ** 2)) < 0.1


def test_heterogeneous_split_matches_select():
    """The grouped split dispatch is the same estimator on the same
    agent keys as the grouped select — one step must agree to float
    tolerance (graph shapes differ, so not pinned bit-exact)."""
    cfg_sel = HDOConfig(zo_impl="fused", dispatch="select", **HET, **BASE)
    cfg_spl = dataclasses.replace(cfg_sel, dispatch="split")
    s0 = init_state({"w": jnp.zeros((D,))}, cfg_sel)
    b = make_batches(jax.random.PRNGKey(3), cfg_sel.n_agents)
    s1, m1 = jax.jit(build_hdo_step(loss_fn, cfg_sel, param_dim=D))(s0, b)
    s2, m2 = jax.jit(build_hdo_step(loss_fn, cfg_spl, param_dim=D))(s0, b)
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]),
                               rtol=1e-5, atol=1e-7)
    assert set(m1) == set(m2)


def test_heterogeneous_lr_only():
    """Per-agent lrs alone (no ZO heterogeneity) goes down the
    heterogeneous path and still converges; the schedule shape is
    shared, scaled per agent."""
    cfg = HDOConfig(n_agents=4, n_zeroth=2,
                    lrs=(0.05, 0.05, 0.1, 0.1), **BASE)
    assert not resolve_population(cfg).homogeneous
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    for t in range(50):
        state, m = step(state, make_batches(
            jax.random.fold_in(jax.random.PRNGKey(1), t), 4))
    assert float(m["loss_mean"]) < 5e-2


def test_shard_cond_heterogeneous_no_mesh_equals_select():
    """The shard_cond homogeneous-cohort restriction is lifted: a
    heterogeneous cohort builds, and without a mesh the shard_cond path
    documents itself as falling through to the grouped select — the two
    dispatches must be the SAME program, bit for bit."""
    cfg_sc = HDOConfig(dispatch="shard_cond", **HET, **BASE)
    cfg_sel = dataclasses.replace(cfg_sc, dispatch="select")
    s0 = init_state({"w": jnp.zeros((D,))}, cfg_sc)
    b = make_batches(jax.random.PRNGKey(3), cfg_sc.n_agents)
    s1, m1 = jax.jit(build_hdo_step(loss_fn, cfg_sc, param_dim=D))(s0, b)
    s2, m2 = jax.jit(build_hdo_step(loss_fn, cfg_sel, param_dim=D))(s0, b)
    np.testing.assert_array_equal(np.asarray(s1.params["w"]),
                                  np.asarray(s2.params["w"]))
    assert set(m1) == set(m2)


def test_shard_cond_heterogeneous_misaligned_groups_raise():
    """With a real mesh, every population shard must hold agents of a
    single estimator-kind group (the runtime branch is per shard): a
    1-device mesh puts all of HET's mixed-kind cohort on one shard, so
    the build must fail loudly with the alignment message rather than
    silently running the wrong estimator."""
    mesh = jax.make_mesh((1,), ("data",))
    cfg = HDOConfig(dispatch="shard_cond", **HET, **BASE)
    with pytest.raises(ValueError, match="single estimator kind group"):
        build_hdo_step(loss_fn, cfg, param_dim=D, mesh=mesh,
                       population_axes=("data",))


@pytest.mark.slow
def test_het_shard_cond_parity_subprocess():
    """Mixed-kind cohort under shard_cond == select on a real
    multi-device population mesh (group-aligned shards: 8 agents over 4
    population shards, each shard a single kind group), both engines."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import HDOConfig
        from repro.core import build_hdo_step, init_state
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        d = 12
        w_true = jax.random.normal(jax.random.PRNGKey(42), (d,))
        def loss_fn(params, batch):
            return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)
        for impl in ("tree", "fused"):
            outs = {}
            for disp in ("select", "shard_cond"):
                cfg = HDOConfig(n_agents=8, n_zeroth=4, gossip="rr_static",
                                lr=0.05, momentum=0.0, warmup_steps=0,
                                use_cosine=False, nu=1e-3,
                                sigmas=(1e-3, 1e-2, 1e-3, 1e-3),
                                rvs=(4, 2, 2, 1),
                                lrs=(0.05, 0.01, 0.05, 0.05,
                                     0.05, 0.05, 0.05, 0.05),
                                estimators_zo=("multi_rv", "multi_rv",
                                               "fwd_grad", "fwd_grad"),
                                dispatch=disp, zo_impl=impl)
                step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=d,
                                              mesh=mesh,
                                              population_axes=("data",)))
                state = init_state({"w": jnp.zeros((d,))}, cfg)
                for t in range(30):
                    k = jax.random.fold_in(jax.random.PRNGKey(9), t)
                    X = jax.random.normal(k, (8, 8, d))
                    state, m = step(state, {"X": X, "y": X @ w_true})
                outs[disp] = np.asarray(state.params["w"])
            np.testing.assert_allclose(outs["select"], outs["shard_cond"],
                                       atol=1e-5, err_msg=impl)
        print("HET_SHARD_COND_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=420, env=env, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "HET_SHARD_COND_OK" in proc.stdout


def test_high_sigma_agent_dominates_group_variance():
    """The heterogeneity diagnostic does its job: a group containing a
    high-sigma agent logs a far larger gradient-estimate variance than
    the same group with all-clean sigmas.  Uses ``biased_1pt`` — the
    sigma-*sensitive* kind (its O(sigma) curvature bias spreads the
    group); the 2-point kinds are exact on this quadratic loss
    regardless of sigma."""
    kinds = ("biased_1pt", "biased_1pt", "fwd_grad", "fwd_grad")

    def group_var(sigmas):
        cfg = HDOConfig(n_agents=6, n_zeroth=4, sigmas=sigmas,
                        estimators_zo=kinds, **BASE)
        step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
        # start AT the optimum: grad F ~ 0, so the group spread is the
        # estimators' own noise — for biased_1pt that is the O(sigma)
        # curvature bias, isolated from the descent signal
        state = init_state({"w": W_TRUE}, cfg)
        _, m = step(state, make_batches(jax.random.PRNGKey(0), 6))
        return float(m["grad_var_zo_biased_1pt"])

    noisy = group_var((0.5, 1e-3, 1e-3, 1e-3))
    clean = group_var((1e-3, 1e-3, 1e-3, 1e-3))
    assert noisy > 10 * clean, (noisy, clean)
