"""Data substrate tests."""
import numpy as np

from repro.data import AgentBatcher, agent_data_splits, brackets, synthetic


def test_brackets_labels_correct():
    toks, labs = brackets.make_dataset(n_samples=64, seq_len=17, seed=0)
    assert toks.shape == (64, 17)
    for i in range(64):
        cls_pos = np.argmax(toks[i] == brackets.CLS)
        seq = toks[i, :cls_pos]
        gold = labs[i, cls_pos]
        assert gold in (brackets.LBL_TRUE, brackets.LBL_FALSE)
        assert (gold == brackets.LBL_TRUE) == brackets.is_valid(seq)
        # all other label positions masked
        assert (labs[i, :cls_pos] == -1).all()


def test_brackets_roughly_balanced():
    toks, labs = brackets.make_dataset(n_samples=512, seq_len=17, seed=1)
    pos = (labs == brackets.LBL_TRUE).sum()
    assert 150 < pos < 360


def test_agent_splits_cover_data_twice():
    """Paper: two copies of the data — one split over ZO, one over FO."""
    shards = agent_data_splits(100, n_zeroth=3, n_first=2, seed=0)
    assert len(shards) == 5
    zo_idx = np.concatenate(shards[:3])
    fo_idx = np.concatenate(shards[3:])
    assert sorted(zo_idx.tolist()) == list(range(100))
    assert sorted(fo_idx.tolist()) == list(range(100))


def test_agent_batcher_shapes():
    data = {"x": np.arange(200).reshape(100, 2).astype(np.float32),
            "y": np.arange(100).astype(np.int32)}
    b = AgentBatcher(data, n_zeroth=2, n_first=2, batch=8, seed=0)
    out = b.next_batches()
    assert out["x"].shape == (4, 8, 2)
    assert out["y"].shape == (4, 8)


def test_prototype_classification_learnable_structure():
    task = synthetic.PrototypeClassification(d=16, n_classes=4, noise=0.1, seed=0)
    x, y = task.sample(np.random.default_rng(0), 256)
    # nearest-prototype classifier should be near-perfect at low noise
    d2 = ((x[:, None, :] - task.prototypes[None]) ** 2).sum(-1)
    acc = (d2.argmin(1) == y).mean()
    assert acc > 0.95


def test_lm_stream_is_markov():
    sample = synthetic.lm_token_stream(vocab=64, seed=0)
    toks = sample(np.random.default_rng(1), 4, 128)
    assert toks.shape == (4, 128)
    assert toks.max() < 64
    # determinism of the table: same rng seed -> same tokens
    toks2 = sample(np.random.default_rng(1), 4, 128)
    np.testing.assert_array_equal(toks, toks2)
