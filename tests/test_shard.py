"""The sharded HDO round (core/shardround.py + topology/shardmix.py +
launch/mesh.make_hdo_mesh): device-free plan correctness against the
dense mixing matrix, mesh/table validation errors, the plane partition
rule, and 8-host-device subprocess parity of the sharded round against
the unsharded step across dispatch x zo_impl x param_layout (plus the
compressed-gossip comm streams, the plane FSDP path, and the phase-fns
decomposition).

Comparison discipline: select-dispatch sharded vs unsharded is pinned
BIT-EXACT (the in-shard bodies mirror the unsharded expressions term
for term, and the ppermute combine is the same jnp expression on the
same rows).  shard_cond is allclose only — the runtime ``lax.cond``
branches compile a different fusion than the masked dual-pass, the
same tolerance tests/test_perf_variants.py grants the unsharded
shard_cond path.  Wide irregular topologies (ER at k > 3) are allclose
at 1e-6: XLA may reassociate the k-slot multiply-add chain differently
across the two gather shapes.  ``all_reduce`` is allclose by design (a
psum reduces in a different order than ``mean(axis=0)``).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import plane as planelib
from repro.launch.mesh import make_hdo_mesh
from repro.topology import shardmix
from repro.topology.graphs import make_topology

# ---------------------------------------------------------------------------
# device-free: the ppermute plan against the dense mixing matrix
# ---------------------------------------------------------------------------


def _divisor_shard_counts(n):
    return [a for a in range(1, n + 1) if n % a == 0]


@pytest.mark.parametrize("name,kw", [
    ("ring", {}),
    ("torus", {}),
    ("hypercube", {}),
    ("erdos_renyi", {"p": 0.5, "seed": 3}),
    ("erdos_renyi", {"p": 0.8, "seed": 11}),
])
def test_plan_matches_dense_mixing_matrix(name, kw):
    """simulate_mix (the numpy oracle of exchange+combine) equals
    W @ X for every divisor shard count, on every static topology."""
    n = 8 if name != "erdos_renyi" else 12
    topo = make_topology(name, n, **kw)
    W = np.asarray(topo.mixing_matrix(), np.float64)
    X = np.random.RandomState(0).randn(n, 5)
    for A in _divisor_shard_counts(n):
        plan = shardmix.plan_shard_mix(topo, A)
        got = shardmix.simulate_mix(plan, topo, X)
        np.testing.assert_allclose(got, W @ X, atol=1e-12,
                                   err_msg=f"{name} A={A}")


def test_plan_slot_structure_for_permutation_columns():
    """At one agent per shard, a permutation-column topology colors to
    exactly one round per slot (the legacy per-slot ppermute schedule)
    and every round is a full permutation of the cross-shard edges."""
    for name, k in (("ring", 2), ("torus", 3), ("hypercube", 3)):
        topo = make_topology(name, 8)
        plan = shardmix.plan_shard_mix(topo, 8)
        assert plan.n_rounds == k, name
        assert plan.n_edges == 8 * k, name


def test_plan_round_bound_and_byte_accounting():
    """Greedy coloring stays within 2*Delta - 1 rounds, and the wire
    accounting scales with neighbor degree (ppermute) vs shard count
    (all-gather)."""
    topo = make_topology("erdos_renyi", 12, p=0.5, seed=3)
    plan = shardmix.plan_shard_mix(topo, 12)
    deg = np.zeros((12, 2), int)
    for r in plan.rounds:
        for (s, d) in r:
            deg[s, 0] += 1
            deg[d, 1] += 1
    assert plan.n_rounds <= 2 * deg.max() - 1
    # ring at 8 shards: 16 directed block edges vs 56 for all-gather
    ring = shardmix.plan_shard_mix(make_topology("ring", 8), 8)
    assert ring.ppermute_bytes(100) == 16 * 1 * 100 * 4
    assert ring.allgather_bytes(100) == 8 * 7 * 1 * 100 * 4
    assert ring.ppermute_bytes(100) < ring.allgather_bytes(100)


def test_plan_rejects_non_divisor_shard_count():
    topo = make_topology("ring", 8)
    with pytest.raises(ValueError, match="n_shards"):
        shardmix.plan_shard_mix(topo, 3)


# ---------------------------------------------------------------------------
# mesh construction + validation (single real device is enough: the
# ValueErrors fire before any device is touched)
# ---------------------------------------------------------------------------


def test_make_hdo_mesh_validates_model_parallel():
    n_dev = len(jax.devices())
    with pytest.raises(ValueError, match="model_parallel"):
        make_hdo_mesh(8, n_dev + 1)
    with pytest.raises(ValueError, match="model_parallel"):
        make_hdo_mesh(8, 0)


def test_make_hdo_mesh_validates_agent_shards():
    with pytest.raises(ValueError, match="agent_shards"):
        make_hdo_mesh(8, 1, agent_shards=3)


def test_make_hdo_mesh_single_device():
    mesh = make_hdo_mesh(8, 1)
    assert dict(mesh.shape) == {"agents": 1, "model": 1} or \
        dict(mesh.shape)["agents"] * dict(mesh.shape)["model"] == len(
            jax.devices())
    assert tuple(mesh.axis_names) == ("agents", "model")


def test_make_host_mesh_validates_model_parallel():
    from repro.launch.mesh import make_host_mesh

    n_dev = len(jax.devices())
    with pytest.raises(ValueError, match=f"model_parallel={n_dev + 1}"):
        make_host_mesh(model_parallel=n_dev + 1)


# ---------------------------------------------------------------------------
# plane partition rule + sharded RNG tables
# ---------------------------------------------------------------------------


def test_plane_pspec_block_divisibility():
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro import sharding as shardlib
    from repro.configs.base import MeshConfig
    from repro.kernels.zo_combine import BLOCK

    mesh = compat.abstract_mesh((4, 2), ("data", "model"))
    mcfg = MeshConfig()
    # dim divisible by model_shards * BLOCK -> FSDP-shard the dim axis
    spec = shardlib.plane_pspec(8, 4 * BLOCK, mcfg, mesh)
    assert spec == P("data", "model")
    # dim NOT divisible -> replicate the dim axis, keep the agent axis
    spec = shardlib.plane_pspec(8, 3 * BLOCK, mcfg, mesh)
    assert spec == P("data")
    # agent axis indivisible -> replicated entirely
    spec = shardlib.plane_pspec(7, 3 * BLOCK, mcfg, mesh)
    assert spec == P(None)


def test_rng_tables_sharded_consistency():
    """The per-shard tables draw the GLOBAL compact counter stream from
    local positions: local_idx - delta'[b] == global_idx - delta[blk]."""
    from repro.kernels.zo_combine import BLOCK

    params = {
        "a": jax.ShapeDtypeStruct((2 * BLOCK,), np.float32),
        "b": jax.ShapeDtypeStruct((BLOCK // 2,), np.float32),
        "c": jax.ShapeDtypeStruct((BLOCK + 7,), np.float32),
    }
    man = planelib.build_manifest(params)
    delta, nvalid = planelib.rng_tables(man)
    for M in (1, man.n_blocks):
        if man.n_blocks % M:
            continue
        delta_s, nvalid_s = planelib.rng_tables_sharded(man, M)
        assert delta_s.shape == (M, man.n_blocks // M)
        dim_local = man.dim // M
        b_local = man.n_blocks // M
        for s in range(M):
            for b in range(b_local):
                gblk = s * b_local + b
                # any local index in this block maps to the same counter
                local_idx = b * BLOCK
                global_idx = s * dim_local + local_idx
                assert (local_idx - delta_s[s, b]
                        == global_idx - delta[gblk]), (s, b)
        np.testing.assert_array_equal(
            nvalid_s.reshape(-1), nvalid)


def test_rng_tables_sharded_rejects_indivisible():
    from repro.kernels.zo_combine import BLOCK

    man = planelib.build_manifest(
        {"a": jax.ShapeDtypeStruct((3 * BLOCK,), np.float32)})
    with pytest.raises(ValueError, match="n_blocks"):
        planelib.rng_tables_sharded(man, 2)


# ---------------------------------------------------------------------------
# sharded-round build validation (device-free: errors fire at build)
# ---------------------------------------------------------------------------


def _build_sharded(cfg, mesh, **kw):
    import jax.numpy as jnp

    from repro.core.shardround import build_sharded_step

    def loss_fn(params, batch):
        return jnp.mean(params["w"] ** 2)

    return build_sharded_step(loss_fn, cfg, mesh=mesh, param_dim=4, **kw)


def test_sharded_step_scope_validation():
    from repro.configs.base import HDOConfig

    mesh = make_hdo_mesh(4, 1)
    base = dict(n_agents=4, n_zeroth=2, lr=0.05)
    with pytest.raises(ValueError, match="split"):
        _build_sharded(HDOConfig(dispatch="split", **base), mesh)
    with pytest.raises(ValueError, match="local_steps"):
        _build_sharded(HDOConfig(local_steps=2, **base), mesh)
    with pytest.raises(ValueError, match="not shardable"):
        _build_sharded(HDOConfig(gossip="dense", **base), mesh)
    with pytest.raises(ValueError, match="fault"):
        _build_sharded(HDOConfig(gossip="graph", topology="ring",
                                 fault_drop_rate=0.1, **base), mesh)
    with pytest.raises(ValueError, match="heterogeneous"):
        _build_sharded(HDOConfig(sigmas=(1e-3, 1e-1), **base), mesh)


def test_sharded_step_single_shard_mesh_bit_identical():
    """On a 1x1 mesh the sharded step runs with no collectives at all
    (the plan has no cross-shard edges) and must match the unsharded
    step bitwise — the degenerate end of the parity matrix, runnable
    on one real device."""
    import jax.numpy as jnp

    from repro.configs.base import HDOConfig
    from repro.core import build_hdo_step, init_state

    d = 8
    w_true = jax.random.normal(jax.random.PRNGKey(42), (d,))

    def loss_fn(params, batch):
        return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)

    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="graph", topology="ring",
                    lr=0.05, rv=2, nu=1e-3)
    mesh = make_hdo_mesh(4, 1, agent_shards=1)
    outs = {}
    for shard in (False, True):
        step = jax.jit(build_hdo_step(
            loss_fn, cfg, param_dim=d, shard=shard,
            mesh=mesh if shard else None,
            population_axes=("agents",) if shard else ()))
        state = init_state({"w": jnp.zeros((d,))}, cfg)
        for t in range(3):
            k = jax.random.fold_in(jax.random.PRNGKey(9), t)
            X = jax.random.normal(k, (4, 8, d))
            state, m = step(state, {"X": X, "y": X @ w_true})
        outs[shard] = state
    np.testing.assert_array_equal(np.asarray(outs[False].params["w"]),
                                  np.asarray(outs[True].params["w"]))
    for a, b in zip(jax.tree.leaves(outs[False].opt_state),
                    jax.tree.leaves(outs[True].opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_analytic_phase_bytes_per_shard():
    from repro.configs.base import HDOConfig
    from repro.obs.timing import analytic_phase_bytes

    cfg = HDOConfig(n_agents=8, n_zeroth=4, gossip="graph", topology="ring",
                    lr=0.05)
    whole = analytic_phase_bytes(cfg, 1000)
    per4 = analytic_phase_bytes(cfg, 1000, n_shards=4)
    assert whole and per4.keys() == whole.keys()
    for k in whole:
        assert per4[k] == whole[k] // 4
    with pytest.raises(ValueError, match="n_shards"):
        analytic_phase_bytes(cfg, 1000, n_shards=0)


# ---------------------------------------------------------------------------
# 8-host-device subprocess parity (slow lane)
# ---------------------------------------------------------------------------

_PARITY_PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import HDOConfig
    from repro.core import build_hdo_step, init_state
    from repro.core import plane as planelib
    from repro.launch.mesh import make_hdo_mesh

    def small_leaf_params():
        k = jax.random.PRNGKey(7)
        ks = jax.random.split(k, 3)
        return {
            "emb": jax.random.normal(ks[0], (96, 90)) * 0.1,
            "blk": {"w": jax.random.normal(ks[1], (40, 40)) * 0.1,
                    "b": jnp.zeros((40,)), "ln": jnp.ones((40,))},
            "head": jax.random.normal(ks[2], (90,)) * 0.1,
        }

    PARAMS = small_leaf_params()
    D = planelib.build_manifest(PARAMS).size
    W_TRUE = jax.random.normal(jax.random.PRNGKey(42), (D,)) * 0.1

    def loss_fn(params, batch):
        w = jnp.concatenate([l.reshape(-1)
                             for l in jax.tree_util.tree_leaves(params)])
        return jnp.mean((batch["X"] @ w - batch["y"]) ** 2)

    def make_batches(key, n):
        X = jax.random.normal(key, (n, 4, D)) / np.sqrt(D)
        return {"X": X, "y": X @ W_TRUE}

    def run(cfg, shard, mesh=None, steps=3):
        step = jax.jit(build_hdo_step(
            loss_fn, cfg, param_dim=D, params_template=PARAMS,
            shard=shard, mesh=mesh, population_axes=("agents",),
            model_axes=("model",)))
        state = init_state(PARAMS, cfg)
        for t in range(steps):
            b = make_batches(jax.random.fold_in(jax.random.PRNGKey(3), t),
                             cfg.n_agents)
            state, mets = step(state, b)
        return state, mets

    def check(name, cfg, mesh, exact=True, steps=3):
        s0, m0 = run(cfg, False, steps=steps)
        s1, m1 = run(cfg, True, mesh=mesh, steps=steps)
        for part in ("params", "opt_state", "comm"):
            for a, b in zip(jax.tree.leaves(getattr(s0, part)),
                            jax.tree.leaves(getattr(s1, part))):
                a, b = np.asarray(a), np.asarray(b)
                if exact:
                    np.testing.assert_array_equal(a, b,
                                                  err_msg=name + ":" + part)
                elif part == "opt_state":
                    # the ZO finite difference divides loss values by nu,
                    # amplifying last-ulp compile differences ~1e4x before
                    # momentum accumulates them — looser than the params
                    # themselves, which the mean-preserving mix keeps tight
                    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3,
                                               err_msg=name + ":" + part)
                else:
                    np.testing.assert_allclose(a, b, atol=1e-5,
                                               err_msg=name + ":" + part)
        np.testing.assert_allclose(float(m0["loss_mean"]),
                                   float(m1["loss_mean"]),
                                   atol=1e-6 if exact else 1e-4)
        print("ok", name)

    base = dict(n_agents=8, n_zeroth=4, lr=0.05, seed=0, rv=2,
                topology="ring", gossip="graph")
"""


def _run_parity(body, sentinel, timeout=540):
    script = textwrap.dedent(_PARITY_PRELUDE) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=timeout, env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert sentinel in proc.stdout, proc.stdout[-2000:]


@pytest.mark.slow
def test_sharded_parity_dispatch_layout_matrix_subprocess():
    """sharded == unsharded over dispatch x zo_impl x param_layout on
    8 host devices, at both one and two agents per shard.  select is
    bit-exact; shard_cond allclose (cond-branch fusion, the unsharded
    shard_cond tolerance)."""
    _run_parity("""
        mesh8 = make_hdo_mesh(8, 1)
        mesh4 = make_hdo_mesh(8, 1, agent_shards=4)
        for layout in ("tree", "plane"):
            for disp in ("select", "shard_cond"):
                for zo in ("tree", "fused"):
                    cfg = HDOConfig(param_layout=layout, dispatch=disp,
                                    zo_impl=zo, **base)
                    exact = disp == "select"
                    check(f"{layout}/{disp}/{zo}/A8", cfg, mesh8, exact=exact)
                    check(f"{layout}/{disp}/{zo}/A4", cfg, mesh4, exact=exact)
        print("SHARD_MATRIX_OK")
    """, "SHARD_MATRIX_OK")


@pytest.mark.slow
def test_sharded_plane_fsdp_and_adamw_subprocess():
    """Model-axis FSDP of the plane (4 agents x 2 model shards) and the
    adamw opt streams stay bit-exact; extended metrics match."""
    _run_parity("""
        mesh42 = make_hdo_mesh(8, 2)   # 4 agent shards x 2 model shards
        assert dict(mesh42.shape) == {"agents": 4, "model": 2}
        for zo in ("tree", "fused"):
            cfg = HDOConfig(param_layout="plane", dispatch="select",
                            zo_impl=zo, **base)
            check(f"plane/M2/{zo}", cfg, mesh42)
        cfg = HDOConfig(param_layout="plane", dispatch="select",
                        zo_impl="fused", optimizer="adamw", **base)
        check("plane/M2/adamw", cfg, mesh42)
        # extended metrics ride along bit-identically
        step = jax.jit(build_hdo_step(
            loss_fn, cfg, param_dim=D, params_template=PARAMS, shard=True,
            mesh=mesh42, population_axes=("agents",), model_axes=("model",),
            extended_metrics=True))
        state = init_state(PARAMS, cfg)
        b = make_batches(jax.random.PRNGKey(3), 8)
        state2, mets = step(state, b)
        assert "consensus_gamma" in mets and "gossip_wire_bytes" in mets
        print("SHARD_FSDP_OK")
    """, "SHARD_FSDP_OK")


@pytest.mark.slow
def test_sharded_compressed_gossip_comm_bit_identity_subprocess():
    """topk + error feedback: the sharded fresh compressed round leaves
    params AND the EF residual comm stream bit-identical to the
    unsharded CompressedGraphMixer, on both layouts, at 1, 2 and 4
    agents per shard.  qsgd is allclose only: the quantized payloads m
    are bit-identical (the round-1 EF residual u - m matches bitwise),
    but its stochastic-rounding subgraph changes how XLA fuses the
    difference-form combine's multiply-add chain between the two
    programs, leaving last-ulp differences in ``x + acc``."""
    _run_parity("""
        for A in (8, 4, 2):
            mesh = make_hdo_mesh(8, 1, agent_shards=A)
            for layout, zo in (("plane", "fused"), ("tree", "tree")):
                cfg = HDOConfig(param_layout=layout, dispatch="select",
                                zo_impl=zo, compression="topk",
                                compress_k=32, error_feedback=True, **base)
                check(f"topk_ef/{layout}/A{A}", cfg, mesh, steps=4)
            cfg = HDOConfig(param_layout="tree", dispatch="select",
                            zo_impl="tree", compression="qsgd",
                            compress_bits=4, error_feedback=True, **base)
            check(f"qsgd_ef/A{A}", cfg, mesh, steps=4, exact=False)
        print("SHARD_COMPRESS_OK")
    """, "SHARD_COMPRESS_OK")


@pytest.mark.slow
def test_sharded_irregular_topology_and_allreduce_subprocess():
    """Round-decomposed ppermute mixing on an irregular (non-
    permutation-column) ER graph tracks the dense gather (allclose:
    the k-slot combine may reassociate), and the psum all_reduce
    matches mean-broadcast."""
    _run_parity("""
        mesh4 = make_hdo_mesh(8, 1, agent_shards=4)
        kw = dict(base); kw.update(topology="erdos_renyi")
        cfg = HDOConfig(param_layout="tree", dispatch="select",
                        zo_impl="tree", topology_p=0.6, topology_seed=5,
                        **kw)
        check("er/A4", cfg, mesh4, exact=False)
        kw2 = dict(base); kw2.pop("topology"); kw2["gossip"] = "all_reduce"
        cfg = HDOConfig(param_layout="tree", dispatch="select",
                        zo_impl="tree", **kw2)
        check("all_reduce/A4", cfg, mesh4, exact=False)
        print("SHARD_IRREGULAR_OK")
    """, "SHARD_IRREGULAR_OK")


@pytest.mark.slow
def test_sharded_phase_fns_match_fused_subprocess():
    """The sharded three-phase decomposition (obs.timing shard=True)
    reproduces the sharded fused step bit-identically — the honesty
    contract behind the per-shard fenced timings."""
    _run_parity("""
        from repro.obs import timing as obstiming
        mesh4 = make_hdo_mesh(8, 1, agent_shards=4)
        cfg = HDOConfig(param_layout="plane", dispatch="select",
                        zo_impl="fused", **base)
        step = jax.jit(build_hdo_step(
            loss_fn, cfg, param_dim=D, params_template=PARAMS, shard=True,
            mesh=mesh4, population_axes=("agents",), model_axes=("model",)))
        fns = obstiming.build_phase_fns(
            loss_fn, cfg, param_dim=D, params_template=PARAMS, shard=True,
            mesh=mesh4, population_axes=("agents",), model_axes=("model",))
        state = init_state(PARAMS, cfg)
        b = make_batches(jax.random.PRNGKey(3), 8)
        fused, _ = step(state, b)
        phased, _ = obstiming.phase_round(fns, state, b)
        for a, c in zip(jax.tree.leaves(fused.params),
                        jax.tree.leaves(phased.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        print("SHARD_PHASES_OK")
    """, "SHARD_PHASES_OK")
