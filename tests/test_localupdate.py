"""The estimate -> update -> mix pipeline (PR 5): pre-refactor
bit-identity of the default local update, pluggable optimizers,
communication-reducing local steps, clip_norm, the fused opt_apply
wiring, and checkpoint/resume of the generalized HDOState.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, optim
from repro.configs.base import HDOConfig
from repro.core import (
    build_estimate_phase,
    build_hdo_step,
    init_state,
    make_local_update,
    mix_all_reduce,
    resolve_population,
    schedules,
)

D = 16
W_TRUE = jax.random.normal(jax.random.PRNGKey(42), (D,))


def loss_fn(params, batch):
    return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)


def make_batches(key, n_agents, bsz=8):
    X = jax.random.normal(key, (n_agents, bsz, D))
    return {"X": X, "y": X @ W_TRUE}


def stack_rounds(*bs):
    """Stack H per-substep batches along a new leading axis — the
    local_steps>1 batch contract (every leaf (H, n_agents, ...))."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)


BASE = dict(lr=0.05, momentum=0.9, warmup_steps=2, use_cosine=True,
            cosine_steps=50, nu=1e-3, rv=2, gossip="dense")


# ---------------------------------------------------------------------------
# the tentpole contract: ("sgd", local_steps=1) is bit-identical to the
# pre-refactor step.  The reference below is the seed repo's inline
# update math verbatim (momentum accumulated in f32, stored in
# momentum_dtype, the stored value consumed by the parameter update),
# recomposed from the shared estimate phase and Mixer — any bit drift
# introduced by the LocalUpdate/optim-substrate rewrite fails here.
# ---------------------------------------------------------------------------


def prerefactor_step(cfg, param_dim):
    from repro.topology.mixer import make_mixer

    pop = resolve_population(cfg)
    assert pop.homogeneous, "reference covers the homogeneous paths"
    n = cfg.n_agents
    sched = schedules.warmup_cosine(
        pop.lr0, cfg.warmup_steps, cfg.cosine_steps, cfg.use_cosine)
    mixer = make_mixer(cfg)
    estimate = build_estimate_phase(loss_fn, cfg)
    mdt = jnp.dtype(cfg.momentum_dtype)

    def step(params, momentum, t, batches):
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t)
        lr = sched(t)
        nu = (lr / jnp.sqrt(jnp.float32(param_dim))
              if (cfg.nu_from_lr and param_dim) else jnp.float32(pop.sigma0))
        agent_keys = jax.random.split(key, n)
        losses, g = estimate(params, batches, agent_keys, nu)
        # --- verbatim pre-refactor momentum-SGD block ---
        if cfg.momentum > 0.0:
            new_mom = jax.tree.map(
                lambda m, gi: (
                    cfg.momentum * m.astype(jnp.float32)
                    + (1.0 - cfg.momentum) * gi.astype(jnp.float32)
                ).astype(m.dtype),
                momentum, g)
            upd = new_mom
        else:
            new_mom = momentum
            upd = jax.tree.map(lambda gi: gi.astype(jnp.float32), g)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
            params, upd)
        gkey = jax.random.fold_in(key, 7)
        new_params = mixer(new_params, key=gkey, step=t)
        metrics = {"loss_mean": losses.mean(), "loss_std": losses.std(),
                   "lr": lr}
        if cfg.n_first:
            metrics["loss_fo_mean"] = losses[cfg.n_zeroth:].mean()
        if cfg.n_zeroth:
            metrics["loss_zo_mean"] = losses[: cfg.n_zeroth].mean()
        return new_params, new_mom, metrics

    def init_momentum():
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
            {"w": jnp.zeros((D,))})
        return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=mdt), stacked)

    return jax.jit(step), init_momentum


@pytest.mark.parametrize("zo_impl", ["tree", "fused"])
@pytest.mark.parametrize("dispatch", ["select", "split"])
def test_default_step_bit_identical_to_pre_refactor(dispatch, zo_impl):
    cfg = HDOConfig(n_agents=6, n_zeroth=4, dispatch=dispatch,
                    zo_impl=zo_impl, **BASE)
    ref_step, init_mom = prerefactor_step(cfg, D)
    mom = init_mom()
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    params = state.params
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    for t in range(3):
        b = make_batches(jax.random.fold_in(jax.random.PRNGKey(7), t), 6)
        params, mom, m_ref = ref_step(params, mom, jnp.int32(t), b)
        state, m_new = step(state, b)
    assert set(m_ref) <= set(m_new)  # + mixer diagnostics only
    np.testing.assert_array_equal(np.asarray(params["w"]),
                                  np.asarray(state.params["w"]))
    np.testing.assert_array_equal(np.asarray(mom["w"]),
                                  np.asarray(state.opt_state["w"]))
    for k in m_ref:
        np.testing.assert_array_equal(np.asarray(m_ref[k]),
                                      np.asarray(m_new[k]), err_msg=k)


def test_all_equal_heterogeneous_bit_identical_to_pre_refactor():
    """The acceptance matrix's het corner: an all-equal per-agent
    override collapses onto the homogeneous path, which itself is
    bit-identical to the pre-refactor step."""
    hom = HDOConfig(n_agents=6, n_zeroth=4, **BASE)
    het = dataclasses.replace(hom, sigmas=(1e-3,) * 4, rvs=(2,) * 4,
                              lrs=(0.05,) * 6, estimators_zo=("multi_rv",) * 4)
    assert resolve_population(het).homogeneous
    ref_step, init_mom = prerefactor_step(hom, D)
    mom = init_mom()
    params = init_state({"w": jnp.zeros((D,))}, hom).params
    state = init_state({"w": jnp.zeros((D,))}, het)
    step = jax.jit(build_hdo_step(loss_fn, het, param_dim=D))
    for t in range(3):
        b = make_batches(jax.random.fold_in(jax.random.PRNGKey(7), t), 6)
        params, mom, _ = ref_step(params, mom, jnp.int32(t), b)
        state, _ = step(state, b)
    np.testing.assert_array_equal(np.asarray(params["w"]),
                                  np.asarray(state.params["w"]))
    np.testing.assert_array_equal(np.asarray(mom["w"]),
                                  np.asarray(state.opt_state["w"]))


def test_bf16_momentum_bit_identical_to_pre_refactor():
    cfg = HDOConfig(n_agents=4, n_zeroth=2, momentum_dtype="bfloat16", **BASE)
    ref_step, init_mom = prerefactor_step(cfg, D)
    mom = init_mom()
    params = init_state({"w": jnp.zeros((D,))}, cfg).params
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    for t in range(3):
        b = make_batches(jax.random.fold_in(jax.random.PRNGKey(7), t), 4)
        params, mom, _ = ref_step(params, mom, jnp.int32(t), b)
        state, _ = step(state, b)
    np.testing.assert_array_equal(np.asarray(params["w"]),
                                  np.asarray(state.params["w"]))
    np.testing.assert_array_equal(np.asarray(mom["w"], np.float32),
                                  np.asarray(state.opt_state["w"], np.float32))


# ---------------------------------------------------------------------------
# local_steps: H estimator passes per gossip, Mixer exactly once per
# round — both verified through the jitted step
# ---------------------------------------------------------------------------

CONST = dict(lr=0.05, momentum=0.9, warmup_steps=0, use_cosine=False,
             nu=1e-3, rv=2)


def test_local_steps_equals_sequential_without_gossip():
    """One H=3 round with no gossip == three H=1 rounds bit for bit on
    the SAME three fresh batches (constant lr; the substep counter
    t*H+h extends the H=1 key stream, and each substep consumes its own
    slice of the stacked (H, n, ...) batches) — proving the scan runs
    exactly H estimate+update iterations on H distinct batches."""
    cfg1 = HDOConfig(n_agents=4, n_zeroth=2, gossip="none", **CONST)
    cfgH = dataclasses.replace(cfg1, local_steps=3)
    bs = [make_batches(jax.random.fold_in(jax.random.PRNGKey(3), h), 4)
          for h in range(3)]
    s1 = init_state({"w": jnp.zeros((D,))}, cfg1)
    step1 = jax.jit(build_hdo_step(loss_fn, cfg1, param_dim=D))
    for b in bs:
        s1, _ = step1(s1, b)
    sH = init_state({"w": jnp.zeros((D,))}, cfgH)
    stepH = jax.jit(build_hdo_step(loss_fn, cfgH, param_dim=D))
    sH, mH = stepH(sH, stack_rounds(*bs))
    assert int(sH.step) == 1  # one round, H local substeps
    np.testing.assert_array_equal(np.asarray(s1.params["w"]),
                                  np.asarray(sH.params["w"]))
    np.testing.assert_array_equal(np.asarray(s1.opt_state["w"]),
                                  np.asarray(sH.opt_state["w"]))


def test_local_steps_rejects_unstacked_batches():
    """H>1 with batches missing the leading H axis must fail loudly at
    trace time — silently re-descending one batch H times was the bug
    this contract removed."""
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="none", local_steps=3,
                    **CONST)
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    step = build_hdo_step(loss_fn, cfg, param_dim=D)
    with pytest.raises(ValueError, match="fresh per-substep batches"):
        step(state, make_batches(jax.random.PRNGKey(0), 4))


def test_local_steps_mix_once_per_round():
    """With gossip="all_reduce" the round must equal: H local substeps
    with NO communication, then ONE full-mean mix — the Mixer runs
    exactly once per round, after the scan."""
    cfgN = HDOConfig(n_agents=4, n_zeroth=2, gossip="none", local_steps=2,
                     **CONST)
    cfgA = dataclasses.replace(cfgN, gossip="all_reduce")
    b = stack_rounds(
        make_batches(jax.random.PRNGKey(5), 4),
        make_batches(jax.random.PRNGKey(6), 4))
    s0 = init_state({"w": jnp.zeros((D,))}, cfgN)
    sN, _ = jax.jit(build_hdo_step(loss_fn, cfgN, param_dim=D))(s0, b)
    sA, _ = jax.jit(build_hdo_step(loss_fn, cfgA, param_dim=D))(s0, b)
    expected = jax.jit(mix_all_reduce)(sN.params)
    np.testing.assert_array_equal(np.asarray(expected["w"]),
                                  np.asarray(sA.params["w"]))
    # the opt state is untouched by the mix
    np.testing.assert_array_equal(np.asarray(sN.opt_state["w"]),
                                  np.asarray(sA.opt_state["w"]))


def test_local_steps_heterogeneous_runs():
    """H>1 composes with the grouped heterogeneous dispatch (scalar
    metrics averaged over substeps, incl. the per-group trajectories)."""
    cfg = HDOConfig(n_agents=4, n_zeroth=3, gossip="dense", local_steps=2,
                    sigmas=(1e-3, 1e-2, 1e-3), rvs=(4, 2, 1),
                    estimators_zo=("multi_rv", "fwd_grad", "multi_rv"),
                    **CONST)
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    first = None
    for t in range(30):
        state, m = step(state, stack_rounds(
            make_batches(jax.random.fold_in(jax.random.PRNGKey(2), 2 * t), 4),
            make_batches(jax.random.fold_in(jax.random.PRNGKey(2), 2 * t + 1), 4)))
        first = float(m["loss_mean"]) if first is None else first
    assert float(m["loss_mean"]) < 0.5 * first, (first, float(m["loss_mean"]))
    for k in ("grad_var_zo_multi_rv", "loss_zo_multi_rv_mean",
              "loss_zo_fwd_grad_mean", "grad_var_fo"):
        assert k in m and np.isfinite(float(m[k])), k


# ---------------------------------------------------------------------------
# pluggable optimizers
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    # adamw's normalized update needs a decaying lr to settle below the
    # constant-step noise floor — cosine to ~0 over the run
    cfg = HDOConfig(n_agents=6, n_zeroth=4, gossip="dense",
                    optimizer="adamw", lr=0.1, momentum=0.9,
                    warmup_steps=5, use_cosine=True, cosine_steps=200,
                    nu=1e-3, rv=2)
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    for t in range(200):
        state, m = step(state, make_batches(
            jax.random.fold_in(jax.random.PRNGKey(9), t), 6))
    mu = jax.tree.map(lambda x: x.mean(0), state.params)
    Xe = jax.random.normal(jax.random.PRNGKey(5), (256, D))
    assert float(jnp.mean((Xe @ mu["w"] - Xe @ W_TRUE) ** 2)) < 5e-2
    # the adamw opt state is carried through the step (count == rounds,
    # one update per round at H=1)
    assert int(state.opt_state["count"]) == 200


@pytest.mark.slow
def test_adamw_local_steps_converges_brackets():
    """adamw + local_steps>1 on the paper's Brackets task: the
    communication-reduced regime still trains the real (reduced)
    transformer."""
    from repro.configs.paper_tasks import brackets_transformer
    from repro.data import brackets
    from repro.models import build_model

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    toks, labs = brackets.make_dataset(n_samples=512, seq_len=17, seed=0)
    hcfg = HDOConfig(n_agents=4, n_zeroth=2, rv=8, estimator_zo="fwd_grad",
                     gossip="dense", lr=0.01, momentum=0.8,
                     optimizer="adamw", local_steps=2, clip_norm=1.0,
                     warmup_steps=3, cosine_steps=30, nu=1e-4)
    step = jax.jit(build_hdo_step(model.loss, hcfg))
    state = init_state(model.init(jax.random.PRNGKey(0)), hcfg)
    rng = np.random.default_rng(0)
    first = None
    for t in range(30):
        # local_steps=2: each round consumes a fresh batch per substep
        idx = rng.integers(0, 512, size=(2, 4, 16))
        batches = {"tokens": jnp.asarray(toks[idx]),
                   "labels": jnp.asarray(labs[idx])}
        state, m = step(state, batches)
        if first is None:
            first = float(m["loss_mean"])
    assert float(m["loss_mean"]) < first * 0.8, (first, float(m["loss_mean"]))
    # 30 rounds x H=2 local updates
    assert int(state.opt_state["count"]) == 60


# ---------------------------------------------------------------------------
# clip_norm (wires the previously-dead optim.clip_by_global_norm)
# ---------------------------------------------------------------------------


def test_clip_norm_validation():
    with pytest.raises(ValueError, match="clip_norm"):
        HDOConfig(clip_norm=-1.0)
    with pytest.raises(ValueError, match="optimizer"):
        HDOConfig(optimizer="adam")
    with pytest.raises(ValueError, match="local_steps"):
        HDOConfig(local_steps=0)


def test_clip_norm_caps_update():
    """With momentum=0 the per-round parameter displacement is exactly
    lr * clipped-gradient, so each agent's step norm is <= lr * clip."""
    clip = 0.1
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="none", clip_norm=clip,
                    lr=0.05, momentum=0.0, warmup_steps=0, use_cosine=False,
                    nu=1e-3, rv=2)
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    new, _ = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))(
        state, make_batches(jax.random.PRNGKey(0), 4))
    delta = np.asarray(new.params["w"]) - np.asarray(state.params["w"])
    norms = np.linalg.norm(delta, axis=1)
    assert np.all(norms <= 0.05 * clip * (1 + 1e-5)), norms
    # and the gradients are genuinely large enough that clipping bit
    unclipped, _ = jax.jit(build_hdo_step(
        loss_fn, dataclasses.replace(cfg, clip_norm=0.0), param_dim=D))(
        state, make_batches(jax.random.PRNGKey(0), 4))
    du = np.asarray(unclipped.params["w"]) - np.asarray(state.params["w"])
    assert np.linalg.norm(du, axis=1).max() > 0.05 * clip * 2


def test_huge_clip_norm_is_identity():
    """A clip threshold far above the gradient norms multiplies by
    exactly 1.0 — bit-identical to clip_norm=0."""
    base = HDOConfig(n_agents=4, n_zeroth=2, gossip="dense", **CONST)
    clipped = dataclasses.replace(base, clip_norm=1e9)
    state = init_state({"w": jnp.zeros((D,))}, base)
    b = make_batches(jax.random.PRNGKey(1), 4)
    s0, _ = jax.jit(build_hdo_step(loss_fn, base, param_dim=D))(state, b)
    s1, _ = jax.jit(build_hdo_step(loss_fn, clipped, param_dim=D))(state, b)
    np.testing.assert_array_equal(np.asarray(s0.params["w"]),
                                  np.asarray(s1.params["w"]))


# ---------------------------------------------------------------------------
# the fused opt_apply wiring (flat-params kernel path of the sgd
# LocalUpdate; default on TPU only — forced on here)
# ---------------------------------------------------------------------------


def test_fused_sgd_apply_bit_exact_vs_tree_path():
    """Dyadic beta/lr make the kernel's mul+add chain FMA-proof, so the
    kernel path must agree with the tree path bit for bit — including a
    non-block-aligned large leaf (kernel route, tail-padded), small
    leaves (below _KERNEL_MIN_SIZE: jnp route), and per-agent lr_vec."""
    n = 3
    cfg = HDOConfig(n_agents=n, n_zeroth=2, momentum=0.5)
    lu_tree = make_local_update(cfg, use_kernel=False)
    lu_kern = make_local_update(cfg, use_kernel=True)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, 8292)),
              "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (n, 7))}}
    g = jax.tree.map(lambda x: x * 0.25, params)
    mom = jax.tree.map(lambda x: x * 0.125, params)
    for lr, lr_vec in ((jnp.float32(0.25), None),
                       (jnp.float32(0.25), jnp.asarray([0.25, 0.5, 0.125]))):
        pt, mt = lu_tree.apply(params, g, mom, lr, lr_vec)
        pk, mk = lu_kern.apply(params, g, mom, lr, lr_vec)
        for a, b in zip(jax.tree.leaves((pt, mt)), jax.tree.leaves((pk, mk))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_sgd_apply_bf16_momentum():
    n = 2
    cfg = HDOConfig(n_agents=n, n_zeroth=1, momentum=0.5,
                    momentum_dtype="bfloat16")
    lu_tree = make_local_update(cfg, use_kernel=False)
    lu_kern = make_local_update(cfg, use_kernel=True)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, 8200))}
    g = jax.tree.map(lambda x: x * 0.25, params)
    mom = jax.tree.map(lambda x: (x * 0.125).astype(jnp.bfloat16), params)
    pt, mt = lu_tree.apply(params, g, mom, jnp.float32(0.25), None)
    pk, mk = lu_kern.apply(params, g, mom, jnp.float32(0.25), None)
    assert mk["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(pt["w"]), np.asarray(pk["w"]))
    np.testing.assert_array_equal(np.asarray(mt["w"], np.float32),
                                  np.asarray(mk["w"], np.float32))


# ---------------------------------------------------------------------------
# checkpoint / resume: restored run == uninterrupted run, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("optimizer", ["sgd", "adamw"])
def test_resume_bit_identity(tmp_path, optimizer):
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="dense",
                    optimizer=optimizer, local_steps=2, **CONST)
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))

    def batch_at(t):
        # local_steps=2: two fresh sub-batches per round
        return stack_rounds(
            make_batches(jax.random.fold_in(jax.random.PRNGKey(11), 2 * t), 4),
            make_batches(jax.random.fold_in(jax.random.PRNGKey(11), 2 * t + 1), 4))

    # uninterrupted: 5 rounds
    full = init_state({"w": jnp.zeros((D,))}, cfg)
    for t in range(5):
        full, _ = step(full, batch_at(t))
    # interrupted: 3 rounds, save, restore into a fresh template, 2 more
    part = init_state({"w": jnp.zeros((D,))}, cfg)
    for t in range(3):
        part, _ = step(part, batch_at(t))
    path = os.path.join(str(tmp_path), "ck")
    checkpoint.save_state(path, part, meta={"optimizer": optimizer})
    restored, meta = checkpoint.restore_state(
        path, init_state({"w": jnp.zeros((D,))}, cfg))
    assert meta["optimizer"] == optimizer and int(restored.step) == 3
    for t in range(3, 5):
        restored, _ = step(restored, batch_at(t))
    np.testing.assert_array_equal(np.asarray(full.params["w"]),
                                  np.asarray(restored.params["w"]))
    for a, b in zip(jax.tree.leaves(full.opt_state),
                    jax.tree.leaves(restored.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_torn_checkpoint(tmp_path):
    """A crash between the npz and sidecar renames leaves files from
    different saves — the shared token catches the pair at restore."""
    import shutil

    cfg = HDOConfig(n_agents=3, n_zeroth=1, **CONST)
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    a = os.path.join(str(tmp_path), "a")
    b = os.path.join(str(tmp_path), "b")
    checkpoint.save_state(a, state)
    checkpoint.save_state(b, state)
    shutil.copy(b + ".npz", a + ".npz")  # new npz, stale sidecar
    with pytest.raises(ValueError, match="torn checkpoint"):
        checkpoint.restore_state(a, state)


def test_restore_rejects_dtype_mismatch(tmp_path):
    """momentum_dtype drift between save and restore template must be
    loud — a silent cast would perturb the optimizer state."""
    f32 = HDOConfig(n_agents=3, n_zeroth=1, **CONST)
    bf16 = dataclasses.replace(f32, momentum_dtype="bfloat16")
    path = os.path.join(str(tmp_path), "ck")
    checkpoint.save_state(path, init_state({"w": jnp.zeros((D,))}, f32))
    with pytest.raises(ValueError, match="dtype mismatch"):
        checkpoint.restore_state(path, init_state({"w": jnp.zeros((D,))}, bf16))


def test_adamw_weight_decay_wired():
    """weight_decay reaches optim.adamw: with decay the params shrink
    relative to the decay-free run on a zero-gradient-free... simply:
    the two runs must differ, and negative decay is rejected."""
    with pytest.raises(ValueError, match="weight_decay"):
        HDOConfig(weight_decay=-0.1)
    base = HDOConfig(n_agents=4, n_zeroth=2, gossip="none",
                     optimizer="adamw", **CONST)
    wd = dataclasses.replace(base, weight_decay=0.3)
    s0 = init_state({"w": jnp.full((D,), 1.0)}, base)
    b = make_batches(jax.random.PRNGKey(0), 4)
    s_plain, _ = jax.jit(build_hdo_step(loss_fn, base, param_dim=D))(s0, b)
    s_decay, _ = jax.jit(build_hdo_step(loss_fn, wd, param_dim=D))(s0, b)
    # decay pulls every agent's params toward 0 relative to plain adam
    assert (np.abs(np.asarray(s_decay.params["w"])).sum()
            < np.abs(np.asarray(s_plain.params["w"])).sum())


def test_restore_rejects_optimizer_mismatch(tmp_path):
    """A checkpoint written under sgd cannot silently restore into an
    adamw template — the opt_state structures differ."""
    sgd_cfg = HDOConfig(n_agents=3, n_zeroth=1, **CONST)
    path = os.path.join(str(tmp_path), "ck")
    checkpoint.save_state(path, init_state({"w": jnp.zeros((D,))}, sgd_cfg))
    adamw_cfg = dataclasses.replace(sgd_cfg, optimizer="adamw")
    with pytest.raises(ValueError, match="structure mismatch"):
        checkpoint.restore_state(
            path, init_state({"w": jnp.zeros((D,))}, adamw_cfg))


# ---------------------------------------------------------------------------
# the optim substrate is live: LocalUpdate("sgd") IS optim.sgd
# ---------------------------------------------------------------------------


def test_local_update_backed_by_optim_substrate():
    cfg = HDOConfig(n_agents=2, n_zeroth=1, momentum=0.9)
    lu = make_local_update(cfg, use_kernel=False)
    params = {"w": jnp.ones((2, 4))}
    g = {"w": jnp.full((2, 4), 0.5)}
    st = lu.init(params)
    opt = optim.sgd(0.9)
    upd_ref, _ = opt.update(g, jax.tree.map(jnp.zeros_like, params), params)
    new_p, new_m = lu.apply(params, g, st, jnp.float32(0.1), None)
    np.testing.assert_array_equal(np.asarray(new_m["w"]),
                                  np.asarray(upd_ref["w"]))
    np.testing.assert_array_equal(
        np.asarray(new_p["w"]),
        np.asarray(optim.apply_updates(params, upd_ref, jnp.float32(0.1))["w"]))
