"""Estimator unit tests: bias/variance structure from the paper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimators


def quad_loss(A, b):
    def loss(p):
        x = p["x"]
        return 0.5 * x @ A @ x - b @ x

    return loss


@pytest.fixture(scope="module")
def quad():
    key = jax.random.PRNGKey(0)
    d = 12
    A = jax.random.normal(key, (d, d))
    A = A @ A.T / d + jnp.eye(d)
    b = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    p = {"x": jax.random.normal(jax.random.fold_in(key, 2), (d,))}
    return A, b, p, d


def test_fo_matches_analytic(quad):
    A, b, p, d = quad
    loss = quad_loss(A, b)
    val, g = estimators.fo_estimate(loss, p)
    np.testing.assert_allclose(g["x"], A @ p["x"] - b, rtol=1e-5)
    np.testing.assert_allclose(val, loss(p), rtol=1e-6)


@pytest.mark.parametrize("kind", ["biased_1pt", "biased_2pt", "multi_rv", "fwd_grad"])
def test_zo_mean_close_to_grad(quad, kind):
    """E[G] ~ grad f (exactly for fwd_grad; O(nu^2) bias for FD)."""
    A, b, p, d = quad
    loss = quad_loss(A, b)
    g_true = A @ p["x"] - b
    est = jax.jit(
        lambda k: estimators.zo_estimate(loss, p, k, kind=kind, rv=8, nu=1e-4)[1]["x"]
    )
    n = 300
    gs = jnp.stack([est(jax.random.PRNGKey(100 + i)) for i in range(n)])
    gm = gs.mean(0)
    rel = float(jnp.linalg.norm(gm - g_true) / jnp.linalg.norm(g_true))
    # MC error ~ sqrt(d / (rv*n)) ~ 0.07; allow 4 sigma
    assert rel < 0.3, (kind, rel)


def test_zo_variance_scales_inverse_rv(quad):
    """Var[multi_rv] ~ 1/rv (paper: more random vectors -> lower noise)."""
    A, b, p, d = quad
    loss = quad_loss(A, b)

    def var_of(rv, n=200):
        est = jax.jit(
            lambda k: estimators.zo_estimate(loss, p, k, kind="multi_rv", rv=rv, nu=1e-4)[1]["x"]
        )
        gs = jnp.stack([est(jax.random.PRNGKey(i)) for i in range(n)])
        return float(gs.var(0).sum())

    v1, v8 = var_of(1), var_of(8)
    assert 4.0 < v1 / v8 < 16.0, (v1, v8)


def test_fwd_grad_single_sample_identity():
    """For fixed u, fwd_grad gives exactly (u . g) u on a linear fn."""
    g = jnp.asarray([1.0, -2.0, 3.0])
    loss = lambda p: p["x"] @ g
    _, est = estimators.zo_estimate(loss, {"x": jnp.zeros(3)}, jax.random.PRNGKey(3),
                                    kind="fwd_grad", rv=1)
    # est = (u.g)u for the drawn u; verify it is rank-1 aligned with u
    u = estimators.tree_normal(jax.random.fold_in(jax.random.PRNGKey(3), 0), {"x": jnp.zeros(3)})["x"]
    np.testing.assert_allclose(est["x"], (u @ g) * u, rtol=1e-5)


def test_biased_1pt_primal_is_loss0(quad):
    A, b, p, d = quad
    loss = quad_loss(A, b)
    val, _ = estimators.zo_estimate(loss, p, jax.random.PRNGKey(0), kind="biased_1pt", nu=1e-4)
    np.testing.assert_allclose(val, loss(p), rtol=1e-6)


def test_tree_normal_structure():
    tree = {"a": jnp.zeros((3, 4)), "b": {"c": jnp.zeros((5,), jnp.bfloat16)}}
    u = estimators.tree_normal(jax.random.PRNGKey(0), tree)
    assert u["a"].shape == (3, 4)
    assert u["b"]["c"].dtype == jnp.bfloat16
