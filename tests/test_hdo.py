"""HDO end-to-end behaviour: convergence, consensus, schedules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, consensus_distance, init_state, schedules, zo_mask

D = 16
W_TRUE = jax.random.normal(jax.random.PRNGKey(42), (D,))


def loss_fn(params, batch):
    return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)


def make_batches(key, n_agents, bsz=8):
    X = jax.random.normal(key, (n_agents, bsz, D))
    return {"X": X, "y": X @ W_TRUE}


def run(cfg, steps=150):
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    for t in range(steps):
        state, m = step(state, make_batches(jax.random.fold_in(jax.random.PRNGKey(9), t), cfg.n_agents))
    Xe = jax.random.normal(jax.random.PRNGKey(5), (256, D))
    mu = jax.tree.map(lambda x: x.mean(0), state.params)
    return float(jnp.mean((Xe @ mu["w"] - Xe @ W_TRUE) ** 2)), state


BASE = dict(lr=0.05, momentum=0.0, warmup_steps=0, use_cosine=False, nu=1e-3, rv=4)


def test_pure_fo_converges():
    loss, _ = run(HDOConfig(n_agents=4, n_zeroth=0, gossip="dense", **BASE))
    assert loss < 1e-3


def test_hybrid_converges():
    loss, state = run(HDOConfig(n_agents=8, n_zeroth=6, gossip="dense", **BASE))
    assert loss < 1e-2
    assert float(consensus_distance(state.params)) < 1e-3  # consensus (Fig 7)


def test_pure_zo_converges():
    loss, _ = run(HDOConfig(n_agents=8, n_zeroth=8, gossip="dense", **BASE))
    assert loss < 5e-2


def test_fwd_grad_population_converges():
    loss, _ = run(HDOConfig(n_agents=8, n_zeroth=8, gossip="dense",
                            estimator_zo="fwd_grad", **BASE))
    assert loss < 5e-2


def test_fused_fwd_grad_population_converges():
    """zo_impl="fused" + fwd_grad runs the flat_fwd_grad engine end-to-
    end through build_hdo_step (no tree fallback since PR 2)."""
    loss, _ = run(HDOConfig(n_agents=8, n_zeroth=8, gossip="dense",
                            estimator_zo="fwd_grad", zo_impl="fused", **BASE))
    assert loss < 5e-2


@pytest.mark.parametrize("zo_impl", ["tree", "fused"])
@pytest.mark.parametrize("estimator_zo", ["multi_rv", "fwd_grad"])
def test_split_dispatch_step_identical_to_select(zo_impl, estimator_zo):
    """One step under dispatch="split" vs the masked SPMD-uniform
    baseline: identical per-agent losses and params (both paths share
    agent_keys, so any drift is a bug — not just statistical parity)."""
    cfg_sel = HDOConfig(n_agents=6, n_zeroth=4, gossip="dense", dispatch="select",
                        estimator_zo=estimator_zo, zo_impl=zo_impl, momentum=0.9,
                        lr=0.05, warmup_steps=0, use_cosine=False, nu=1e-3, rv=2)
    cfg_spl = dataclasses.replace(cfg_sel, dispatch="split")
    batches = make_batches(jax.random.PRNGKey(3), cfg_sel.n_agents)
    state0 = init_state({"w": jnp.zeros((D,))}, cfg_sel)
    s_sel, m_sel = jax.jit(build_hdo_step(loss_fn, cfg_sel, param_dim=D))(state0, batches)
    s_spl, m_spl = jax.jit(build_hdo_step(loss_fn, cfg_spl, param_dim=D))(state0, batches)
    np.testing.assert_array_equal(np.asarray(s_sel.params["w"]),
                                  np.asarray(s_spl.params["w"]))
    np.testing.assert_array_equal(np.asarray(s_sel.opt_state["w"]),
                                  np.asarray(s_spl.opt_state["w"]))
    for k in m_sel:
        np.testing.assert_array_equal(np.asarray(m_sel[k]), np.asarray(m_spl[k]),
                                      err_msg=k)


def test_donated_step_matches_undonated():
    """donate=True returns a jitted step with the state buffers donated;
    results are unchanged."""
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="dense", **BASE)
    plain = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    donated = build_hdo_step(loss_fn, cfg, param_dim=D, donate=True)
    batches = make_batches(jax.random.PRNGKey(1), cfg.n_agents)
    s_plain, _ = plain(init_state({"w": jnp.zeros((D,))}, cfg), batches)
    s_don, _ = donated(init_state({"w": jnp.zeros((D,))}, cfg), batches)
    np.testing.assert_array_equal(np.asarray(s_plain.params["w"]),
                                  np.asarray(s_don.params["w"]))


def test_config_validation_rejects_typos():
    with pytest.raises(ValueError):
        HDOConfig(estimator_zo="multirv")
    with pytest.raises(ValueError):
        HDOConfig(zo_impl="flat")
    with pytest.raises(ValueError):
        HDOConfig(dispatch="shard")
    with pytest.raises(ValueError):
        HDOConfig(gossip="ring")  # ring is a topology, not a gossip mode
    with pytest.raises(ValueError):
        HDOConfig(topology="rng")
    with pytest.raises(ValueError):
        HDOConfig(topology_p=0.0)
    with pytest.raises(ValueError):
        HDOConfig(topology_rounds=0)
    with pytest.raises(ValueError):
        HDOConfig(momentum_dtype="bf16")
    with pytest.raises(ValueError):
        HDOConfig(n_agents=4, n_zeroth=5)


def test_rr_gossip_equivalent_convergence():
    loss, _ = run(HDOConfig(n_agents=8, n_zeroth=4, gossip="rr_static", **BASE))
    assert loss < 1e-2


def test_hybrid_beats_mono_zo_same_size():
    """Paper Figs 2-4: hybrid outperforms the same-size pure-ZO population.

    Compared mid-descent (50 steps, rv=1) where the populations are
    well separated — at 100 steps with rv=4 both have converged to the
    ~1e-8 float noise floor and the comparison is a coin flip — and on
    the median over 3 ZO-perturbation seeds.
    """
    mid = dict(BASE, rv=1)

    def median_loss(n_zeroth):
        losses = [
            run(HDOConfig(n_agents=8, n_zeroth=n_zeroth, gossip="dense", seed=s, **mid),
                steps=50)[0]
            for s in range(3)
        ]
        return sorted(losses)[1]

    assert median_loss(4) < median_loss(8)


def test_momentum_runs():
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="dense", lr=0.02, momentum=0.9,
                    warmup_steps=5, cosine_steps=60, use_cosine=True, nu=1e-3, rv=2)
    loss, _ = run(cfg, steps=60)
    assert np.isfinite(loss)


def test_zo_mask():
    cfg = HDOConfig(n_agents=6, n_zeroth=2)
    m = np.asarray(zo_mask(cfg))
    assert m.tolist() == [True, True, False, False, False, False]


def test_warmup_cosine_schedule():
    s = schedules.warmup_cosine(0.1, warmup_steps=10, cosine_steps=100)
    assert float(s(0)) == pytest.approx(0.01)
    assert float(s(9)) == pytest.approx(0.1)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-6)
    vals = [float(s(t)) for t in range(0, 100, 5)]
    assert max(vals) <= 0.1 * (1 + 1e-5) and min(vals) >= 0.0


def test_state_is_pytree():
    cfg = HDOConfig(n_agents=3, n_zeroth=1)
    state = init_state({"w": jnp.zeros((4,))}, cfg)
    leaves = jax.tree.leaves(state)
    assert any(l.shape == (3, 4) for l in leaves)
