import os

# Tests must see the real (1-device) CPU platform — the 512-device flag
# is set ONLY inside repro/launch/dryrun.py (its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


def require_hypothesis():
    """The single gate for hypothesis-based tests (README §Development).

    ``hypothesis`` is a declared test extra (pyproject ``[test]``) but
    is absent from the pinned CPU container — files that need it call
    this at import time and skip cleanly there, while CI (which
    installs ``.[test]``) runs them.  Returns the imported module.
    """
    return pytest.importorskip("hypothesis")
