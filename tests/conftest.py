import os

# Tests must see the real (1-device) CPU platform — the 512-device flag
# is set ONLY inside repro/launch/dryrun.py (its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
