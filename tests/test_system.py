"""End-to-end behaviour tests for the paper's system: HDO trains a real
(reduced) transformer on the paper's Brackets task; theory probes for
the Eq. (1) noise terms; the train/serve CLIs run.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.data import brackets
from repro.models import build_model


def test_hdo_trains_brackets_transformer():
    """Paper Fig 4 (reduced): hybrid population on Dyck classification."""
    from repro.configs.paper_tasks import brackets_transformer

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    toks, labs = brackets.make_dataset(n_samples=512, seq_len=17, seed=0)
    hcfg = HDOConfig(n_agents=4, n_zeroth=2, rv=8, estimator_zo="fwd_grad",
                     gossip="dense", lr=0.05, momentum=0.8, warmup_steps=5,
                     cosine_steps=60, nu=1e-4)
    step = jax.jit(build_hdo_step(model.loss, hcfg))
    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params, hcfg)
    rng = np.random.default_rng(0)
    first = None
    for t in range(60):
        idx = rng.integers(0, 512, size=(4, 16))
        batches = {"tokens": jnp.asarray(toks[idx]), "labels": jnp.asarray(labs[idx])}
        state, m = step(state, batches)
        if first is None:
            first = float(m["loss_mean"])
    last = float(m["loss_mean"])
    assert last < first * 0.8, (first, last)
    assert float(consensus_distance(state.params)) < 1.0


@pytest.mark.slow
def test_eq1_noise_scaling_with_d():
    """Theory probe: ZO estimator second moment scales ~ d (Eq. 1 /
    Lemma 5: E||G||^2 <= ~2(d+4)||grad||^2)."""
    from repro.core import zo_estimate

    def sqnorm_for_dim(d, n=150):
        g = jnp.ones((d,)) / jnp.sqrt(d)  # unit gradient
        loss = lambda p: p["x"] @ g
        tot = 0.0
        for i in range(n):
            _, est = zo_estimate(loss, {"x": jnp.zeros(d)}, jax.random.PRNGKey(i),
                                 kind="fwd_grad", rv=1)
            tot += float((est["x"] ** 2).sum())
        return tot / n

    m8, m64 = sqnorm_for_dim(8), sqnorm_for_dim(64)
    ratio = m64 / m8
    assert 3.0 < ratio < 20.0, (m8, m64)  # ~ (64+2)/(8+2) = 6.6


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return env


def test_train_cli_runs():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen1.5-0.5b",
         "--steps", "3", "--agents", "2", "--zo", "1", "--batch", "2",
         "--seq", "16", "--rv", "1", "--log-every", "1"],
        capture_output=True, text=True, timeout=300, env=_env(), cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    lines = [l for l in proc.stdout.splitlines() if l.startswith("{")]
    assert len(lines) >= 2
    rec = json.loads(lines[-1])
    assert np.isfinite(rec["loss_mean"])


def test_serve_cli_runs():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen1.5-0.5b",
         "--batch", "2", "--prompt-len", "8", "--gen", "8"],
        capture_output=True, text=True, timeout=300, env=_env(), cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "tok/s" in proc.stdout
