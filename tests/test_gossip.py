"""Gossip invariants (load-balancing view of the paper's Lemma 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip


def test_matching_is_involution():
    for seed in range(20):
        for n in (2, 5, 8, 16, 17):
            p = np.asarray(gossip.sample_matching(jax.random.PRNGKey(seed), n))
            assert (p[p] == np.arange(n)).all(), (n, seed)


def test_round_robin_all_pairs_meet():
    n = 8
    sched = gossip.round_robin_schedule(n)
    assert sched.shape == (n - 1, n)
    met = set()
    for r in range(n - 1):
        p = sched[r]
        assert (p[p] == np.arange(n)).all()
        assert (p != np.arange(n)).all()  # perfect matching, no fixed points
        for i in range(n):
            met.add((min(i, p[i]), max(i, p[i])))
    assert len(met) == n * (n - 1) // 2  # tournament: every pair once


def test_mix_pairwise_preserves_mean_and_contracts():
    key = jax.random.PRNGKey(1)
    X = {"w": jax.random.normal(key, (16, 7, 3)), "b": jax.random.normal(key, (16,))}
    partner = gossip.sample_matching(jax.random.PRNGKey(2), 16)
    Y = gossip.mix_pairwise(X, partner)
    for k in X:
        np.testing.assert_allclose(np.asarray(X[k].mean(0)), np.asarray(Y[k].mean(0)), atol=1e-6)

    def gamma(t):
        return sum(float(((v - v.mean(0, keepdims=True)) ** 2).sum()) for v in t.values())

    assert gamma(Y) <= gamma(X) + 1e-6


def test_all_reduce_zeroes_gamma():
    X = {"w": jax.random.normal(jax.random.PRNGKey(3), (8, 5))}
    Y = gossip.mix_all_reduce(X)
    assert float(((Y["w"] - Y["w"].mean(0)) ** 2).sum()) < 1e-10
    np.testing.assert_allclose(np.asarray(Y["w"][0]), np.asarray(X["w"].mean(0)), atol=1e-6)


@pytest.mark.parametrize("mode", ["dense", "rr_static", "all_reduce", "none"])
def test_gossip_step_modes(mode):
    X = {"w": jax.random.normal(jax.random.PRNGKey(4), (8, 5))}
    Y = gossip.gossip_step(X, mode=mode, key=jax.random.PRNGKey(5), step=3, n=8)
    assert Y["w"].shape == X["w"].shape
    np.testing.assert_allclose(np.asarray(Y["w"].mean(0)), np.asarray(X["w"].mean(0)), atol=1e-6)


def test_gossip_jit_traceable():
    @jax.jit
    def f(X, step):
        return gossip.gossip_step(X, mode="dense", key=jax.random.PRNGKey(0), step=step, n=8)

    X = {"w": jnp.ones((8, 4))}
    Y = f(X, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(Y["w"]), 1.0)
