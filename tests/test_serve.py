"""Serving engine tests: the engine-vs-loop parity suite that pins the
continuous-batching engine (repro.serve) to the per-token reference
loop, plus the scheduling invariants, population routing, and the
checkpoint->serve handoff.

Parity runs in float32: the smoke configs default to bfloat16, where
the batched loop (one B=n program) and the engine (vmapped B=1 lanes)
legitimately round differently and near-tie argmaxes flip.  In f32 the
greedy token streams are BIT-IDENTICAL for all four text families.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis
from repro.configs import get_smoke_config
from repro.configs.base import HDOConfig
from repro.core import plane as planelib
from repro.launch.serve import generate
from repro.models import build_model
from repro.models import decode as decodelib
from repro.serve import (
    Engine,
    EngineConfig,
    Request,
    Scheduler,
    load_population,
    population_params,
)

FAMILIES = {
    "dense": "qwen1.5-0.5b",
    "moe": "qwen2-moe-a2.7b",
    "ssm": "mamba2-780m",
    "hybrid": "zamba2-2.7b",
}
PROMPT, GEN, TOTAL, N_REQ = 8, 8, 16, 4

_CACHE = {}


def setup_family(family):
    """(cfg, model, params, prompts, loop_toks, loop_timing) — the
    reference per-token loop run, computed once per family."""
    if family not in _CACHE:
        cfg = dataclasses.replace(get_smoke_config(FAMILIES[family]),
                                  dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, cfg.vocab_size, (N_REQ, PROMPT),
                               dtype=np.int32)
        toks, timing = generate(model, params, jnp.asarray(prompts),
                                TOTAL, GEN)
        _CACHE[family] = (cfg, model, params, prompts, np.asarray(toks),
                          timing)
    return _CACHE[family]


_SOLO_STEP = {}


def solo_decode(family, model, params, prompt, gen):
    """B=1 reference decode with a per-family cached jitted step (so
    varied-gen references don't recompile)."""
    key = (family, id(params))
    if key not in _SOLO_STEP:
        _SOLO_STEP[key] = jax.jit(model.serve_step)
    step = _SOLO_STEP[key]
    plen = len(prompt)
    cache = model.init_cache(1, plen + gen)
    tok = jnp.asarray(prompt[:1], jnp.int32)
    out = [int(tok[0])]
    for t in range(plen + gen - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        tok = jnp.asarray(prompt[t + 1 : t + 2], jnp.int32) \
            if t + 1 < plen else nxt
        out.append(int(tok[0]))
    return np.asarray(out, np.int32)


def run_engine(model, params, prompts, *, gens=None, n_slots=N_REQ,
               chunk=4, cache_seq=TOTAL, max_total=TOTAL, eos_id=None,
               ensemble=False, agents=None, ticks=None, logger=None):
    eng = Engine(model, params,
                 config=EngineConfig(n_slots=n_slots, cache_seq=cache_seq,
                                     max_total=max_total, chunk=chunk,
                                     eos_id=eos_id),
                 ensemble=ensemble)
    sched = Scheduler(eng, logger=logger)
    for i in range(len(prompts)):
        sched.submit(Request(
            request_id=i, prompt=prompts[i],
            max_gen=gens[i] if gens else GEN,
            agent=agents[i] if agents else 0,
            arrival_tick=ticks[i] if ticks else 0))
    return {r.request_id: r for r in sched.run()}


# ---------------------------------------------------------------------------
# engine-vs-loop parity (the acceptance pin)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", list(FAMILIES))
def test_engine_matches_loop(family):
    """Scan-decode greedy streams are bit-identical to the per-token
    loop for every text family."""
    cfg, model, params, prompts, loop_toks, _ = setup_family(family)
    res = run_engine(model, params, prompts)
    assert set(res) == set(range(N_REQ))
    for i in range(N_REQ):
        np.testing.assert_array_equal(res[i].tokens, loop_toks[i])
        assert res[i].finish_reason == "budget"
        assert res[i].prompt_tokens == PROMPT
        assert res[i].gen_tokens == GEN


def test_chunk_size_invariance():
    """Token streams are independent of the scan chunk length (chunk=1
    is token-granular scheduling; chunk=5 straddles the prefill/decode
    boundary mid-chunk)."""
    cfg, model, params, prompts, loop_toks, _ = setup_family("dense")
    for chunk in (1, 5):
        res = run_engine(model, params, prompts, chunk=chunk)
        for i in range(N_REQ):
            np.testing.assert_array_equal(res[i].tokens, loop_toks[i])


def test_slot_isolation_under_churn():
    """n_slots < n_requests forces slot reuse: freed slots are re-zeroed
    on admission, so late requests decode bit-identically to the loop
    (recurrent SSM state especially must not leak across requests)."""
    for family in ("dense", "ssm"):
        cfg, model, params, prompts, loop_toks, _ = setup_family(family)
        res = run_engine(model, params, prompts, n_slots=2, chunk=2)
        assert set(res) == set(range(N_REQ))
        for i in range(N_REQ):
            np.testing.assert_array_equal(res[i].tokens, loop_toks[i])


# ---------------------------------------------------------------------------
# continuous-batching invariants
# ---------------------------------------------------------------------------


def test_every_request_completes_exactly_once():
    """Varied generation budgets — evictions at different ticks — and
    every request still completes exactly once, with its own prompt's
    stream (request_id <-> output pairing)."""
    cfg, model, params, _, _, _ = setup_family("dense")
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (6, PROMPT), dtype=np.int32)
    gens = [3, 8, 5, 8, 2, 6]
    res = run_engine(model, params, prompts, gens=gens, n_slots=2, chunk=2,
                     cache_seq=TOTAL, max_total=TOTAL)
    assert sorted(res) == list(range(6))
    for i in range(6):
        ref = solo_decode("dense", model, params, prompts[i], gens[i])
        np.testing.assert_array_equal(res[i].tokens, ref)
        assert res[i].gen_tokens == gens[i]
        assert res[i].finish_reason == "budget"


def test_deterministic_under_seeded_arrivals():
    """Tick-scheduled arrivals are wall-clock free: two runs with the
    same seeded arrival schedule produce identical streams in identical
    completion order."""
    cfg, model, params, _, _, _ = setup_family("dense")
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, (6, PROMPT), dtype=np.int32)
    ticks = sorted(int(t) for t in rng.integers(0, 20, 6))

    def one_run():
        res = run_engine(model, params, prompts, n_slots=2, chunk=2,
                         ticks=ticks)
        order = [r for r in res]
        return order, {i: res[i].tokens for i in res}

    o1, t1 = one_run()
    o2, t2 = one_run()
    assert o1 == o2
    for i in t1:
        np.testing.assert_array_equal(t1[i], t2[i])


def test_eos_evicts_and_frees_slot():
    """A generated eos_id terminates the request early (token-granular
    eviction inside the chunk) and frees its slot for the queue: with
    n_slots=1 the second request can only complete through that freed
    slot, and still matches the loop."""
    cfg, model, params, prompts, loop_toks, _ = setup_family("dense")
    gen0, gen1 = loop_toks[0][PROMPT:], loop_toks[1][PROMPT:]
    # an eos value request 0 generates early but request 1 never does
    eos = next(int(t) for t in gen0[:4] if t not in gen1)
    cut = int(np.nonzero(gen0 == eos)[0][0])  # 0-based index in gen region
    res = run_engine(model, params, prompts[:2], n_slots=1, chunk=2,
                     eos_id=eos)
    assert res[0].finish_reason == "eos"
    assert res[0].gen_tokens == cut + 1  # stream includes the eos token
    np.testing.assert_array_equal(res[0].tokens,
                                  loop_toks[0][: PROMPT + cut + 1])
    assert res[1].finish_reason == "budget"
    np.testing.assert_array_equal(res[1].tokens, loop_toks[1])


def test_request_validation():
    cfg, model, params, prompts, _, _ = setup_family("dense")
    eng = Engine(model, params,
                 config=EngineConfig(n_slots=2, cache_seq=TOTAL,
                                     max_total=TOTAL, chunk=2))
    with pytest.raises(ValueError, match="max_total"):
        eng.validate(12, 8)
    with pytest.raises(ValueError, match="cache"):
        Engine(model, params,
               config=EngineConfig(n_slots=2, cache_seq=8, max_total=32,
                                   chunk=2)).validate(8, 8)
    with pytest.raises(ValueError, match="agent"):
        eng.validate(4, 4, agent=1)
    with pytest.raises(ValueError, match="prompt_len"):
        eng.validate(0, 4)
    sched = Scheduler(eng)
    sched.submit(Request(request_id=0, prompt=prompts[0], max_gen=2))
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(Request(request_id=0, prompt=prompts[1], max_gen=2))
    for bad in (dict(n_slots=0), dict(chunk=0), dict(cache_seq=0)):
        with pytest.raises(ValueError):
            EngineConfig(**bad)


def test_engine_rejects_vlm():
    cfg = dataclasses.replace(get_smoke_config("pixtral-12b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="text decoders"):
        Engine(model, params, config=EngineConfig(n_slots=1, cache_seq=8,
                                                  max_total=8, chunk=1))


# ---------------------------------------------------------------------------
# ring-buffer KV path
# ---------------------------------------------------------------------------


def _ring_cfg():
    return dataclasses.replace(
        get_smoke_config("qwen1.5-0.5b"), dtype="float32",
        sliding_window=8, decode_window_slice=True, local_global_period=0)


def test_ring_slot_math_property():
    """Hypothesis pin of the ring-buffer slot math (models/decode.py):
    p_s = pos - ((pos - s) mod window).  For every (window, pos) the
    written slots hold exactly the last min(window, pos+1) absolute
    positions, each in its own slot, none from the future."""
    hyp = require_hypothesis()
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=200)
    @given(st.integers(1, 64), st.integers(0, 10_000))
    def check(window, pos):
        s = np.arange(window)
        p_s = pos - ((pos - s) % window)
        assert (p_s <= pos).all()            # never the future
        assert (pos - p_s < window).all()    # never older than the window
        assert ((p_s % window) == s).all()   # each position in its slot
        held = set(p_s[p_s >= 0].tolist())
        assert held == set(range(max(0, pos - window + 1), pos + 1))

    check()


def test_ring_cache_engine_parity():
    """Ring-eligible config: the slot-pool cache stores only the window
    (positions unbounded by cache_seq) and the engine still matches the
    loop past the window boundary."""
    cfg = _ring_cfg()
    total, gen = 24, 16
    assert decodelib.use_ring(cfg, total)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, cfg.vocab_size, (2, PROMPT), dtype=np.int32)
    toks, _ = generate(model, params, jnp.asarray(prompts), total, gen)
    eng = Engine(model, params,
                 config=EngineConfig(n_slots=2, cache_seq=total,
                                     max_total=total, chunk=4))
    # ring KV: requests longer than the stored window are admissible
    eng.validate(PROMPT, gen)
    assert eng._st["cache"]["k"].shape[3] == cfg.sliding_window
    sched = Scheduler(eng)
    for i in range(2):
        sched.submit(Request(request_id=i, prompt=prompts[i], max_gen=gen))
    res = {r.request_id: r for r in sched.run()}
    for i in range(2):
        np.testing.assert_array_equal(res[i].tokens, np.asarray(toks[i]))


# ---------------------------------------------------------------------------
# population-aware serving
# ---------------------------------------------------------------------------


def _stacked_pair(model):
    p0 = model.init(jax.random.PRNGKey(0))
    p1 = model.init(jax.random.PRNGKey(7))
    return p0, p1, jax.tree.map(lambda a, b: jnp.stack([a, b]), p0, p1)


def test_ensemble_routing_matches_solo():
    """Requests routed to different cohort members in the same batch
    each produce the member's own solo stream, bit-exact."""
    cfg, model, _, prompts, loop_toks, _ = setup_family("dense")
    p0, p1, stacked = _stacked_pair(model)
    toks1, _ = generate(model, p1, jnp.asarray(prompts), TOTAL, GEN)
    agents = [0, 1, 0, 1]
    res = run_engine(model, stacked, prompts, ensemble=True, agents=agents)
    for i, a in enumerate(agents):
        ref = loop_toks[i] if a == 0 else np.asarray(toks1[i])
        np.testing.assert_array_equal(res[i].tokens, ref)
        assert res[i].agent == a


def test_ensemble_vs_mean_differ():
    """Sanity: serving the population mean is a different model than
    serving a member (the two modes are not silently aliased)."""
    cfg, model, _, prompts, _, _ = setup_family("dense")
    _, _, stacked = _stacked_pair(model)
    mean = population_params(stacked, mode="mean")
    res_m = run_engine(model, mean, prompts[:1], n_slots=1)
    res_e = run_engine(model, stacked, prompts[:1], n_slots=1,
                       ensemble=True, agents=[1])
    assert not np.array_equal(res_m[0].tokens, res_e[0].tokens)


def test_population_mean_layout_consistency():
    """mean(tree layout) == mean(plane layout), bit-exact — the plane
    packs the same numbers contiguously, and the mean commutes."""
    cfg, model, params, _, _, _ = setup_family("dense")
    p0, p1, stacked = _stacked_pair(model)
    man = planelib.build_manifest(p0)
    planes = jnp.stack([planelib.pack(man, p0), planelib.pack(man, p1)])
    m_tree = population_params(stacked, mode="mean")
    m_plane = population_params(planes, mode="mean",
                                param_layout="plane", template=p0)
    for a, b in zip(jax.tree.leaves(m_tree), jax.tree.leaves(m_plane)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    e_plane = population_params(planes, mode="ensemble",
                                param_layout="plane", template=p0)
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(e_plane)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="template"):
        population_params(planes, mode="mean", param_layout="plane")
    with pytest.raises(ValueError, match="population"):
        population_params(stacked, mode="median")


@pytest.mark.parametrize("layout", ["tree", "plane"])
def test_checkpoint_serve_handoff(layout, tmp_path):
    """Train 2 rounds, checkpoint, restore through load_population's
    meta guards, serve the mean: logits match the in-memory mean."""
    from repro import checkpoint
    from repro.core import build_hdo_step, init_state

    cfg, model, params, prompts, _, _ = setup_family("dense")
    hcfg = HDOConfig(n_agents=2, n_zeroth=1, rv=2, estimator_zo="fwd_grad",
                     gossip="dense", lr=0.01, momentum=0.9, warmup_steps=1,
                     cosine_steps=4, nu=1e-4, param_layout=layout)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    step = jax.jit(build_hdo_step(model.loss, hcfg, param_dim=n_params,
                                  params_template=params))
    state = init_state(params, hcfg)
    rng = np.random.default_rng(5)
    for _ in range(2):
        toks = rng.integers(0, cfg.vocab_size, (2, 2, 17))
        state, _m = step(state, {"tokens": jnp.asarray(toks[..., :-1]),
                                 "labels": jnp.asarray(toks[..., 1:])})
    man_hash = planelib.manifest_hash(planelib.build_manifest(params))
    path = str(tmp_path / "ckpt")
    checkpoint.save_state(path, state, meta={
        "arch": cfg.name, "hdo": dataclasses.asdict(hcfg),
        "param_layout": layout, "manifest_hash": man_hash})

    restored, hcfg2 = load_population(path, model)
    assert hcfg2.param_layout == layout and hcfg2.n_agents == 2
    mean_r = population_params(restored.params, mode="mean",
                               param_layout=layout, template=params)
    mean_m = population_params(state.params, mode="mean",
                               param_layout=layout, template=params)
    step1 = jax.jit(model.serve_step)
    tok = jnp.asarray(prompts[:1, 0], jnp.int32)
    lr_, _ = step1(mean_r, model.init_cache(1, 4), tok, jnp.int32(0))
    lm_, _ = step1(mean_m, model.init_cache(1, 4), tok, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(lr_), np.asarray(lm_))


# ---------------------------------------------------------------------------
# metrics + regressions
# ---------------------------------------------------------------------------


def test_serve_metrics_artifact(tmp_path):
    """A scheduler run writes a validator-clean artifact: manifest
    first, per-chunk engine metrics, one serve_request per request."""
    from repro.obs import MetricsLogger, make_sink, run_manifest, validate_jsonl

    cfg, model, params, prompts, _, _ = setup_family("dense")
    path = str(tmp_path / "serve.jsonl")
    logger = MetricsLogger([make_sink(path)])
    logger.start_run(run_manifest({"arch": cfg.name}, arch=cfg.name))
    run_engine(model, params, prompts[:3], n_slots=2, chunk=2,
               logger=logger)
    logger.finish({"completed": 3})
    assert validate_jsonl(path) == []
    recs = [json.loads(l) for l in open(path)]
    assert recs[0]["record"] == "manifest"
    reqs = [r for r in recs if r["record"] == "serve_request"]
    assert sorted(r["request_id"] for r in reqs) == [0, 1, 2]
    for r in reqs:
        assert r["agent_id"] == -1  # mean serving: no cohort routing
        assert r["gen_tokens"] == GEN
        assert r["decode_ms"] >= 0 and r["prefill_ms"] >= 0
    chunks = [r for r in recs if r["record"] == "metrics"]
    assert chunks, "per-chunk engine metrics missing"
    assert {"queue_depth", "slots_active", "slots_free", "prefill_tokens",
            "decode_tokens", "chunk_ms"} <= set(chunks[0])
    # token conservation: chunk streams account for every emitted token
    emitted = sum(r["prefill_tokens"] + r["decode_tokens"] for r in chunks)
    assert emitted == 3 * (TOTAL - 1)


@pytest.mark.parametrize("family", list(FAMILIES))
def test_cache_max_seq_per_family(family):
    """serve_step's cache capacity is derived per family — the old
    '"k" in cache' chain returned 0 for pure-SSM caches and leaned on
    dict key order for hybrids."""
    cfg, model, _, _, _, _ = setup_family(family)
    cache = model.init_cache(2, TOTAL)
    want = 0 if family == "ssm" else TOTAL
    assert decodelib.cache_max_seq(cfg, cache) == want
    # key order must not matter (regression: hybrid caches carry both
    # "mamba" and "k" and the old chain took whichever it hit first)
    reordered = dict(reversed(list(cache.items())))
    assert decodelib.cache_max_seq(cfg, reordered) == want


def test_loop_timing_split():
    """generate() reports prefill and decode separately (the old
    decode_s lumped teacher-forced prompt steps into decode)."""
    _, _, _, _, _, timing = setup_family("dense")
    assert set(timing) == {"compile_s", "prefill_s", "decode_s"}
    assert timing["prefill_s"] > 0 and timing["decode_s"] > 0
