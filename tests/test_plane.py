"""The persistent flat parameter plane (``param_layout="plane"``,
core/plane.py): manifest invariants + round-trip across every
registered architecture, the plane kernels' compact-counter-stream
contract, the fused adamw apply, the small-leaf regime where the plane
layout earns its keep (zero jnp-fallback leaves by construction), the
plane-vs-tree single-step equivalence matrix, and the checkpoint
manifest/layout guards.

Comparison discipline (mirrors tests/test_kernels.py): kernel vs
kernel on the same stream is asserted BIT-EXACT; kernel vs jnp oracle
is allclose only (XLA may fuse multiply-add chains the kernel
associates differently).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, init_state
from repro.core import plane as planelib
from repro.kernels import ops, ref
from repro.kernels.zo_combine import BLOCK
from repro.models import build_model

# ---------------------------------------------------------------------------
# the small-leaf regime model: one leaf above BLOCK (the embedding) and
# several far below it (biases, norms) — the shapes where the tree
# layout pays per-leaf dispatch and the jnp fallback
# ---------------------------------------------------------------------------


def small_leaf_params():
    k = jax.random.PRNGKey(7)
    ks = jax.random.split(k, 3)
    return {
        "emb": jax.random.normal(ks[0], (96, 90)) * 0.1,   # 8640 > BLOCK
        "blk": {
            "w": jax.random.normal(ks[1], (40, 40)) * 0.1,  # 1600 < BLOCK
            "b": jnp.zeros((40,)),
            "ln": jnp.ones((40,)),
        },
        "head": jax.random.normal(ks[2], (90,)) * 0.1,
    }


PARAMS = small_leaf_params()
MAN = planelib.build_manifest(PARAMS)
D = MAN.size
W_TRUE = jax.random.normal(jax.random.PRNGKey(42), (D,)) * 0.1


def loss_fn(params, batch):
    w = jnp.concatenate([l.reshape(-1) for l in jax.tree_util.tree_leaves(params)])
    return jnp.mean((batch["X"] @ w - batch["y"]) ** 2)


def make_batches(key, n_agents, bsz=4):
    X = jax.random.normal(key, (n_agents, bsz, D)) / np.sqrt(D)
    return {"X": X, "y": X @ W_TRUE}


# ---------------------------------------------------------------------------
# manifest: invariants + pack/unpack round-trip for every architecture
# ---------------------------------------------------------------------------


def _counter_filled(sds_tree):
    """Deterministic leaves whose values survive any float cast exactly
    (arange % 127 is exact even in bfloat16)."""
    leaves, treedef = jax.tree_util.tree_flatten(sds_tree)
    out = [
        (jnp.arange(int(np.prod(l.shape) or 1)) % 127)
        .astype(l.dtype).reshape(l.shape)
        for l in leaves
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_manifest_round_trip_every_architecture(arch):
    """build_manifest works on eval_shape structs of every registered
    model, the layout invariants hold, the hash is stable, and
    pack -> unpack restores every leaf exactly."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    man = planelib.build_manifest(sds)

    offset = 0
    for spec in man.leaves:
        assert spec.offset == offset
        assert spec.offset % BLOCK == 0
        assert spec.extent % BLOCK == 0
        assert spec.size <= spec.extent < spec.size + BLOCK
        offset += spec.extent
    assert man.dim == offset and man.dim % BLOCK == 0
    assert man.size == sum(s.size for s in man.leaves)
    # the fingerprint is a pure function of the layout
    assert planelib.manifest_hash(man) == planelib.manifest_hash(
        planelib.build_manifest(sds))

    tree = _counter_filled(sds)
    plane = planelib.pack(man, tree)
    assert plane.shape == (man.dim,)
    back = planelib.unpack(man, plane)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_hash_sensitive_to_layout():
    p2 = {**PARAMS, "head": jnp.zeros((91,))}
    assert (planelib.manifest_hash(planelib.build_manifest(p2))
            != planelib.manifest_hash(MAN))


def test_manifest_rejects_non_float_leaves():
    with pytest.raises(ValueError, match="floating-point"):
        planelib.build_manifest({"ids": jnp.zeros((8,), jnp.int32)})


def test_unpack_stacked_matches_per_row():
    plane = planelib.pack(MAN, PARAMS)
    stacked = jnp.stack([plane, 2.0 * plane])
    tree = planelib.unpack_stacked(MAN, stacked)
    row0 = planelib.unpack(MAN, plane)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(row0)):
        np.testing.assert_array_equal(np.asarray(a)[0], np.asarray(b))


def test_small_leaf_model_is_the_fallback_regime():
    """The test model really exercises the regime the plane removes:
    the tree layout has a non-empty sub-BLOCK fallback set, the plane
    has none and O(#agents) dispatches per phase."""
    counts = planelib.dispatch_counts(MAN, n_agents=4)
    assert counts["tree"]["update_fallback_leaves"] > 0
    assert counts["tree"]["mix_kernel_calls"] == 4 * counts["n_leaves"]
    assert counts["plane"] == {
        "update_kernel_calls": 4,
        "mix_kernel_calls": 4,
        "update_fallback_leaves": 0,
    }
    assert all(s.extent % BLOCK == 0 for s in MAN.leaves)


# ---------------------------------------------------------------------------
# plane kernels: compact counter stream + masked pads
# ---------------------------------------------------------------------------


DELTA, NVALID = (jnp.asarray(t) for t in planelib.rng_tables(MAN))
SEED = 1234


def _compact_of(plane_vec):
    """Gather the compact lanes of a plane vector, in leaf order."""
    return np.concatenate([
        np.asarray(plane_vec)[s.offset:s.offset + s.size] for s in MAN.leaves
    ])


def _pad_mask():
    m = np.zeros((MAN.dim,), bool)
    for s in MAN.leaves:
        m[s.offset + s.size:s.offset + s.extent] = True
    return m


def test_zo_combine_plane_matches_tree_kernel_bitwise():
    coeffs = jax.random.normal(jax.random.PRNGKey(0), (4,))
    g_plane = ops.zo_combine_plane(coeffs, SEED, DELTA, NVALID, MAN.dim)
    g_tree = ops.zo_combine(coeffs, SEED, MAN.size)
    np.testing.assert_array_equal(_compact_of(g_plane), np.asarray(g_tree))
    assert not np.any(np.asarray(g_plane)[_pad_mask()])
    # and allclose to the jnp oracle (FMA association may differ)
    g_ref = jax.jit(lambda c: ref.zo_combine_plane_ref(
        c, SEED, DELTA, NVALID, MAN.dim, BLOCK))(coeffs)
    np.testing.assert_allclose(np.asarray(g_plane), np.asarray(g_ref),
                               atol=1e-5, rtol=1e-6)


def test_zo_tangent_plane_matches_tree_kernel_bitwise():
    u_plane = ops.zo_tangent_plane(SEED, 3, DELTA, NVALID, MAN.dim)
    u_tree = ops.zo_tangent(SEED, 3, MAN.size)
    np.testing.assert_array_equal(_compact_of(u_plane), np.asarray(u_tree))
    assert not np.any(np.asarray(u_plane)[_pad_mask()])
    # tangent is pure generation (no FMA chain): oracle is bit-exact too
    u_ref = jax.jit(lambda: ref.zo_tangent_plane_ref(
        SEED, 3, DELTA, NVALID, MAN.dim, BLOCK))()
    np.testing.assert_array_equal(np.asarray(u_plane), np.asarray(u_ref))


def test_zo_perturb_plane_matches_tree_kernel_bitwise():
    x_plane = planelib.pack(MAN, PARAMS)
    x_tree = jnp.asarray(_compact_of(x_plane))
    nu = 1e-3
    c_plane = ops.zo_perturb_plane(x_plane, SEED, 2, nu, DELTA, NVALID)
    c_tree = ops.zo_perturb(x_tree, SEED, 2, nu)
    np.testing.assert_array_equal(_compact_of(c_plane), np.asarray(c_tree))
    # pad lanes pass x through untouched (here: the zero pads)
    np.testing.assert_array_equal(np.asarray(c_plane)[_pad_mask()],
                                  np.asarray(x_plane)[_pad_mask()])
    c_ref = jax.jit(lambda v: ref.zo_perturb_plane_ref(
        v, SEED, 2, nu, DELTA, NVALID, BLOCK))(x_plane)
    np.testing.assert_allclose(np.asarray(c_plane), np.asarray(c_ref),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# fused adamw apply
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mdt", [jnp.float32, jnp.bfloat16])
def test_adamw_apply_kernel_equals_oracle(mdt):
    """Dyadic constants => the kernel and the oracle compute the same
    float chain exactly (the rounded mu drives the update in both)."""
    d = BLOCK + 100
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    p = jax.random.normal(ks[0], (d,))
    g = jax.random.normal(ks[1], (d,))
    mu = (jax.random.normal(ks[2], (d,)) * 0.1).astype(mdt)
    nu = jnp.abs(jax.random.normal(ks[3], (d,))) * 0.01
    lr, b1, b2, eps, wd, count = 0.25, 0.5, 0.75, 0.0078125, 0.125, 3
    outs_k = ops.adamw_apply(p, g, mu, nu, lr, b1, b2, eps, wd, count)
    outs_r = jax.jit(ref.adamw_apply_ref)(p, g, mu, nu, lr, b1, b2, eps,
                                          wd, count)
    for a, b, name in zip(outs_k, outs_r, ("p", "mu", "nu")):
        assert a.dtype == b.dtype, name
        np.testing.assert_allclose(
            np.asarray(a, jnp.float32), np.asarray(b, jnp.float32),
            rtol=2e-6, err_msg=name)
    assert outs_k[1].dtype == mdt


def test_adamw_apply_vmaps_per_agent_lr():
    n, d = 3, BLOCK
    p = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    g = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    mu = jnp.zeros((n, d))
    nu = jnp.zeros((n, d))
    lrs = jnp.asarray([0.1, 0.2, 0.4], jnp.float32)
    po, _, _ = jax.vmap(
        lambda pf, gf, mf, vf, lrf: ops.adamw_apply(
            pf, gf, mf, vf, lrf, 0.9, 0.999, 1e-8, 0.0, 1)
    )(p, g, mu, nu, lrs)
    singles = [
        ops.adamw_apply(p[i], g[i], mu[i], nu[i], lrs[i],
                        0.9, 0.999, 1e-8, 0.0, 1)[0]
        for i in range(n)
    ]
    np.testing.assert_array_equal(np.asarray(po), np.stack([np.asarray(s) for s in singles]))


# ---------------------------------------------------------------------------
# plane-vs-tree single-step equivalence (the tentpole contract)
# ---------------------------------------------------------------------------


BASE = dict(n_agents=4, n_zeroth=2, estimator_zo="multi_rv", rv=2,
            nu=1e-3, gossip="dense", warmup_steps=0, use_cosine=False)
# all-equal per-agent tables: goes down the heterogeneous path but must
# collapse to the homogeneous trajectory (the PR-4 contract), so the
# plane/tree comparison covers the het machinery too
ALL_EQUAL = dict(sigmas=(1e-3, 1e-3), rvs=(2, 2), lrs=(0.25,) * 4,
                 estimators_zo=("multi_rv", "multi_rv"))


def _run_layout(cfg, steps=3):
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D,
                                  params_template=PARAMS))
    state = init_state(PARAMS, cfg)
    for t in range(steps):
        state, m = step(state, make_batches(
            jax.random.fold_in(jax.random.PRNGKey(5), t), cfg.n_agents))
    return state, m


def _params_tree(cfg, state):
    if cfg.param_layout == "plane":
        return planelib.unpack_stacked(MAN, state.params)
    return state.params


@pytest.mark.parametrize("zo_impl", ["tree", "fused"])
@pytest.mark.parametrize("dispatch", ["select", "split"])
@pytest.mark.parametrize("het", [False, True], ids=["hom", "all_equal_het"])
def test_plane_step_bit_identical_to_tree_sgd(zo_impl, dispatch, het):
    """The headline contract: with dyadic lr/momentum the plane layout
    replays the tree layout's sgd trajectory BIT FOR BIT — estimate
    (compact counter stream), clip-free update, and mix included —
    for both ZO engines, both grouped dispatches, and the heterogeneous
    all-equal cohort."""
    kw = dict(BASE, lr=0.25, momentum=0.5, zo_impl=zo_impl,
              dispatch=dispatch, **(ALL_EQUAL if het else {}))
    s_tree, m_tree = _run_layout(HDOConfig(param_layout="tree", **kw))
    s_pln, m_pln = _run_layout(HDOConfig(param_layout="plane", **kw))

    pt = _params_tree(HDOConfig(param_layout="plane", **kw), s_pln)
    for a, b in zip(jax.tree_util.tree_leaves(s_tree.params),
                    jax.tree_util.tree_leaves(pt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # momentum plane rows unpack to the tree momentum exactly
    for a, b in zip(jax.tree_util.tree_leaves(s_tree.opt_state),
                    jax.tree_util.tree_leaves(
                        planelib.unpack_stacked(MAN, s_pln.opt_state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert set(m_tree) == set(m_pln)
    np.testing.assert_array_equal(np.asarray(m_tree["loss_mean"]),
                                  np.asarray(m_pln["loss_mean"]))


def test_plane_pads_stay_zero_through_the_step():
    """The pads-are-invariant-zero contract that makes every phase safe
    to run on the padded buffer."""
    cfg = HDOConfig(param_layout="plane", lr=0.25, momentum=0.5, **BASE)
    state, _ = _run_layout(cfg)
    pads = np.asarray(state.params)[:, _pad_mask()]
    np.testing.assert_array_equal(pads, np.zeros_like(pads))
    mpads = np.asarray(state.opt_state)[:, _pad_mask()]
    np.testing.assert_array_equal(mpads, np.zeros_like(mpads))


def test_plane_adamw_allclose_to_tree():
    """adamw goes through the fused plane kernel vs the optim transform
    tree path — same math, different association, so allclose (the sgd
    rule above is the bit-exact surface)."""
    kw = dict(BASE, lr=0.01, momentum=0.9, optimizer="adamw",
              weight_decay=0.01)
    s_tree, _ = _run_layout(HDOConfig(param_layout="tree", **kw))
    s_pln, _ = _run_layout(HDOConfig(param_layout="plane", **kw))
    pt = _params_tree(HDOConfig(param_layout="plane", **kw), s_pln)
    for a, b in zip(jax.tree_util.tree_leaves(s_tree.params),
                    jax.tree_util.tree_leaves(pt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
    assert s_pln.opt_state["count"] == 3


def test_plane_adamw_bf16_first_moment():
    """momentum_dtype reaches the adamw first moment under the plane
    layout (the fused kernel's write-back discipline legitimizes it)."""
    cfg = HDOConfig(param_layout="plane", lr=0.01, momentum=0.9,
                    optimizer="adamw", momentum_dtype="bfloat16", **BASE)
    state, m = _run_layout(cfg)
    assert state.opt_state["mu"].dtype == jnp.bfloat16
    assert state.opt_state["nu"].dtype == jnp.float32
    assert np.isfinite(float(m["loss_mean"]))
    assert bool(jnp.all(jnp.isfinite(state.params)))


# ---------------------------------------------------------------------------
# checkpoint: the manifest/layout guards + plane state round-trip
# ---------------------------------------------------------------------------


def test_checkpoint_meta_guards(tmp_path):
    cfg = HDOConfig(param_layout="plane", lr=0.25, momentum=0.5, **BASE)
    state = init_state(PARAMS, cfg)
    h = planelib.manifest_hash(MAN)
    path = str(tmp_path / "ckpt")
    checkpoint.save_state(path, state,
                          meta={"param_layout": "plane", "manifest_hash": h})

    meta = checkpoint.read_meta(path)
    assert meta["param_layout"] == "plane" and meta["manifest_hash"] == h
    # matching run: no raise; layout drift and manifest drift: loud
    checkpoint.check_meta_compat(meta, param_layout="plane", manifest_hash=h)
    with pytest.raises(ValueError, match="param_layout"):
        checkpoint.check_meta_compat(meta, param_layout="tree")
    with pytest.raises(ValueError, match="manifest"):
        checkpoint.check_meta_compat(meta, param_layout="plane",
                                     manifest_hash="deadbeefdeadbeef")
    # checkpoints written before the guard keys existed stay accepted
    checkpoint.check_meta_compat({}, param_layout="plane", manifest_hash=h)

    # and the plane state itself round-trips exactly
    restored, _ = checkpoint.restore_state(path, init_state(PARAMS, cfg))
    np.testing.assert_array_equal(np.asarray(restored.params),
                                  np.asarray(state.params))
