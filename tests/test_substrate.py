"""Optimizer / checkpoint / sharding-rule tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import checkpoint, compat, optim
from repro.configs import get_config, get_mesh_config
from repro.models import build_model
from repro import sharding as shardlib


# ---------------- optim ----------------


def test_sgd_momentum_matches_closed_form():
    opt = optim.sgd(momentum=0.5)
    p = {"w": jnp.asarray([1.0, 2.0])}
    st = opt.init(p)
    g = {"w": jnp.asarray([1.0, 1.0])}
    u1, st = opt.update(g, st, p)
    u2, st = opt.update(g, st, p)
    np.testing.assert_allclose(np.asarray(u1["w"]), 0.5)
    np.testing.assert_allclose(np.asarray(u2["w"]), 0.75)


def test_adamw_direction():
    opt = optim.adamw()
    p = {"w": jnp.zeros(3)}
    st = opt.init(p)
    g = {"w": jnp.asarray([1.0, -1.0, 0.0])}
    u, st = opt.update(g, st, p)
    assert float(u["w"][0]) > 0 and float(u["w"][1]) < 0


def test_clip_by_global_norm():
    t = {"a": jnp.full((4,), 10.0)}
    c = optim.clip_by_global_norm(t, 1.0)
    assert float(optim.global_norm(c)) <= 1.0 + 1e-5


def test_apply_updates_dtype_preserved():
    p = {"w": jnp.ones(3, jnp.bfloat16)}
    out = optim.apply_updates(p, {"w": jnp.ones(3)}, 0.5)
    assert out["w"].dtype == jnp.bfloat16


# ---------------- checkpoint ----------------


def test_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.int32(7)},
    }
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        checkpoint.save(path, tree, step=42, meta={"arch": "t"})
        back, step, meta = checkpoint.restore(path, tree)
        assert step == 42 and meta["arch"] == "t"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_structure_mismatch_raises():
    tree = {"a": jnp.zeros(3)}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        checkpoint.save(path, tree)
        with pytest.raises(ValueError):
            checkpoint.restore(path, {"zzz": jnp.zeros(3)})


# ---------------- sharding rules ----------------


def _abstract_mesh(shape, names):
    # constructor signature moved across JAX releases; the compat shim
    # owns the dispatch so these tests survive future changes too
    return compat.abstract_mesh(shape, names)


def test_param_rules_production_mesh():
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("gemma2-9b")
    mcfg = get_mesh_config("gemma2-9b")
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shardlib.params_pspecs(sds, mcfg, mesh, population=False)
    # embed (V, d): vocab over model
    assert specs["embed"] == P("model", None)
    # attention wq (L, d, nq*hd): last dim over model
    assert specs["blocks"]["attn"]["wq"] == P(None, None, "model")
    assert specs["blocks"]["attn"]["wo"] == P(None, "model", None)
    assert specs["blocks"]["mlp"]["wi"] == P(None, None, "model")
    assert specs["blocks"]["ln1"] == P(None, None)


def test_param_rules_moe_expert_parallel():
    mesh = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    cfg = get_config("llama4-maverick-400b-a17b")
    mcfg = get_mesh_config("llama4-maverick-400b-a17b")
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # population=True expects the stacked (n_agents, ...) state tree
    sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((2,) + s.shape, s.dtype), sds
    )
    specs = shardlib.params_pspecs(sds, mcfg, mesh, population=True)
    # routed experts (A, L, E, d, ff): population, layer, expert->data, ff->model
    assert specs["blocks_moe"]["moe"]["wi"] == P("pod", None, "data", None, "model")
    assert specs["blocks_moe"]["moe"]["wo"] == P("pod", None, "data", "model", None)
    # shared expert is plain 2-D after pop+layer dims
    assert specs["blocks_moe"]["moe"]["shared"]["wi"] == P("pod", None, None, "model")


def test_param_rules_divisibility_fallback():
    """Dims not divisible by the axis size replicate instead of erroring."""
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("yi-9b")  # kv heads = 4 < 16
    mcfg = get_mesh_config("yi-9b")
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shardlib.params_pspecs(sds, mcfg, mesh, population=False)
    # wk output dim = 4 * 128 = 512, divisible by 16 -> sharded
    assert specs["blocks"]["attn"]["wk"] == P(None, None, "model")
    # vocab 64000 / 16 = 4000 -> sharded
    assert specs["embed"] == P("model", None)


def test_cache_rules_long_context_shards_sequence():
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    mcfg = get_mesh_config("gemma2-9b")
    cfg = get_config("gemma2-9b")
    from repro.models import decode as _decode

    cache = jax.eval_shape(lambda: _decode.init_cache(cfg, 1, 524288))
    specs = shardlib.cache_pspecs(cache, mcfg, mesh)
    assert specs["k"][2] == "data"  # B=1 -> shard the sequence dim
    cache_b = jax.eval_shape(lambda: _decode.init_cache(cfg, 128, 32768))
    specs_b = shardlib.cache_pspecs(cache_b, mcfg, mesh)
    assert specs_b["k"][1] == "data"  # B=128 -> shard batch
