"""Observability layer (repro.obs): schema-drift gate across the step
variants, JSONL artifact round-trip + manifest integrity, the fenced
per-phase decomposition's bit-identity honesty contract, the
logger-off/extended-metrics-off no-op guarantee, wire accounting, and
the generated docs table's --write/--check CLI.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, init_state
from repro.core.hdo import HDOState
from repro.obs import metrics as metricslib
from repro.obs import timing as timinglib
from repro.obs import trace as tracelib
from repro.obs.metrics import (
    SCHEMA_VERSION,
    JSONLSink,
    MetricsLogger,
    run_manifest,
    spec_for,
    undeclared,
    validate_jsonl,
)

D = 16
W_TRUE = jax.random.normal(jax.random.PRNGKey(42), (D,))


def loss_fn(params, batch):
    return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)


def make_batches(key, n_agents, bsz=4):
    X = jax.random.normal(key, (n_agents, bsz, D))
    return {"X": X, "y": X @ W_TRUE}


BASE = dict(lr=0.05, momentum=0.0, warmup_steps=0, use_cosine=False,
            nu=1e-3, rv=1)


def _params():
    return {"w": jnp.zeros((D,))}


def _one_step(cfg, *, extended=True, steps=1):
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D,
                                  params_template=_params(),
                                  extended_metrics=extended))
    state = init_state(_params(), cfg)
    mets = None
    for t in range(steps):
        state, mets = step(state, make_batches(
            jax.random.fold_in(jax.random.PRNGKey(9), t), cfg.n_agents))
    return state, mets


# ---------------------------------------------------------------------------
# schema registry + drift gate
# ---------------------------------------------------------------------------


def test_spec_lookup_exact_and_pattern():
    assert spec_for("loss_mean").phase == "estimate"
    assert spec_for("grad_var_zo_multi_rv").key == "grad_var_zo_*"
    assert spec_for("phase_compile_ms_mix").phase == "system"
    assert spec_for("definitely_not_declared") is None
    assert undeclared(["loss_mean", "nope", "lr"]) == ["nope"]


# one config per axis value (dispatch x zo_impl x param_layout x
# compression) plus the heterogeneous / fault / staleness key families —
# every metric key build_hdo_step can emit must be declared in REGISTRY
DRIFT_CFGS = [
    ("select_tree", dict(n_agents=4, n_zeroth=2, gossip="dense",
                         dispatch="select", **BASE)),
    ("split_fused", dict(n_agents=4, n_zeroth=2, gossip="dense",
                         dispatch="split", zo_impl="fused", **BASE)),
    ("plane_adamw", dict(n_agents=4, n_zeroth=2, gossip="dense",
                         param_layout="plane", optimizer="adamw", **BASE)),
    ("graph_ring", dict(n_agents=4, n_zeroth=2, gossip="graph",
                        topology="ring", **BASE)),
    ("graph_topk_stale_faults",
     dict(n_agents=4, n_zeroth=2, gossip="graph", topology="ring",
          compression="topk", compress_k=4, staleness=1,
          fault_drop_rate=0.2, fault_straggler_rate=0.2,
          fault_byzantine_rate=0.2, **BASE)),
    ("graph_qsgd_plane",
     dict(n_agents=4, n_zeroth=2, gossip="graph", topology="ring",
          compression="qsgd", compress_bits=4, param_layout="plane", **BASE)),
    ("het_mixed_estimators",
     dict(n_agents=4, n_zeroth=2, gossip="dense",
          sigmas=(1e-3, 1e-2), estimators_zo=("multi_rv", "fwd_grad"),
          lrs=(0.05, 0.04, 0.05, 0.04), **BASE)),
]


@pytest.mark.parametrize("name,kw", DRIFT_CFGS, ids=[n for n, _ in DRIFT_CFGS])
def test_step_metrics_all_declared(name, kw):
    """The runtime half of the drift gate: every key the step emits
    (extended metrics on) is declared in the registry."""
    _, mets = _one_step(HDOConfig(**kw))
    bad = undeclared(mets.keys())
    assert not bad, f"{name}: undeclared metric keys {bad}"
    # and the coercion layer accepts each value under its declared type
    logger = MetricsLogger([_ListSink()])
    logger.log_round(0, mets)


class _ListSink:
    def __init__(self):
        self.records = []

    def write(self, record):
        self.records.append(record)

    def close(self):
        self.closed = True


def test_extended_metrics_do_not_change_the_state():
    """extended_metrics is observe-only: the returned state is
    bit-identical with it on or off (the logger only ever reads)."""
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="graph", topology="ring",
                    compression="topk", compress_k=4, momentum=0.9,
                    **{k: v for k, v in BASE.items() if k != "momentum"})
    s_off, m_off = _one_step(cfg, extended=False, steps=3)
    s_on, m_on = _one_step(cfg, extended=True, steps=3)
    for a, b in zip(jax.tree.leaves(s_off), jax.tree.leaves(s_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # extended adds keys, never changes shared ones
    for k in m_off:
        np.testing.assert_allclose(np.asarray(m_off[k]), np.asarray(m_on[k]),
                                   rtol=0, atol=0)
    assert {"loss_agent", "consensus_gamma", "consensus_agent",
            "gossip_wire_bytes"} <= set(m_on)


def test_extended_wire_and_fault_metrics_values():
    """gossip_wire_bytes = broadcasting agents x bytes_on_wire; the
    fault counters match the replayable schedule."""
    from repro.topology.compress import make_compressor
    from repro.topology.faults import FaultSpec, fault_masks

    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="graph", topology="ring",
                    compression="topk", compress_k=4,
                    fault_drop_rate=0.3, fault_seed=5, **BASE)
    _, mets = _one_step(cfg)
    comp = make_compressor(cfg)
    per_agent = comp.bytes_on_wire(D)
    masks = fault_masks(FaultSpec.from_config(cfg), 0, cfg.n_agents)
    alive = np.asarray(masks["alive"])
    n_bcast = int(alive.sum())  # staleness=0: everyone alive broadcasts
    assert float(mets["gossip_wire_bytes"]) == pytest.approx(
        n_bcast * per_agent)
    assert float(mets["fault_drop_count"]) == pytest.approx(
        cfg.n_agents - alive.sum())


# ---------------------------------------------------------------------------
# logger + sinks + artifact round-trip
# ---------------------------------------------------------------------------


def test_logger_strict_rejects_undeclared_keys():
    logger = MetricsLogger([_ListSink()])
    with pytest.raises(KeyError, match="undeclared"):
        logger.log_round(0, {"loss_mean": 1.0, "made_up_key": 2.0})
    # strict=False lets exploratory keys through
    MetricsLogger([_ListSink()], strict=False).log_round(
        0, {"made_up_key": 2.0})


def test_logger_without_sinks_is_inert():
    logger = MetricsLogger([])
    assert not logger.enabled
    logger.start_run({"record": "manifest"})
    logger.log_round(0, {"bad key that would raise": 1.0})  # no-op, no check
    logger.finish({"x": 1})


def test_wire_mib_accumulates_across_rounds():
    sink = _ListSink()
    logger = MetricsLogger([sink])
    logger.log_round(0, {"gossip_wire_bytes": float(1 << 20)})
    logger.log_round(1, {"gossip_wire_bytes": float(1 << 20)})
    totals = [r["wire_mib_total"] for r in sink.records]
    assert totals == [1.0, 2.0]


def test_vector_and_scalar_type_enforcement():
    logger = MetricsLogger([_ListSink()])
    with pytest.raises(TypeError):
        logger.log_round(0, {"loss_agent": 1.0})  # declared vec_f32
    with pytest.raises(TypeError):
        logger.log_round(0, {"loss_mean": [1.0, 2.0]})  # declared scalar
    logger.log_round(0, {"loss_agent": jnp.ones((3,)), "step": jnp.int32(0)})


def test_jsonl_round_trip_and_validator(tmp_path):
    path = str(tmp_path / "run.jsonl")
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="dense", **BASE)
    logger = MetricsLogger([JSONLSink(path)])
    logger.start_run(run_manifest(cfg, manifest_hash="ab12", arch="toy"))
    logger.log_round(0, {"loss_mean": 1.5, "lr": 0.05,
                         "loss_agent": [1.0, 2.0, 1.0, 2.0]})
    logger.log_timing(3, {"phase_ms_estimate": 1.0, "phase_ms_update": 0.5,
                          "phase_ms_mix": 0.25, "phase_ms_total": 1.75})
    logger.log_round(5, {"loss_mean": 1.25, "lr": 0.04})
    logger.finish({"rounds": 6})
    assert validate_jsonl(path) == []

    records = [json.loads(l) for l in open(path)]
    kinds = [r["record"] for r in records]
    assert kinds == ["manifest", "metrics", "phase_timing", "metrics", "final"]
    head = records[0]
    assert head["schema_version"] == SCHEMA_VERSION
    assert head["manifest_hash"] == "ab12"
    assert head["config_hash"] == metricslib.config_hash(cfg)
    # json round-trip of the config hash input is stable (tuples/lists)
    assert metricslib.config_hash(dataclasses.asdict(cfg)) == head["config_hash"]


def test_validator_catches_broken_artifacts(tmp_path):
    # no manifest header
    p1 = tmp_path / "no_manifest.jsonl"
    p1.write_text('{"record": "metrics", "step": 0, "loss_mean": 1.0}\n')
    assert any("manifest" in s for s in validate_jsonl(str(p1)))
    # undeclared key (written around the strict logger)
    p2 = tmp_path / "undeclared.jsonl"
    p2.write_text(
        json.dumps({"record": "manifest", "schema_version": SCHEMA_VERSION,
                    "config_hash": "x", "jax_version": "0", "backend": "cpu"})
        + "\n" + json.dumps({"record": "metrics", "step": 0, "mystery": 1.0})
        + "\n")
    assert any("undeclared" in s for s in validate_jsonl(str(p2)))
    # non-monotone step
    p3 = tmp_path / "steps.jsonl"
    p3.write_text(
        json.dumps({"record": "manifest", "schema_version": SCHEMA_VERSION,
                    "config_hash": "x", "jax_version": "0", "backend": "cpu"})
        + "\n" + json.dumps({"record": "metrics", "step": 5, "loss_mean": 1.0})
        + "\n" + json.dumps({"record": "metrics", "step": 5, "loss_mean": 1.0})
        + "\n")
    assert any("monotone" in s for s in validate_jsonl(str(p3)))


def test_csv_sink_flattens_metrics_only(tmp_path):
    path = str(tmp_path / "run.csv")
    logger = MetricsLogger([metricslib.CSVSink(path)])
    logger.start_run(run_manifest(arch="toy"))
    logger.log_round(0, {"loss_mean": 1.5, "loss_agent": [1.0, 2.0]})
    logger.log_round(1, {"loss_mean": 1.25, "loss_agent": [1.0, 2.0]})
    logger.finish({"rounds": 2})
    lines = open(path).read().strip().splitlines()
    assert lines[0].split(",")[:2] == ["step", "loss_mean"]
    assert len(lines) == 3  # header + 2 metrics rows; manifest/final dropped


def test_make_sink_dispatch(tmp_path):
    assert isinstance(metricslib.make_sink("-"), metricslib.StdoutSink)
    assert isinstance(metricslib.make_sink(str(tmp_path / "a.csv")),
                      metricslib.CSVSink)
    assert isinstance(metricslib.make_sink(str(tmp_path / "a.jsonl")),
                      metricslib.JSONLSink)


# ---------------------------------------------------------------------------
# fenced per-phase decomposition: honesty contracts
# ---------------------------------------------------------------------------

PHASE_CFGS = [
    ("dense_sgd", dict(n_agents=4, n_zeroth=2, gossip="dense",
                       momentum=0.9,
                       **{k: v for k, v in BASE.items() if k != "momentum"})),
    ("graph_topk_ef", dict(n_agents=4, n_zeroth=2, gossip="graph",
                           topology="ring", compression="topk", compress_k=4,
                           staleness=1, **BASE)),
    ("plane_adamw", dict(n_agents=4, n_zeroth=2, gossip="dense",
                         param_layout="plane", optimizer="adamw", **BASE)),
    ("het_sigmas", dict(n_agents=4, n_zeroth=2, gossip="dense",
                        sigmas=(1e-3, 1e-2), lrs=(0.05, 0.04, 0.05, 0.04),
                        **BASE)),
]


@pytest.mark.parametrize("name,kw", PHASE_CFGS, ids=[n for n, _ in PHASE_CFGS])
def test_phase_round_bit_identical_to_fused_step(name, kw):
    """The three separately-jitted phase calls ARE the fused round:
    same params, opt state, comm state and losses, bit for bit, over
    several rounds — the honesty contract behind the fenced numbers."""
    cfg = HDOConfig(**kw)
    fused = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D,
                                   params_template=_params()))
    fns = timinglib.build_phase_fns(loss_fn, cfg, param_dim=D,
                                    params_template=_params())
    s_f = init_state(_params(), cfg)
    s_p = jax.tree.map(lambda x: x, s_f)
    for t in range(3):
        b = make_batches(jax.random.fold_in(jax.random.PRNGKey(9), t),
                         cfg.n_agents)
        s_f, mets = fused(s_f, b)
        s_p, losses = timinglib.phase_round(fns, s_p, b)
        np.testing.assert_array_equal(np.asarray(mets["loss_mean"]),
                                      np.asarray(losses.mean()))
        for a, c in zip(jax.tree.leaves(s_f), jax.tree.leaves(s_p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c),
                                          err_msg=f"{name} round {t}")


def test_phase_timer_measure_schema_and_compile_split():
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="graph", topology="ring",
                    momentum=0.9,
                    **{k: v for k, v in BASE.items() if k != "momentum"})
    fns = timinglib.build_phase_fns(loss_fn, cfg, param_dim=D,
                                    params_template=_params())
    fused = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D,
                                   params_template=_params()))
    timer = timinglib.PhaseTimer(
        fns, timinglib.analytic_phase_bytes(cfg, D))
    state = init_state(_params(), cfg)
    b = make_batches(jax.random.PRNGKey(9), cfg.n_agents)
    first = timer.measure(state, b, fused_fn=fused)
    second = timer.measure(state, b, fused_fn=fused)
    # compile split only on the first sample
    assert {k for k in first if k.startswith("phase_compile_ms_")} == {
        "phase_compile_ms_estimate", "phase_compile_ms_update",
        "phase_compile_ms_mix"}
    assert not any(k.startswith("phase_compile_ms_") for k in second)
    for rec in (first, second):
        assert undeclared(rec.keys()) == []
        assert rec["phase_ms_total"] == pytest.approx(
            rec["phase_ms_estimate"] + rec["phase_ms_update"]
            + rec["phase_ms_mix"])
        assert rec["step_ms_fused"] > 0
        # ring: both phases priced by the analytic model
        assert rec["hbm_bytes_update"] == cfg.n_agents * (12 + 2 * 4) * D
        assert rec["hbm_bytes_mix"] == cfg.n_agents * (2 + 2) * D * 4
        assert rec["hbm_gbps_update"] > 0
    # measuring never advanced the state
    assert int(state.step) == 0


def test_build_phase_fns_rejects_local_steps():
    cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="dense", local_steps=2,
                    **BASE)
    with pytest.raises(ValueError, match="local_steps"):
        timinglib.build_phase_fns(loss_fn, cfg, param_dim=D)


def test_analytic_phase_bytes_model():
    mk = lambda **kw: HDOConfig(n_agents=4, n_zeroth=2, **{**BASE, **kw})
    d = 100
    # momentum=0 sgd: no momentum stream
    assert timinglib.analytic_phase_bytes(
        mk(gossip="dense"), d)["hbm_bytes_update"] == 4 * 12 * d
    # momentum sgd: + read+write momentum
    assert timinglib.analytic_phase_bytes(
        mk(gossip="dense", momentum=0.9), d)["hbm_bytes_update"] == 4 * 20 * d
    # adamw reads/writes mu and nu
    assert timinglib.analytic_phase_bytes(
        mk(gossip="dense", optimizer="adamw"),
        d)["hbm_bytes_update"] == 4 * 28 * d
    # bfloat16 momentum halves the momentum stream
    assert timinglib.analytic_phase_bytes(
        mk(gossip="dense", momentum=0.9, momentum_dtype="bfloat16"),
        d)["hbm_bytes_update"] == 4 * 16 * d
    # mix priced only for static graphs; compression adds 2 streams
    assert "hbm_bytes_mix" not in timinglib.analytic_phase_bytes(
        mk(gossip="dense"), d)
    ring = timinglib.analytic_phase_bytes(mk(gossip="graph"), d)
    assert ring["hbm_bytes_mix"] == 4 * (2 + 2) * d * 4
    ringc = timinglib.analytic_phase_bytes(
        mk(gossip="graph", compression="topk", compress_k=8), d)
    assert ringc["hbm_bytes_mix"] == 4 * (2 + 4) * d * 4
    assert timinglib.analytic_phase_bytes(mk(gossip="dense"), None) == {}


def test_default_sample_rounds():
    assert timinglib.default_sample_rounds(0) == ()
    assert timinglib.default_sample_rounds(1) == ()
    assert timinglib.default_sample_rounds(2) == (1,)
    assert timinglib.default_sample_rounds(20) == (3, 10, 18)
    for steps in (2, 3, 5, 7, 100):
        for t in timinglib.default_sample_rounds(steps):
            assert 0 < t < steps


# ---------------------------------------------------------------------------
# tracing wrappers
# ---------------------------------------------------------------------------


def test_phase_scope_names_and_numerics():
    with pytest.raises(ValueError):
        with tracelib.phase_scope("not_a_phase"):
            pass
    # named_scope annotates HLO metadata only — numerics are untouched
    x = jnp.arange(8.0)

    @jax.jit
    def f(x):
        with tracelib.phase_scope("estimate"):
            y = x * 2
        with tracelib.op_scope("gossip_mix"):
            return y + 1

    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x * 2 + 1))


def test_host_annotation_disabled_is_nullcontext():
    with tracelib.host_annotation("x", False):
        pass
    with tracelib.host_annotation("x", True):
        pass


# ---------------------------------------------------------------------------
# generated docs table CLI
# ---------------------------------------------------------------------------


def test_schema_table_write_and_check(tmp_path, capsys):
    doc = tmp_path / "obs.md"
    doc.write_text(f"# Title\n\n{metricslib.BEGIN}\nstale\n{metricslib.END}\n")
    assert metricslib.main(["--check", str(doc)]) == 1
    assert metricslib.main(["--write", str(doc)]) == 0
    assert metricslib.main(["--check", str(doc)]) == 0
    text = doc.read_text()
    assert "| `loss_mean` |" in text
    assert f"**{SCHEMA_VERSION}**" in text
    # idempotent
    before = doc.read_text()
    assert metricslib.main(["--write", str(doc)]) == 0
    assert doc.read_text() == before


def test_schema_table_missing_markers_fails(tmp_path):
    doc = tmp_path / "no_markers.md"
    doc.write_text("# Title\n")
    with pytest.raises(SystemExit):
        metricslib.main(["--write", str(doc)])


def test_docs_observability_table_is_current():
    """The committed docs table matches the registry (the docs half of
    the drift gate; CI also runs --check)."""
    import os

    doc = os.path.join(os.path.dirname(__file__), "..", "docs",
                       "observability.md")
    assert metricslib.main(["--check", doc]) == 0
