"""Numerical-equivalence tests for the §Perf optimization variants
(every beyond-paper change must preserve the paper-faithful semantics).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, init_state
from repro.models import build_model


def _run_quadratic(cfg, steps=80):
    d = 12
    w_true = jax.random.normal(jax.random.PRNGKey(42), (d,))

    def loss_fn(params, batch):
        return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)

    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=d))
    state = init_state({"w": jnp.zeros((d,))}, cfg)
    for t in range(steps):
        k = jax.random.fold_in(jax.random.PRNGKey(9), t)
        X = jax.random.normal(k, (cfg.n_agents, 8, d))
        state, m = step(state, {"X": X, "y": X @ w_true})
    return state.params["w"].mean(0)


def test_split_dispatch_matches_select():
    base = dict(n_agents=6, n_zeroth=4, gossip="rr_static", lr=0.05,
                momentum=0.9, warmup_steps=0, use_cosine=False, rv=2, nu=1e-3)
    w_sel = _run_quadratic(HDOConfig(dispatch="select", **base))
    w_spl = _run_quadratic(HDOConfig(dispatch="split", **base))
    np.testing.assert_allclose(np.asarray(w_sel), np.asarray(w_spl), atol=1e-5)


def test_fused_zo_matches_tree_converged():
    """zo_impl="fused" reaches the tree path's converged solution.

    The counter-RNG draws differ from jax.random, so trajectories are
    not bit-equal; on the quadratic both settle onto w_true to float
    eps, which is where parity is asserted (same tolerance as the
    dispatch-parity tests above).
    """
    base = dict(n_agents=6, n_zeroth=4, gossip="rr_static", lr=0.05,
                momentum=0.0, warmup_steps=0, use_cosine=False, rv=2, nu=1e-3)
    w_tree = _run_quadratic(HDOConfig(zo_impl="tree", **base), steps=300)
    w_fused = _run_quadratic(HDOConfig(zo_impl="fused", **base), steps=300)
    np.testing.assert_allclose(np.asarray(w_tree), np.asarray(w_fused), atol=1e-5)


def test_fused_split_dispatch_matches_select():
    """The fused engine is dispatch-invariant (same seeds -> same draws)."""
    base = dict(n_agents=6, n_zeroth=4, gossip="rr_static", lr=0.05,
                momentum=0.9, warmup_steps=0, use_cosine=False, rv=2, nu=1e-3,
                zo_impl="fused")
    w_sel = _run_quadratic(HDOConfig(dispatch="select", **base))
    w_spl = _run_quadratic(HDOConfig(dispatch="split", **base))
    np.testing.assert_allclose(np.asarray(w_sel), np.asarray(w_spl), atol=1e-5)


def test_bf16_momentum_close_to_f32():
    base = dict(n_agents=4, n_zeroth=2, gossip="dense", lr=0.05,
                momentum=0.9, warmup_steps=0, use_cosine=False, rv=2, nu=1e-3)
    w32 = _run_quadratic(HDOConfig(momentum_dtype="float32", **base))
    w16 = _run_quadratic(HDOConfig(momentum_dtype="bfloat16", **base))
    # bf16 accumulator: same optimum, small rounding drift allowed
    assert float(jnp.linalg.norm(w32 - w16)) < 0.05 * float(jnp.linalg.norm(w32) + 1)


def test_ring_cache_matches_full_cache():
    base = dataclasses.replace(get_smoke_config("gemma2-9b"), dtype="float32",
                               local_global_period=0, sliding_window=8)
    ring = dataclasses.replace(base, decode_window_slice=True)
    S, B = 24, 2
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, base.vocab_size)
    outs = {}
    for name, cfg in [("full", base), ("ring", ring)]:
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        cache = m.init_cache(B, S)
        step = jax.jit(m.serve_step)
        o = []
        for t in range(S):
            lg, cache = step(params, cache, toks[:, t], jnp.int32(t))
            o.append(lg)
        outs[name] = jnp.stack(o, 1)
    assert outs["ring"] is not None
    np.testing.assert_allclose(np.asarray(outs["ring"]), np.asarray(outs["full"]),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.slow
def test_moe_ep_parity_subprocess():
    """Expert-parallel shard_map MoE == reference (needs 8 devices)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import moe as moe_lib
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = dataclasses.replace(get_smoke_config("llama4-maverick-400b-a17b"), dtype="float32")
        p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
        cf = float(cfg.num_experts)
        y0, a0 = jax.jit(lambda p, x: moe_lib.moe_apply(p, x, cfg, capacity_factor=cf))(p, x)
        moe_lib.set_ep_context(mesh, "data")
        y1, a1 = jax.jit(lambda p, x: moe_lib.moe_apply(p, x, cfg, capacity_factor=cf))(p, x)
        # 1e-4: the EP program replicates over the model axis on 0.4.x
        # (compat full-manual fallback), so einsum reduction order and
        # fusion differ from the unsharded reference by float noise
        assert float(jnp.max(jnp.abs(y0 - y1))) < 1e-4, "y mismatch"
        assert float(abs(a0 - a1)) < 1e-4, "aux mismatch"
        print("EP_PARITY_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=420, env=env, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "EP_PARITY_OK" in proc.stdout


@pytest.mark.slow
def test_shard_cond_parity_subprocess():
    """shard_cond dispatch == select on a multi-device population,
    for both the tree and the fused ZO engines."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import HDOConfig
        from repro.core import build_hdo_step, init_state
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        d = 12
        w_true = jax.random.normal(jax.random.PRNGKey(42), (d,))
        def loss_fn(params, batch):
            return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)
        for impl in ("tree", "fused"):
            outs = {}
            for disp in ("select", "shard_cond"):
                cfg = HDOConfig(n_agents=4, n_zeroth=2, gossip="rr_static", lr=0.05,
                                momentum=0.0, warmup_steps=0, use_cosine=False,
                                rv=2, nu=1e-3, dispatch=disp, zo_impl=impl)
                step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=d, mesh=mesh,
                                              population_axes=("data",)))
                state = init_state({"w": jnp.zeros((d,))}, cfg)
                for t in range(40):
                    k = jax.random.fold_in(jax.random.PRNGKey(9), t)
                    X = jax.random.normal(k, (4, 8, d))
                    state, m = step(state, {"X": X, "y": X @ w_true})
                outs[disp] = np.asarray(state.params["w"])
            np.testing.assert_allclose(outs["select"], outs["shard_cond"],
                                       atol=1e-5, err_msg=impl)
        print("SHARD_COND_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=420, env=env, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARD_COND_OK" in proc.stdout
