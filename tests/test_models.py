"""Per-architecture smoke tests (REQUIRED): reduced variant of each of
the 10 assigned architectures runs one forward + one train step on CPU,
asserting output shapes and no NaNs.  Plus decode-vs-forward parity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, init_state
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def smoke_batch(cfg, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(KEY, (B, cfg.num_patches, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = dataclasses.replace(get_smoke_config(request.param), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def test_forward_shapes_and_finite(arch_setup):
    cfg, model, params = arch_setup
    B, S = 2, 32
    batch = smoke_batch(cfg, B, S)
    logits = model.logits(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_step_no_nans(arch_setup):
    """One HDO train step (2 agents: 1 FO + 1 ZO) on the reduced arch."""
    cfg, model, params = arch_setup
    hcfg = HDOConfig(n_agents=2, n_zeroth=1, rv=1, estimator_zo="fwd_grad",
                     gossip="dense", lr=0.01, momentum=0.9, warmup_steps=0,
                     use_cosine=False)
    step = jax.jit(build_hdo_step(model.loss, hcfg))
    state = init_state(params, hcfg)
    batch = smoke_batch(cfg)
    batches = jax.tree.map(lambda x: jnp.stack([x, x]), batch)
    state, metrics = step(state, batches)
    assert np.isfinite(float(metrics["loss_mean"]))
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_decode_matches_forward(arch_setup):
    cfg, model, params = arch_setup
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family in ("vlm", "audio"):
        pytest.skip("parity test covers pure text decoders; vlm/audio via dryrun")
    full = model.logits(params, batch)
    cache = model.init_cache(B, S)
    step = jax.jit(model.serve_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4, rtol=1e-3)


def test_audio_decode_runs():
    cfg = dataclasses.replace(get_smoke_config("whisper-base"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 16)
    logits, cache = jax.jit(model.serve_step)(params, cache, jnp.zeros((2,), jnp.int32), jnp.int32(0))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_vlm_decode_runs():
    cfg = dataclasses.replace(get_smoke_config("pixtral-12b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 16)
    logits, cache = jax.jit(model.serve_step)(params, cache, jnp.zeros((2,), jnp.int32), jnp.int32(0))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_gemma2_softcap_bounds_attention_logits():
    """Behavioural check: logits stay finite with adversarial scale."""
    cfg = dataclasses.replace(get_smoke_config("gemma2-9b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    params = jax.tree.map(lambda x: x * 10.0 if x.ndim >= 2 else x, params)
    logits = model.logits(params, smoke_batch(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_masked_labels_ignored_in_loss():
    cfg = dataclasses.replace(get_smoke_config("qwen1.5-0.5b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    batch = smoke_batch(cfg)
    l_full = float(model.loss(params, batch))
    labels = batch["labels"].at[:, ::2].set(-1)
    l_masked = float(model.loss(params, {**batch, "labels": labels}))
    assert np.isfinite(l_masked) and abs(l_masked - l_full) > 0  # different subset
