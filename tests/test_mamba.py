"""Mamba2 / SSD unit tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import mamba2

CFG = dataclasses.replace(get_smoke_config("mamba2-780m"), dtype="float32")
KEY = jax.random.PRNGKey(0)


def _ssd_inputs(b=2, s=96, h=3, p=8, n=16, key=KEY):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [16, 32, 96])
def test_chunked_matches_sequential(chunk):
    x, dt, A, Bm, Cm = _ssd_inputs()
    y1, h1 = mamba2.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y2, h2 = mamba2.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-4)


def test_chunked_with_initial_state():
    x, dt, A, Bm, Cm = _ssd_inputs()
    h0 = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 3, 8, 16))
    y1, h1 = mamba2.ssd_chunked(x, dt, A, Bm, Cm, chunk=32, h0=h0)
    y2, h2 = mamba2.ssd_reference(x, dt, A, Bm, Cm, h0=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)


def test_decode_step_matches_prefill():
    """Recurrent decode over S steps == chunked forward."""
    x, dt, A, Bm, Cm = _ssd_inputs(b=1, s=32)
    y_ref, _ = mamba2.ssd_reference(x, dt, A, Bm, Cm)
    h = jnp.zeros((1, 3, 8, 16))
    outs = []
    for t in range(32):
        y, h = mamba2.ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                                      jnp.zeros((3,)))
        outs.append(y)
    y_dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_ref), atol=1e-4, rtol=1e-4)


def test_mamba_block_grads_finite():
    p = mamba2.init_mamba_block(KEY, CFG, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 64, CFG.d_model))

    def loss(p):
        return jnp.sum(mamba2.mamba_block(p, x, CFG) ** 2)

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_conv_cache_consistency():
    """mamba_decode_step over a sequence == mamba_block on it."""
    p = mamba2.init_mamba_block(KEY, CFG, jnp.float32)
    S = 16
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, CFG.d_model))
    full = mamba2.mamba_block(p, x, CFG)
    cache = mamba2.mamba_init_cache(CFG, 2, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = mamba2.mamba_decode_step(p, cache, x[:, t], CFG)
        outs.append(y)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4, rtol=1e-4)
