"""Compressed, fault-tolerant gossip (PR 7): compressor contracts
(top-k support, qsgd unbiasedness, error-feedback telescoping), mean
preservation of the difference-form round, kernel-vs-oracle
bit-exactness, the compression="none" regression pin, replayable fault
injection, measured-vs-predicted Gamma contraction under compression /
staleness, checkpoint round-trip of the comm state, and plane-vs-tree
residual-stream parity.

Comparison discipline (mirrors tests/test_kernels.py): the fused
``compress_mix`` kernel is compared BIT-EXACT against the JITTED jnp
oracle (both run as one compiled jaxpr, so XLA applies the same FMA
contraction); kernel vs the eager oracle or across different
associations is allclose only.

Hypothesis property variants of the compressor contracts live at the
bottom, gated exactly like tests/test_properties.py — the seeded
deterministic versions above them always run.
"""
import dataclasses
import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis
from repro import checkpoint
from repro import topology as topolib
from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.core import plane as planelib
from repro.core.hdo import HDOState
from repro.kernels import ops, ref
from repro.kernels.compress_mix import BLOCK
from repro.topology import compress as compresslib
from repro.topology import faults as faultlib
from repro.topology import spectral

D = 16
W_TRUE = jax.random.normal(jax.random.PRNGKey(42), (D,))


def loss_fn(params, batch):
    return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)


def make_batches(key, n_agents, bsz=4):
    X = jax.random.normal(key, (n_agents, bsz, D))
    return {"X": X, "y": X @ W_TRUE}


CONST = dict(lr=0.05, momentum=0.0, warmup_steps=0, use_cosine=False,
             nu=1e-3, rv=1, gossip="graph", topology="ring")


# ---------------------------------------------------------------------------
# compressor unit contracts (seeded, deterministic)
# ---------------------------------------------------------------------------


def _payload(key, n, d):
    u = jax.random.normal(key, (n, d), jnp.float32)
    seeds = compresslib.payload_seeds(0, 0, n)
    return u, seeds


def test_topk_keeps_exactly_the_largest_coordinates():
    """C(u) is supported on exactly the k largest-|u| coordinates and
    equals u there (continuous draws: ties are measure-zero)."""
    comp = compresslib.Compressor("topk", k=5)
    u, seeds = _payload(jax.random.PRNGKey(0), 6, 41)
    m = np.asarray(comp.apply(u, comp.thresholds(u), seeds))
    un = np.asarray(u)
    for i in range(6):
        support = np.nonzero(m[i])[0]
        assert len(support) == 5, (i, support)
        expect = set(np.argsort(-np.abs(un[i]))[:5].tolist())
        assert set(support.tolist()) == expect, i
        np.testing.assert_array_equal(m[i][support], un[i][support])


def test_qsgd_values_on_the_level_grid():
    """Every quantized coordinate is sign(u) * thr * j / levels for an
    integer j in [0, levels], so the payload really is bits+sign."""
    bits = 3
    comp = compresslib.Compressor("qsgd", bits=bits)
    u, seeds = _payload(jax.random.PRNGKey(1), 4, 257)
    thr = comp.thresholds(u)
    m = np.asarray(comp.apply(u, thr, seeds), np.float64)
    levels = (1 << bits) - 1
    j = m * levels / np.asarray(thr)[:, None]
    np.testing.assert_allclose(j, np.round(j), atol=1e-4)
    assert np.abs(j).max() <= levels + 1e-4
    # sign never flips
    assert np.all(m * np.asarray(u) >= 0.0)


def test_qsgd_unbiased_in_expectation():
    """E[C(u)] == u over the rounding randomness (the seed lane) —
    CLT tolerance on the per-coordinate mean."""
    bits = 3
    comp = compresslib.Compressor("qsgd", bits=bits)
    d, S = 64, 4096
    u = jax.random.normal(jax.random.PRNGKey(2), (1, d), jnp.float32)
    rows = jnp.broadcast_to(u, (S, d))
    thr = comp.thresholds(rows)
    seeds = jnp.arange(S, dtype=jnp.uint32)
    m = np.asarray(jax.jit(comp.apply)(rows, thr, seeds), np.float64)
    mean = m.mean(axis=0)
    # per-coordinate std <= thr/(2*levels); 5 sigma of the S-mean
    tol = 5.0 * float(thr[0]) / (2 * ((1 << bits) - 1)) / np.sqrt(S)
    np.testing.assert_allclose(mean, np.asarray(u[0], np.float64), atol=tol)


def test_error_feedback_telescopes():
    """sent + residual == raw send basis: m_i + e_i' == x_i + e_i after
    every round, for both compressors (exact for topk — the residual is
    a masked copy; float-tight for qsgd)."""
    n = 8
    topo = topolib.ring(n)
    for comp, atol in ((compresslib.Compressor("topk", k=3), 0.0),
                       (compresslib.Compressor("qsgd", bits=4), 1e-6)):
        mixer = topolib.CompressedGraphMixer(topo, compressor=comp, seed=5)
        params = {"w": jax.random.normal(jax.random.PRNGKey(3), (n, D))}
        comm = mixer.init_comm(params)
        for t in range(4):
            u = (params["w"].astype(jnp.float32)
                 + comm["residual"]["w"])  # raw send basis this round
            new_params, new_comm = mixer.mix(
                params, key=None, step=jnp.int32(t), comm=comm)
            seeds = compresslib.payload_seeds(5, t, n)
            m = comp.apply(u, comp.thresholds(u), seeds)
            lhs = np.asarray(m + new_comm["residual"]["w"], np.float64)
            np.testing.assert_allclose(lhs, np.asarray(u, np.float64),
                                       atol=atol, err_msg=f"{comp.mode}@{t}")
            params, comm = new_params, new_comm


def test_payload_seeds_replayable_and_distinct():
    a = np.asarray(compresslib.payload_seeds(3, 7, 8))
    b = np.asarray(compresslib.payload_seeds(3, 7, 8))
    np.testing.assert_array_equal(a, b)
    assert len(set(a.tolist())) == 8  # distinct per agent
    c = np.asarray(compresslib.payload_seeds(3, 8, 8))
    assert not np.array_equal(a, c)  # step moves the stream


def test_bytes_on_wire_accounting():
    d = 1 << 20
    topk = compresslib.Compressor("topk", k=d // 100)
    qsgd = compresslib.Compressor("qsgd", bits=4)
    assert topk.bytes_on_wire(d) == 8 * (d // 100)
    assert qsgd.bytes_on_wire(d) == (d * 5 + 7) // 8 + 4
    # both far below the dense f32 payload
    assert topk.bytes_on_wire(d) < 4 * d / 10
    assert qsgd.bytes_on_wire(d) < 4 * d / 5
    assert 0.0 < topk.delta(d) < 1.0 and 0.0 < qsgd.delta(d) <= 1.0


# ---------------------------------------------------------------------------
# mean preservation of the compressed round
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,kw", [("topk", dict(k=3)),
                                     ("qsgd", dict(bits=4))])
@pytest.mark.parametrize("topo_fn", [
    lambda: topolib.ring(8),
    lambda: topolib.torus(8),
    lambda: topolib.erdos_renyi(8, 0.5, seed=2),
])
def test_compressed_round_preserves_mean(topo_fn, mode, kw):
    """Difference-form mixing keeps the population mean exact for ANY
    compressor — including under staleness and drop/straggler faults
    (byzantine intentionally excepted, asserted below)."""
    topo = topo_fn()
    comp = compresslib.Compressor(mode, **kw)
    variants = [
        topolib.CompressedGraphMixer(topo, compressor=comp),
        topolib.CompressedGraphMixer(topo, compressor=comp, staleness=2),
        topolib.CompressedGraphMixer(
            topo, compressor=comp, staleness=1,
            faults=faultlib.FaultSpec(drop_rate=0.3, straggler_rate=0.3,
                                      seed=11)),
    ]
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (8, D))}
    mu0 = np.asarray(params["w"], np.float64).mean(axis=0)
    for mixer in variants:
        p, comm = params, mixer.init_comm(params)
        for t in range(5):
            p, comm = mixer.mix(p, key=None, step=jnp.int32(t), comm=comm)
        np.testing.assert_allclose(
            np.asarray(p["w"], np.float64).mean(axis=0), mu0, atol=1e-5)


def test_byzantine_breaks_the_mean():
    """The adversarial payload must actually move the population mean —
    otherwise the fault injection is a no-op."""
    topo = topolib.ring(8)
    mixer = topolib.CompressedGraphMixer(
        topo, compressor=compresslib.Compressor("topk", k=8),
        faults=faultlib.FaultSpec(byzantine_rate=0.5, seed=3))
    params = {"w": jax.random.normal(jax.random.PRNGKey(6), (8, D))}
    p, comm = params, mixer.init_comm(params)
    for t in range(3):
        p, comm = mixer.mix(p, key=None, step=jnp.int32(t), comm=comm)
    drift = np.abs(np.asarray(p["w"]).mean(axis=0)
                   - np.asarray(params["w"]).mean(axis=0)).max()
    assert drift > 1e-3, drift


# ---------------------------------------------------------------------------
# fused kernel vs jitted jnp oracle: bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,bits,k", [("topk", 0, 37), ("qsgd", 4, 0)])
@pytest.mark.parametrize("d", [1000, BLOCK, 10007])
def test_compress_mix_kernel_bit_exact_vs_jitted_ref(d, mode, bits, k):
    """ops.compress_mix == jit(ref.compress_mix_ref) bit for bit across
    sub-block, exactly-aligned, and tail-padded sizes, for both
    compressors — output AND residual."""
    comp = compresslib.Compressor(mode, k=k, bits=bits)
    key = jax.random.PRNGKey(d)
    x = jax.random.normal(key, (d,))
    e = jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 0.1
    u = x + e
    nbrs = jax.random.normal(jax.random.fold_in(key, 2), (2, d))
    w = jnp.asarray([0.25, 0.25], jnp.float32)
    rows = jnp.concatenate([u[None], nbrs], axis=0)
    thr = comp.thresholds(rows)
    seeds = compresslib.payload_seeds(9, 3, 3)
    out_k, res_k = ops.compress_mix(x, u, nbrs, w, thr, seeds, mode, bits)
    jref = jax.jit(functools.partial(ref.compress_mix_ref, mode=mode,
                                     bits=bits))
    out_r, res_r = jref(x, u, nbrs, w, thr, seeds)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(res_k), np.asarray(res_r))


def test_compress_mix_kernel_bf16_params():
    """bf16 x with f32 send bases: the kernel accumulates in f32 and
    casts the mixed output back to x.dtype, matching the jitted ref."""
    d = 9000
    x = jax.random.normal(jax.random.PRNGKey(0), (d,)).astype(jnp.bfloat16)
    u = x.astype(jnp.float32)
    nbrs = jax.random.normal(jax.random.PRNGKey(1), (2, d))
    w = jnp.asarray([0.25, 0.25], jnp.float32)
    comp = compresslib.Compressor("qsgd", bits=4)
    thr = comp.thresholds(jnp.concatenate([u[None], nbrs], axis=0))
    seeds = compresslib.payload_seeds(1, 0, 3)
    out_k, res_k = ops.compress_mix(x, u, nbrs, w, thr, seeds, "qsgd", 4)
    jref = jax.jit(functools.partial(ref.compress_mix_ref, mode="qsgd",
                                     bits=4))
    out_r, res_r = jref(x, u, nbrs, w, thr, seeds)
    assert out_k.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out_k, np.float32),
                                  np.asarray(out_r, np.float32))
    np.testing.assert_array_equal(np.asarray(res_k), np.asarray(res_r))


def test_compressed_mixer_kernel_path_matches_jnp():
    """CompressedGraphMixer(use_kernel=True) == the jnp lowering on the
    fresh path (allclose: different float association)."""
    topo = topolib.torus(8)
    comp = compresslib.Compressor("topk", k=4)
    params = {"w": jax.random.normal(jax.random.PRNGKey(8), (8, D))}
    outs = {}
    for uk in (False, True):
        mixer = topolib.CompressedGraphMixer(topo, compressor=comp,
                                             use_kernel=uk, seed=2)
        p, comm = mixer.mix(params, key=None, step=jnp.int32(0),
                            comm=mixer.init_comm(params))
        outs[uk] = (np.asarray(p["w"]), np.asarray(comm["residual"]["w"]))
    np.testing.assert_allclose(outs[False][0], outs[True][0], atol=1e-6)
    np.testing.assert_allclose(outs[False][1], outs[True][1], atol=1e-6)


# ---------------------------------------------------------------------------
# the regression pin: compression="none" is bit-identical to the plain
# graph round (the stateless Mixer objects, the empty comm stream)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("zo_impl,dispatch,param_layout", [
    ("tree", "select", "tree"),
    ("fused", "split", "tree"),
    ("fused", "select", "plane"),
])
def test_none_compression_bit_identical(zo_impl, dispatch, param_layout):
    """With compression="none" the step must replay the uncompressed
    graph round EXACTLY: make_mixer returns the plain (stateless)
    GraphMixer class, state.comm is the empty pytree, and one step
    equals a gossip="none" step followed by the jitted GraphMixer on
    its output (the pre-compression decomposition, same discipline as
    tests/test_topology.py::test_dense_step_bit_identical_to_pre_refactor)
    — across both ZO engines, grouped dispatch, and the plane layout."""
    n = 6
    kw = dict(n_agents=n, n_zeroth=3, zo_impl=zo_impl, dispatch=dispatch,
              param_layout=param_layout, lr=0.25, momentum=0.5,
              warmup_steps=0, use_cosine=False, nu=1e-3, rv=2)
    cfg_g = HDOConfig(gossip="graph", topology="ring", compression="none",
                      **kw)
    cfg_n = HDOConfig(gossip="none", **kw)
    assert type(topolib.make_mixer(cfg_g, use_kernel=False)) \
        is topolib.GraphMixer
    p0 = {"w": jnp.zeros((D,))}
    tmpl = dict(params_template=p0) if param_layout == "plane" else {}
    step_g = jax.jit(build_hdo_step(loss_fn, cfg_g, param_dim=D, **tmpl))
    step_n = jax.jit(build_hdo_step(loss_fn, cfg_n, param_dim=D, **tmpl))
    mixer = topolib.GraphMixer(topolib.ring(n))
    sg = init_state(p0, cfg_g)
    assert sg.comm == ()
    sn = init_state(p0, cfg_n)
    b = make_batches(jax.random.PRNGKey(13), n)
    sg, mg = step_g(sg, b)
    sn, _ = step_n(sn, b)
    ref_params = jax.jit(
        lambda p: mixer.mix(p, key=None, step=jnp.int32(0), comm=())[0]
    )(sn.params)
    for a, b in zip(jax.tree.leaves(sg.params), jax.tree.leaves(ref_params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # plain spectral metrics only — no compression diagnostics
    assert "gossip_lambda2" in mg and "gossip_compress_delta" not in mg


def test_compression_metrics_surface_in_step():
    cfg = HDOConfig(n_agents=8, n_zeroth=4, compression="topk", compress_k=4,
                    staleness=1, **CONST)
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg)
    _, m = step(state, make_batches(jax.random.PRNGKey(0), 8))
    topo = topolib.ring(8)
    assert float(m["gossip_compress_delta"]) == pytest.approx(4 / D)
    assert float(m["gossip_staleness"]) == 1.0
    se = spectral.effective_slem(topo, delta=4 / D, staleness=1)
    assert float(m["gossip_effective_lambda2"]) == pytest.approx(se, abs=1e-6)
    assert float(m["gossip_gamma_contraction"]) == pytest.approx(
        se * se, abs=1e-6)
    # the raw graph slem is still reported unchanged
    assert float(m["gossip_lambda2"]) == pytest.approx(
        spectral.slem(topo), abs=1e-6)


def test_config_validation():
    with pytest.raises(ValueError, match="compress_k"):
        HDOConfig(gossip="graph", compression="topk", compress_k=0)
    with pytest.raises(ValueError, match="compress_bits"):
        HDOConfig(gossip="graph", compression="qsgd", compress_bits=9)
    with pytest.raises(ValueError, match="gossip"):
        HDOConfig(gossip="dense", compression="topk", compress_k=2)
    with pytest.raises(ValueError, match="static"):
        HDOConfig(gossip="graph", topology="tv_round_robin",
                  compression="topk", compress_k=2)
    with pytest.raises(ValueError, match="fresh compressed path"):
        HDOConfig(gossip="graph_ppermute", compression="topk", compress_k=2,
                  staleness=1)
    with pytest.raises(ValueError, match="fault_drop_rate"):
        HDOConfig(gossip="graph", fault_drop_rate=1.5)


# ---------------------------------------------------------------------------
# fault injection: replayable by construction
# ---------------------------------------------------------------------------


def test_fault_masks_replayable_and_step_dependent():
    spec = faultlib.FaultSpec(drop_rate=0.5, straggler_rate=0.5,
                              byzantine_rate=0.5, seed=21)
    a = faultlib.fault_masks(spec, jnp.int32(4), 32)
    b = jax.jit(lambda s: faultlib.fault_masks(spec, s, 32))(jnp.int32(4))
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]), k)
    c = faultlib.fault_masks(spec, jnp.int32(5), 32)
    assert any(not np.array_equal(np.asarray(a[k]), np.asarray(c[k]))
               for k in a)
    # zero rates can never fire (the counter uniform lies in (0, 1])
    quiet = faultlib.FaultSpec(drop_rate=0.0, seed=21)
    m = faultlib.fault_masks(quiet, jnp.int32(0), 32)
    assert np.asarray(m["alive"]).all()
    assert not np.asarray(m["straggler"]).any()
    assert not np.asarray(m["byzantine"]).any()


def test_faulty_run_replays_bit_identically():
    """Two fresh builds of the same faulty config produce the same
    trajectory bit for bit — the fault schedule is a pure function of
    (fault_seed, step, agent), not of JAX PRNG state."""
    cfg = HDOConfig(n_agents=8, n_zeroth=4, compression="qsgd",
                    compress_bits=4, staleness=1, fault_drop_rate=0.25,
                    fault_straggler_rate=0.25, fault_byzantine_rate=0.1,
                    fault_seed=17, **CONST)
    outs = []
    for _ in range(2):
        step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
        state = init_state({"w": jnp.zeros((D,))}, cfg)
        for t in range(4):
            state, _ = step(state, make_batches(
                jax.random.fold_in(jax.random.PRNGKey(2), t), 8))
        outs.append(state)
    np.testing.assert_array_equal(np.asarray(outs[0].params["w"]),
                                  np.asarray(outs[1].params["w"]))
    for a, b in zip(jax.tree.leaves(outs[0].comm),
                    jax.tree.leaves(outs[1].comm)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a different fault seed diverges (faults really injected)
    cfg2 = dataclasses.replace(cfg, fault_seed=18)
    step = jax.jit(build_hdo_step(loss_fn, cfg2, param_dim=D))
    state = init_state({"w": jnp.zeros((D,))}, cfg2)
    for t in range(4):
        state, _ = step(state, make_batches(
            jax.random.fold_in(jax.random.PRNGKey(2), t), 8))
    assert not np.array_equal(np.asarray(state.params["w"]),
                              np.asarray(outs[0].params["w"]))


# ---------------------------------------------------------------------------
# measured Gamma vs the spectral model's prediction
# ---------------------------------------------------------------------------


def test_mc_prediction_sanity_none_equals_slem_sq():
    """The independent numpy Monte-Carlo harness reproduces the exact
    closed form in the uncompressed case — pinning the harness itself
    before it is used as the reference for the lossy cases."""
    topo = topolib.ring(8)
    got = spectral.predicted_contraction_empirical(topo, compression="none")
    assert got == pytest.approx(spectral.slem(topo) ** 2, abs=1e-9)


@pytest.mark.parametrize("topo_name,n,comp_kw,tau,kw", [
    ("ring", 12, dict(compression="topk", compress_k=4), 0, {}),
    ("torus", 12, dict(compression="topk", compress_k=4), 1, {}),
    ("erdos_renyi", 12, dict(compression="qsgd", compress_bits=4), 0,
     dict(topology_p=0.45, topology_seed=3)),
])
def test_measured_gamma_matches_compressed_prediction(topo_name, n, comp_kw,
                                                      tau, kw):
    """With lr=0 (pure interaction) the measured per-round Gamma
    contraction through the full jitted step matches the independent
    numpy simulation of compressed/stale gossip — same tail estimator
    (spectral.tail_rate) applied to both traces."""
    cfg = HDOConfig(n_agents=n, n_zeroth=n // 2, gossip="graph",
                    topology=topo_name, lr=0.0, momentum=0.0,
                    warmup_steps=0, use_cosine=False, rv=1, nu=1e-3,
                    staleness=tau, **comp_kw, **kw)
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))
    st0 = init_state({"w": jnp.zeros((D,))}, cfg)
    params = {"w": jax.random.normal(jax.random.PRNGKey(7), (n, D))}
    st = HDOState(params=params, opt_state=st0.opt_state, step=st0.step,
                  comm=compresslib.init_comm(cfg, params))
    gammas = [float(consensus_distance(st.params))]
    for t in range(36):
        st, _ = step(st, make_batches(
            jax.random.fold_in(jax.random.PRNGKey(1), t), n))
        gammas.append(float(consensus_distance(st.params)))
    assert gammas[-1] > 1e-18, "Gamma hit the float noise floor"
    measured = spectral.tail_rate(gammas, staleness=tau)
    topo = topolib.make_topology(topo_name, n, p=kw.get("topology_p", 0.3),
                                 seed=kw.get("topology_seed", 0))
    predicted = spectral.predicted_contraction_empirical(
        topo, compression=cfg.compression, k=cfg.compress_k,
        bits=cfg.compress_bits, staleness=tau, dim=D, rounds=36, trials=8)
    assert measured == pytest.approx(predicted, rel=0.2), (
        topo_name, measured, predicted)
    # and the closed-form effective model brackets the same decade
    delta = spectral.compression_delta(cfg.compression, D, k=cfg.compress_k,
                                       bits=cfg.compress_bits)
    closed = spectral.effective_slem(topo, delta=delta, staleness=tau) ** 2
    assert 0.0 < closed < 1.0


# ---------------------------------------------------------------------------
# checkpoint round-trip of the comm state
# ---------------------------------------------------------------------------


def test_checkpoint_resume_with_comm_state(tmp_path):
    """Resume bit-identity with BOTH comm streams live (residual via
    compression + error feedback, bcast via staleness + stragglers) and
    faults injected — the restored run replays the interrupted one
    exactly, comm leaves included."""
    cfg = HDOConfig(n_agents=8, n_zeroth=4, compression="topk", compress_k=4,
                    staleness=2, fault_drop_rate=0.2,
                    fault_straggler_rate=0.2, fault_seed=9, **CONST)
    step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D))

    def batch_at(t):
        return make_batches(jax.random.fold_in(jax.random.PRNGKey(23), t), 8)

    full = init_state({"w": jnp.zeros((D,))}, cfg)
    assert sorted(full.comm) == ["bcast", "residual"]
    for t in range(5):
        full, _ = step(full, batch_at(t))
    part = init_state({"w": jnp.zeros((D,))}, cfg)
    for t in range(3):
        part, _ = step(part, batch_at(t))
    path = os.path.join(str(tmp_path), "ck")
    checkpoint.save_state(path, part)
    restored, _ = checkpoint.restore_state(
        path, init_state({"w": jnp.zeros((D,))}, cfg))
    assert int(restored.step) == 3
    for t in range(3, 5):
        restored, _ = step(restored, batch_at(t))
    np.testing.assert_array_equal(np.asarray(full.params["w"]),
                                  np.asarray(restored.params["w"]))
    for a, b in zip(jax.tree.leaves(full.comm),
                    jax.tree.leaves(restored.comm)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pre_comm_checkpoints_still_restore(tmp_path):
    """A checkpoint written before the comm stream existed (raw
    params+opt_state tree) restores into a plain config unchanged — the
    empty comm contributes no leaves to the saved structure."""
    cfg = HDOConfig(n_agents=4, n_zeroth=2, **CONST)
    state = init_state({"w": jnp.full((D,), 0.5)}, cfg)
    assert state.comm == ()
    path = os.path.join(str(tmp_path), "old")
    # the pre-comm layout: exactly these two keys
    checkpoint.save(path, jax.device_get(
        {"params": state.params, "opt_state": state.opt_state}), step=7)
    restored, meta = checkpoint.restore_state(path, state)
    assert int(restored.step) == 7 and restored.comm == ()
    np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                  np.asarray(state.params["w"]))


def test_restore_rejects_comm_structure_mismatch(tmp_path):
    """A checkpoint with comm streams cannot silently restore into a
    config without them (and vice versa)."""
    comp_cfg = HDOConfig(n_agents=4, n_zeroth=2, compression="topk",
                         compress_k=2, **CONST)
    plain_cfg = HDOConfig(n_agents=4, n_zeroth=2, **CONST)
    path = os.path.join(str(tmp_path), "ck")
    checkpoint.save_state(path, init_state({"w": jnp.zeros((D,))}, comp_cfg))
    with pytest.raises(ValueError, match="structure mismatch"):
        checkpoint.restore_state(
            path, init_state({"w": jnp.zeros((D,))}, plain_cfg))


# ---------------------------------------------------------------------------
# plane-vs-tree residual-stream parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,kw", [("topk", dict(compress_k=4)),
                                     ("qsgd", dict(compress_bits=4))])
def test_plane_vs_tree_compressed_parity(mode, kw):
    """On a single-leaf model the plane layout replays the compressed
    tree trajectory bit for bit — the plane's padded coordinates are
    zero in params AND residual, thresholds/seed positions coincide on
    the compact prefix, and the residual stream unpacks to the tree
    residual exactly."""
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(31), (D,))}
    man = planelib.build_manifest(p0)
    base = dict(n_agents=4, n_zeroth=2, estimator_zo="multi_rv", rv=2,
                zo_impl="fused", lr=0.25, momentum=0.5, warmup_steps=0,
                use_cosine=False, nu=1e-3, gossip="graph", topology="ring",
                compression=mode, **kw)
    states = {}
    for layout in ("tree", "plane"):
        cfg = HDOConfig(param_layout=layout, **base)
        step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=D,
                                      params_template=p0))
        st = init_state(p0, cfg)
        for t in range(3):
            st, _ = step(st, make_batches(
                jax.random.fold_in(jax.random.PRNGKey(5), t), 4))
        states[layout] = st
    tree_p = states["tree"].params["w"]
    plane_p = planelib.unpack_stacked(man, states["plane"].params)["w"]
    np.testing.assert_array_equal(np.asarray(tree_p), np.asarray(plane_p))
    tree_e = states["tree"].comm["residual"]["w"]
    plane_res = states["plane"].comm["residual"]
    plane_e = planelib.unpack_stacked(man, plane_res)["w"]
    np.testing.assert_array_equal(np.asarray(tree_e), np.asarray(plane_e))
    # pads stay invariantly zero in the residual stream too
    if man.dim > D:
        pads = np.asarray(plane_res)[:, D:]
        np.testing.assert_array_equal(pads, np.zeros_like(pads))


# ---------------------------------------------------------------------------
# ppermute lowering parity (multi-device subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_compressed_graph_ppermute_parity_subprocess():
    """CompressedGraphPpermuteMixer == CompressedGraphMixer on the fresh
    path (identical payload seeds and thresholds by construction, so
    only the neighbor-accumulation association differs across the two
    lowerings — allclose, on both the kernel and jnp routes), and
    end-to-end through the jitted HDO step."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import repro.topology as T
        from repro.configs.base import HDOConfig
        from repro.core import build_hdo_step, init_state
        from repro.topology import compress as C
        mesh = jax.make_mesh((8,), ("data",))
        n, d = 8, 12
        topo = T.hypercube(n)
        X = {"w": jax.random.normal(jax.random.PRNGKey(1), (n, 24))}
        for comp in (C.Compressor("topk", k=5), C.Compressor("qsgd", bits=4)):
            gm = T.CompressedGraphMixer(topo, compressor=comp, seed=3)
            exp, ecomm = gm.mix(X, key=None, step=jnp.int32(2),
                                comm=gm.init_comm(X))
            for uk in (False, True):
                pm = T.CompressedGraphPpermuteMixer(
                    topo, mesh, ("data",), compressor=comp, seed=3,
                    use_kernel=uk)
                got, gcomm = jax.jit(
                    lambda p, c: pm.mix(p, key=None, step=jnp.int32(2),
                                        comm=c))(X, pm.init_comm(X))
                np.testing.assert_allclose(np.asarray(got["w"]),
                                           np.asarray(exp["w"]), atol=1e-6)
                np.testing.assert_allclose(
                    np.asarray(gcomm["residual"]["w"]),
                    np.asarray(ecomm["residual"]["w"]), atol=1e-6)
        w_true = jax.random.normal(jax.random.PRNGKey(42), (d,))
        def loss_fn(params, batch):
            return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)
        outs = {}
        for mode in ("graph", "graph_ppermute"):
            cfg = HDOConfig(n_agents=n, n_zeroth=4, gossip=mode,
                            topology="hypercube", compression="topk",
                            compress_k=4, lr=0.05, momentum=0.0,
                            warmup_steps=0, use_cosine=False, rv=2, nu=1e-3)
            step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=d,
                                          mesh=mesh,
                                          population_axes=("data",)))
            state = init_state({"w": jnp.zeros((d,))}, cfg)
            for t in range(10):
                k = jax.random.fold_in(jax.random.PRNGKey(9), t)
                Xb = jax.random.normal(k, (n, 8, d))
                state, m = step(state, {"X": Xb, "y": Xb @ w_true})
            outs[mode] = np.asarray(state.params["w"])
        # top-k selection is discontinuous: one ulp of association noise
        # can flip which coordinate a payload keeps, so the multi-round
        # trajectories only agree coarsely — the bit-level contract is
        # the single-round mixer parity above; this leg catches gross
        # wiring bugs (wrong neighbor routing => O(1) errors)
        np.testing.assert_allclose(outs["graph"], outs["graph_ppermute"],
                                   atol=2e-2)
        np.testing.assert_allclose(outs["graph"].mean(0),
                                   outs["graph_ppermute"].mean(0), atol=2e-3)
        print("COMPRESSED_PPERMUTE_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=420, env=env, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COMPRESSED_PPERMUTE_OK" in proc.stdout


# ---------------------------------------------------------------------------
# hypothesis property variants (CI runs them; hypothesis-less
# containers skip exactly these through the conftest gate)
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    settings.register_profile("compress", max_examples=25, deadline=None)
    settings.load_profile("compress")

    @given(d=st.integers(2, 64), k=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    def test_prop_topk_support_size(d, k, seed):
        comp = compresslib.Compressor("topk", k=k)
        u = jax.random.normal(jax.random.PRNGKey(seed), (1, d))
        m = np.asarray(comp.apply(u, comp.thresholds(u),
                                  jnp.zeros((1,), jnp.uint32)))
        assert (m != 0).sum() == min(k, d)

    @given(d=st.integers(2, 64), bits=st.integers(1, 8),
           seed=st.integers(0, 2**31 - 1), pseed=st.integers(0, 2**31 - 1))
    def test_prop_qsgd_bounded_and_sign_preserving(d, bits, seed, pseed):
        comp = compresslib.Compressor("qsgd", bits=bits)
        u = jax.random.normal(jax.random.PRNGKey(pseed), (1, d))
        thr = comp.thresholds(u)
        m = np.asarray(comp.apply(u, thr, jnp.full((1,), seed % (1 << 32),
                                                   jnp.uint32)))
        assert np.abs(m).max() <= float(thr[0]) * (1 + 1e-6)
        assert np.all(m * np.asarray(u) >= 0.0)

    @given(seed=st.integers(0, 2**31 - 1),
           mode=st.sampled_from(["topk", "qsgd"]),
           step=st.integers(0, 100))
    def test_prop_error_feedback_telescopes(seed, mode, step):
        comp = (compresslib.Compressor("topk", k=3) if mode == "topk"
                else compresslib.Compressor("qsgd", bits=4))
        u = jax.random.normal(jax.random.PRNGKey(seed), (4, D))
        seeds = compresslib.payload_seeds(seed, step, 4)
        m = comp.apply(u, comp.thresholds(u), seeds)
        resid = u - m
        np.testing.assert_allclose(np.asarray(m + resid, np.float64),
                                   np.asarray(u, np.float64), atol=1e-6)
else:
    @pytest.mark.parametrize("prop", ["topk_support", "qsgd_bounded",
                                      "ef_telescoping"])
    def test_hypothesis_properties_gated(prop):
        require_hypothesis()  # records the standard skip reason
