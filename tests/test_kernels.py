"""Per-kernel shape/dtype sweeps against the ref.py jnp oracles
(Pallas interpret mode on CPU; Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.rng import counter_normal


@pytest.mark.parametrize("d", [8192, 16384, 20000, 50001])
@pytest.mark.parametrize("rv", [1, 4, 7])
def test_zo_combine_sweep(d, rv):
    coeffs = jax.random.normal(jax.random.PRNGKey(rv), (rv,))
    out = ops.zo_combine(coeffs, 99, d)
    exp = ref.zo_combine_ref(coeffs, 99, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d", [8192, 24576, 10000])
def test_zo_perturb_sweep(d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (d,), dtype)
    out = ops.zo_perturb(x, 5, 2, 1e-3)
    exp = ref.zo_perturb_ref(x, 5, 2, 1e-3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=1e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d", [8192, 10000])
@pytest.mark.parametrize("rv", [1, 3])
def test_zo_perturb_batch_sweep(d, rv, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (d,), dtype)
    out = ops.zo_perturb_batch(x, 5, rv, 1e-3)
    exp = ref.zo_perturb_batch_ref(x, 5, rv, 1e-3)
    assert out.shape == (rv, d) and out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=1e-5
    )


def test_zo_perturb_batch_rows_match_sequential():
    """Row r of the batched kernel == the sequential zo_perturb at r."""
    x = jax.random.normal(jax.random.PRNGKey(1), (8192,))
    batch = ops.zo_perturb_batch(x, 9, 4, 1e-2)
    for r in range(4):
        np.testing.assert_array_equal(
            np.asarray(batch[r]), np.asarray(ops.zo_perturb(x, 9, r, 1e-2))
        )


def test_zo_combine_bf16_out():
    coeffs = jax.random.normal(jax.random.PRNGKey(2), (4,))
    out = ops.zo_combine(coeffs, 11, 8192, out_dtype=jnp.bfloat16)
    exp = ref.zo_combine_ref(coeffs, 11, 8192)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp), atol=0.05, rtol=0.05
    )


@pytest.mark.parametrize("d", [8192, 16384, 20000, 50001])
@pytest.mark.parametrize("r", [0, 3])
def test_zo_tangent_matches_ref_bit_exact(d, r):
    """ops.zo_tangent == its jnp oracle bit-for-bit (shared counter
    stream), across block boundaries and non-multiple-of-BLOCK padding."""
    out = ops.zo_tangent(99, r, d)
    exp = ref.zo_tangent_ref(99, r, d)
    assert out.shape == (d,) and out.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("d", [8192, 24576, 20000, 50001])
def test_zo_tangent_equals_perturb_displacement(d):
    """u_r == (zo_perturb(x, seed, r, nu) - x) / nu on the same stream.

    At x = 0, nu = 1 the identity is bit-exact; for generic x it holds
    to f32 rounding of the add/sub round-trip.
    """
    u = ops.zo_tangent(7, 1, d)
    zero = jnp.zeros((d,))
    np.testing.assert_array_equal(
        np.asarray(ops.zo_perturb(zero, 7, 1, 1.0)), np.asarray(u)
    )
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    nu = 1e-2
    fd = (ops.zo_perturb(x, 7, 1, nu) - x) / nu
    np.testing.assert_allclose(np.asarray(fd), np.asarray(u), atol=1e-3)


def test_zo_tangent_bf16_out():
    u32 = ops.zo_tangent(11, 2, 8192)
    u16 = ops.zo_tangent(11, 2, 8192, dtype=jnp.bfloat16)
    assert u16.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(u16, np.float32), np.asarray(u32), atol=0.05, rtol=0.05
    )


def test_zo_tangent_stream_matches_combine():
    """zo_combine with a one-hot coefficient reproduces u_r / rv —
    tangent generation and estimate assembly share one RNG stream."""
    d, rv = 8192, 4
    for r in range(rv):
        coeffs = jnp.zeros((rv,)).at[r].set(1.0)
        g = ops.zo_combine(coeffs, 13, d)
        u = ops.zo_tangent(13, r, d)
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(u) / rv, atol=1e-6
        )


def test_zo_perturb_distinct_r_distinct_noise():
    x = jnp.zeros((8192,))
    a = ops.zo_perturb(x, 5, 0, 1.0)
    b = ops.zo_perturb(x, 5, 1, 1.0)
    assert float(jnp.max(jnp.abs(a - b))) > 0.1


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_avg_sweep(dtype):
    for d in (8192, 12345):
        x = jax.random.normal(jax.random.PRNGKey(1), (d,), dtype)
        y = jax.random.normal(jax.random.PRNGKey(2), (d,), dtype)
        out = ops.gossip_avg(x, y)
        exp = ref.gossip_avg_ref(x, y)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=1e-6
        )


@pytest.mark.parametrize("d", [100, 8191, 12345, 50001])
def test_gossip_avg_raw_kernel_tail_padding(d):
    """The raw kernel (not just the ops wrapper) accepts any d — the
    d % BLOCK hard-assert is gone; padding lives in the kernel module
    like the ZO kernels."""
    from repro.kernels import gossip_avg as _gossip

    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    y = jax.random.normal(jax.random.PRNGKey(2), (d,))
    out = _gossip.gossip_avg(x, y, interpret=True)
    assert out.shape == (d,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gossip_avg_ref(x, y)),
                               atol=1e-6)


@pytest.mark.parametrize("d", [8192, 12345, 24576, 50001])
@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_bit_exact_vs_ref(d, k, dtype):
    """ops.gossip_mix == ref.gossip_mix_ref bit-for-bit across block
    boundaries, non-aligned tails, degrees, and dtypes.

    Neighbor weights are powers of two (the hypercube/matching MH
    weights), so every product is exactly representable and LLVM FMA
    contraction — which varies with fusion clustering between the two
    compiled graphs — cannot change the rounding.
    """
    x = jax.random.normal(jax.random.PRNGKey(d + k), (d,), dtype)
    nbrs = jax.random.normal(jax.random.PRNGKey(d + k + 1), (k, d), dtype)
    w = jnp.asarray([2.0 ** -(s % 3 + 2) for s in range(k)])
    w_self = 1.0 - float(w.sum())
    out = ops.gossip_mix(x, nbrs, w_self, w)
    exp = jax.jit(ref.gossip_mix_ref)(x, nbrs, w_self, w)
    assert out.shape == (d,) and out.dtype == dtype
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(exp, np.float32))


@pytest.mark.parametrize("d", [8192, 20000])
@pytest.mark.parametrize("k", [3, 5])
def test_gossip_mix_generic_weights_close(d, k):
    """Generic (non-dyadic) weights: parity to 1 ulp (FMA contraction
    may differ between the separately-compiled graphs on CPU)."""
    key = jax.random.PRNGKey(k)
    x = jax.random.normal(key, (d,))
    nbrs = jax.random.normal(jax.random.fold_in(key, 1), (k, d))
    w = jax.random.uniform(jax.random.fold_in(key, 2), (k,)) * (0.9 / k)
    w_self = 1.0 - float(w.sum())
    out = ops.gossip_mix(x, nbrs, w_self, w)
    exp = jax.jit(ref.gossip_mix_ref)(x, nbrs, w_self, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


@pytest.mark.parametrize("d", [8192, 12345, 24576, 1000])
@pytest.mark.parametrize("mdt", [jnp.float32, jnp.bfloat16])
def test_opt_apply_bit_exact_vs_ref(d, mdt):
    """ops.opt_apply == ref.opt_apply_ref bit-for-bit across block
    boundaries, non-aligned tails, and momentum dtypes.

    beta and lr are dyadic (1/2, 1/4), so every product is exactly
    representable and LLVM FMA contraction — which varies with fusion
    clustering between the two compiled graphs — cannot change the
    rounding.
    """
    p = jax.random.normal(jax.random.PRNGKey(d), (d,))
    g = jax.random.normal(jax.random.PRNGKey(d + 1), (d,))
    m = (jax.random.normal(jax.random.PRNGKey(d + 2), (d,)) * 0.1).astype(mdt)
    po, mo = ops.opt_apply(p, g, m, 0.25, 0.5)
    pe, me = jax.jit(ref.opt_apply_ref)(p, g, m, 0.25, 0.5)
    assert po.shape == (d,) and po.dtype == p.dtype and mo.dtype == mdt
    np.testing.assert_array_equal(np.asarray(po), np.asarray(pe))
    np.testing.assert_array_equal(np.asarray(mo, np.float32),
                                  np.asarray(me, np.float32))


def test_opt_apply_generic_weights_close():
    """Generic (non-dyadic) beta/lr: parity to 1 ulp (FMA contraction
    may differ between the separately-compiled graphs on CPU)."""
    d = 20000
    p = jax.random.normal(jax.random.PRNGKey(0), (d,))
    g = jax.random.normal(jax.random.PRNGKey(1), (d,))
    m = jax.random.normal(jax.random.PRNGKey(2), (d,)) * 0.1
    po, mo = ops.opt_apply(p, g, m, 0.0123, 0.9)
    pe, me = jax.jit(ref.opt_apply_ref)(p, g, m, 0.0123, 0.9)
    np.testing.assert_allclose(np.asarray(po), np.asarray(pe), atol=1e-6)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(me), atol=1e-6)


def test_opt_apply_bf16_momentum_rounds_before_param_update():
    """The contract that makes the kernel == the tree path for
    momentum_dtype="bfloat16": the momentum is rounded to bf16 and the
    *rounded* value drives the parameter update."""
    d = 8192
    p = jax.random.normal(jax.random.PRNGKey(3), (d,))
    g = jax.random.normal(jax.random.PRNGKey(4), (d,))
    m = (jax.random.normal(jax.random.PRNGKey(5), (d,)) * 0.1).astype(jnp.bfloat16)
    po, mo = ops.opt_apply(p, g, m, 0.25, 0.5)
    nm = (0.5 * m.astype(jnp.float32) + 0.5 * g.astype(jnp.float32)
          ).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(mo, np.float32),
                                  np.asarray(nm, np.float32))
    np.testing.assert_array_equal(
        np.asarray(po),
        np.asarray((p - 0.25 * nm.astype(jnp.float32)).astype(p.dtype)))


def test_gossip_mix_generalizes_gossip_avg():
    """k=1 with (1/2, 1/2) weights is exactly the pairwise average."""
    x = jax.random.normal(jax.random.PRNGKey(5), (20000,))
    y = jax.random.normal(jax.random.PRNGKey(6), (20000,))
    mix = ops.gossip_mix(x, y[None], 0.5, jnp.asarray([0.5]))
    avg = ops.gossip_avg(x, y)
    np.testing.assert_allclose(np.asarray(mix), np.asarray(avg), atol=1e-7)


@pytest.mark.parametrize("shape", [(1, 64, 2, 16, 8), (2, 128, 3, 32, 16), (1, 256, 1, 8, 32)])
@pytest.mark.parametrize("chunk", [32, 64])
def test_ssd_scan_sweep(shape, chunk):
    b, s, h, p, n = shape
    ks = jax.random.split(jax.random.PRNGKey(s), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    exp = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3, rtol=2e-3)


def test_ssd_scan_bf16():
    b, s, h, p, n = 1, 128, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.bfloat16)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n), jnp.bfloat16)
    Cm = jax.random.normal(ks[4], (b, s, n), jnp.bfloat16)
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    exp = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=0.15, rtol=0.15
    )


def test_counter_normal_statistics():
    idx = jnp.arange(1 << 18, dtype=jnp.uint32)
    u = counter_normal(jnp.uint32(3), idx, jnp.uint32(0))
    assert abs(float(u.mean())) < 0.01
    assert abs(float(u.std()) - 1.0) < 0.01
    # kurtosis-ish sanity: P(|u|>3) ~ 0.0027
    frac = float((jnp.abs(u) > 3.0).mean())
    assert 0.0005 < frac < 0.008


def test_counter_normal_decorrelated_across_r():
    idx = jnp.arange(1 << 16, dtype=jnp.uint32)
    a = counter_normal(jnp.uint32(3), idx, jnp.uint32(0))
    b = counter_normal(jnp.uint32(3), idx, jnp.uint32(1))
    corr = float(jnp.corrcoef(a, b)[0, 1])
    assert abs(corr) < 0.02
