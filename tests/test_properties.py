"""Hypothesis property-based tests on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis

# single importorskip gate (tests/conftest.py): environments without
# the hypothesis test extra (e.g. the pinned CPU container) skip this
# file rather than breaking collection of the whole suite; CI runs it
hypothesis = require_hypothesis()
from hypothesis import given, settings, strategies as st

from repro import topology as topolib
from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, estimators, flatzo, gossip, init_state
from repro.core.schedules import warmup_cosine
from repro.kernels.rng import counter_normal
from repro.launch.hlo_analysis import HloCostModel, _shape_elems_bytes

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# ZO estimator contracts: tree and fused are distribution-equivalent,
# not bit-equal (flatzo.py docstring) — both must satisfy E[g] ~ grad F
# on a quadratic with closed-form gradient, within CLT tolerance.
# ---------------------------------------------------------------------------

_EST_D = 8
_EST_RV = 8
_EST_SAMPLES = 256


def _est_quadratic():
    key = jax.random.PRNGKey(17)
    A = jax.random.normal(key, (_EST_D, _EST_D))
    A = A @ A.T / _EST_D + jnp.eye(_EST_D)
    b = jax.random.normal(jax.random.fold_in(key, 1), (_EST_D,))
    x0 = jax.random.normal(jax.random.fold_in(key, 2), (_EST_D,))
    loss = lambda p: 0.5 * p["x"] @ A @ p["x"] - b @ p["x"]
    return loss, {"x": x0}, A @ x0 - b


_EST_LOSS, _EST_P0, _EST_GRAD = _est_quadratic()
_EST_CACHE = {}


def _batched_estimator(impl, kind):
    """(n_keys,) keys -> (n_keys, d) estimates; jitted+vmapped, cached
    so each (impl, kind) compiles once across hypothesis examples."""
    if (impl, kind) not in _EST_CACHE:
        engine = estimators.zo_estimate if impl == "tree" else flatzo.flat_zo_estimate
        one = lambda k: engine(_EST_LOSS, _EST_P0, k, kind=kind, rv=_EST_RV,
                               nu=1e-4)[1]["x"]
        _EST_CACHE[(impl, kind)] = jax.jit(jax.vmap(one))
    return _EST_CACHE[(impl, kind)]


@pytest.mark.parametrize("impl", ["tree", "fused"])
@pytest.mark.parametrize("kind", ["multi_rv", "fwd_grad"])
@given(seed=st.integers(0, 2**16))
@settings(max_examples=5, deadline=None)
def test_zo_estimator_unbiased(impl, kind, seed):
    """E[g] ~ grad F across seeds.  Relative error of the sample mean is
    ~ sqrt((d+1)/(N*rv)) ~ 0.066 here; 0.3 is a >4-sigma budget."""
    est = _batched_estimator(impl, kind)
    keys = jax.random.split(jax.random.PRNGKey(seed), _EST_SAMPLES)
    g_bar = est(keys).mean(0)
    rel = float(jnp.linalg.norm(g_bar - _EST_GRAD) / jnp.linalg.norm(_EST_GRAD))
    assert rel < 0.3, (impl, kind, rel)


@pytest.mark.parametrize("kind", ["multi_rv", "fwd_grad"])
@given(seed=st.integers(0, 2**16))
@settings(max_examples=5, deadline=None)
def test_tree_and_fused_means_agree(kind, seed):
    """Tree and fused draw from different RNGs, so single estimates
    differ — but their sample means must land on the same gradient
    (distribution equivalence, the flatzo contract)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), _EST_SAMPLES)
    g_tree = _batched_estimator("tree", kind)(keys).mean(0)
    g_fused = _batched_estimator("fused", kind)(keys).mean(0)
    scale = float(jnp.linalg.norm(_EST_GRAD))
    assert float(jnp.linalg.norm(g_tree - g_fused)) / scale < 0.5, kind


@given(
    n=st.sampled_from([2, 4, 6, 8, 16]),
    seed=st.integers(0, 2**16),
    shape=st.sampled_from([(3,), (4, 5), (2, 3, 2)]),
)
def test_gossip_preserves_mean_and_contracts_gamma(n, seed, shape):
    key = jax.random.PRNGKey(seed)
    X = {"w": jax.random.normal(key, (n,) + shape)}
    partner = gossip.sample_matching(jax.random.fold_in(key, 1), n)
    Y = gossip.mix_pairwise(X, partner)
    np.testing.assert_allclose(np.asarray(Y["w"].mean(0)), np.asarray(X["w"].mean(0)),
                               atol=1e-5)

    def gamma(t):
        v = t["w"]
        return float(((v - v.mean(0, keepdims=True)) ** 2).sum())

    assert gamma(Y) <= gamma(X) + 1e-5


@given(n=st.sampled_from([2, 4, 8, 12, 16, 32]))
def test_round_robin_is_tournament(n):
    sched = gossip.round_robin_schedule(n)
    met = set()
    for r in range(n - 1):
        p = sched[r]
        assert (p[p] == np.arange(n)).all()
        assert (p != np.arange(n)).all()
        met |= {(min(i, int(p[i])), max(i, int(p[i]))) for i in range(n)}
    assert len(met) == n * (n - 1) // 2


# ---------------------------------------------------------------------------
# heterogeneous-population contract: a per-agent override with all-equal
# values is BIT-IDENTICAL to the homogeneous scalar path — the collapse
# contract of core/population.py (deterministic grid variant lives in
# tests/test_population.py so the pinned container exercises it too)
# ---------------------------------------------------------------------------

_POP_D = 6


def _pop_loss(params, batch):
    return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)


def _pop_batches(key, n):
    X = jax.random.normal(key, (n, 4, _POP_D))
    return {"X": X, "y": X @ jnp.arange(1.0, _POP_D + 1.0)}


@given(
    n0=st.integers(1, 3),
    n1=st.integers(0, 2),
    kind=st.sampled_from(["multi_rv", "fwd_grad", "biased_2pt"]),
    impl=st.sampled_from(["tree", "fused"]),
    dispatch=st.sampled_from(["select", "split"]),
    sigma=st.sampled_from([1e-4, 1e-3, 1e-2]),
    rv=st.integers(1, 3),
    lr=st.sampled_from([0.01, 0.05]),
)
@settings(max_examples=8, deadline=None)
def test_all_equal_heterogeneous_bit_identical_to_homogeneous(
        n0, n1, kind, impl, dispatch, sigma, rv, lr):
    n = n0 + n1
    hom = HDOConfig(n_agents=n, n_zeroth=n0, estimator_zo=kind, zo_impl=impl,
                    dispatch=dispatch, rv=rv, nu=sigma, lr=lr, gossip="dense",
                    momentum=0.9, warmup_steps=0, use_cosine=False)
    het = dataclasses.replace(hom, sigmas=(sigma,) * n0, rvs=(rv,) * n0,
                              lrs=(lr,) * n, estimators_zo=(kind,) * n0)
    state1 = state2 = init_state({"w": jnp.zeros((_POP_D,))}, hom)
    step_hom = jax.jit(build_hdo_step(_pop_loss, hom, param_dim=_POP_D))
    step_het = jax.jit(build_hdo_step(_pop_loss, het, param_dim=_POP_D))
    for t in range(2):
        b = _pop_batches(jax.random.fold_in(jax.random.PRNGKey(0), t), n)
        state1, m1 = step_hom(state1, b)
        state2, m2 = step_het(state2, b)
    assert set(m1) == set(m2)
    np.testing.assert_array_equal(np.asarray(state1.params["w"]),
                                  np.asarray(state2.params["w"]))
    np.testing.assert_array_equal(np.asarray(state1.opt_state["w"]),
                                  np.asarray(state2.opt_state["w"]))
    for k in m1:
        np.testing.assert_array_equal(np.asarray(m1[k]), np.asarray(m2[k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# graph-topology gossip invariants (repro.topology)
# ---------------------------------------------------------------------------


@given(
    n=st.sampled_from([2, 4, 6, 8, 9, 12, 16]),
    family=st.sampled_from(["ring", "torus", "hypercube", "erdos_renyi"]),
    seed=st.integers(0, 2**10),
)
@settings(max_examples=25, deadline=None)
def test_topology_mixing_matrix_symmetric_doubly_stochastic(n, family, seed):
    """Metropolis–Hastings weights are symmetric doubly-stochastic and
    nonnegative for every graph family, size, and random sample."""
    if family == "hypercube" and (n & (n - 1)):
        n = 8
    if family == "torus" and n in (2, 4, 7, 9):
        n = 12
    topo = topolib.make_topology(family, n, p=0.5, seed=seed)
    W = topo.mixing_matrix()
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-6)
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-6)
    assert (W >= 0).all()
    # second eigenvalue strictly inside the unit disc => consensus
    assert topolib.slem(topo) < 1.0 - 1e-9


@given(
    n=st.sampled_from([4, 6, 8, 12]),
    gossip_mode=st.sampled_from(["dense", "rr_static", "all_reduce", "none", "graph"]),
    topo=st.sampled_from(["ring", "erdos_renyi", "tv_round_robin"]),
    seed=st.integers(0, 2**16),
    step=st.integers(0, 30),
    shape=st.sampled_from([(3,), (4, 5), (2, 3, 2)]),
)
@settings(max_examples=25, deadline=None)
def test_every_mixer_preserves_population_mean(n, gossip_mode, topo, seed, step, shape):
    """Every Mixer — legacy modes and graph topologies — is
    doubly-stochastic mixing: the population mean never moves."""
    cfg = HDOConfig(n_agents=n, n_zeroth=0, gossip=gossip_mode, topology=topo,
                    topology_p=0.6, topology_rounds=3)
    mixer = topolib.make_mixer(cfg)
    X = {"w": jax.random.normal(jax.random.PRNGKey(seed), (n,) + shape)}
    Y = mixer(X, key=jax.random.PRNGKey(seed + 1), step=jnp.int32(step))
    np.testing.assert_allclose(np.asarray(Y["w"].mean(0)), np.asarray(X["w"].mean(0)),
                               atol=1e-5)


@given(
    lr=st.floats(1e-4, 1.0),
    warm=st.integers(0, 50),
    total=st.integers(51, 500),
    t=st.integers(0, 600),
)
def test_schedule_bounded(lr, warm, total, t):
    s = warmup_cosine(lr, warm, total)
    v = float(s(t))
    assert 0.0 <= v <= lr * (1 + 1e-6)


@given(seed=st.integers(0, 2**20), r=st.integers(0, 255))
def test_counter_normal_deterministic(seed, r):
    idx = jnp.arange(256, dtype=jnp.uint32)
    a = counter_normal(jnp.uint32(seed), idx, jnp.uint32(r))
    b = counter_normal(jnp.uint32(seed), idx, jnp.uint32(r))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert bool(jnp.all(jnp.isfinite(a)))


@given(
    dims=st.lists(st.integers(1, 64), min_size=0, max_size=4),
    dtype=st.sampled_from(["f32", "bf16", "s32", "pred"]),
)
def test_shape_parser(dims, dtype):
    s = f"{dtype}[{','.join(map(str, dims))}]{{0}}"
    elems, byts = _shape_elems_bytes(s)
    exp = int(np.prod(dims)) if dims else 1
    assert elems == exp
    sizes = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1}
    assert byts == exp * sizes[dtype]


@given(trip=st.integers(1, 100), m=st.integers(1, 32), k=st.integers(1, 32))
def test_hlo_cost_model_while_scaling(trip, m, k):
    """Synthetic HLO: while(trip) around one dot -> flops = trip * dot."""
    hlo = f"""
HloModule synthetic

%body (p: (s32[], f32[{m},{k}])) -> (s32[], f32[{m},{k}]) {{
  %p = (s32[], f32[{m},{k}]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[{m},{k}] get-tuple-element(%p), index=1
  %w = f32[{k},{k}] constant(0)
  %d = f32[{m},{k}] dot(%g1, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  ROOT %t = (s32[], f32[{m},{k}]) tuple(%g0, %d)
}}

%cond (p: (s32[], f32[{m},{k}])) -> pred[] {{
  %p = (s32[], f32[{m},{k}]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant({trip})
  ROOT %lt = pred[] compare(%g0, %c), direction=LT
}}

ENTRY %main (x: f32[{m},{k}]) -> f32[{m},{k}] {{
  %x = f32[{m},{k}] parameter(0)
  %i = s32[] constant(0)
  %t0 = (s32[], f32[{m},{k}]) tuple(%i, %x)
  %w0 = (s32[], f32[{m},{k}]) while(%t0), condition=%cond, body=%body, backend_config={{"known_trip_count":{{"n":"{trip}"}}}}
  ROOT %out = f32[{m},{k}] get-tuple-element(%w0), index=1
}}
"""
    model = HloCostModel(hlo)
    cost = model.entry_cost()
    expected_dot = 2 * m * k * k
    assert cost.flops >= trip * expected_dot
    assert cost.flops <= trip * (expected_dot + m * k + 8) + 8
