"""Whisper-base — encoder-decoder audio backbone, conv frontend stubbed.

[arXiv:2212.04356]  The mel+conv feature extractor is a stub: the dry-run
``input_specs()`` provides (batch, 1500, 512) precomputed frame
embeddings (the allowed modality-frontend carve-out).
"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "whisper-base"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=51_865,
        mlp_activation="gelu",
        is_encoder_decoder=True,
        num_encoder_layers=6,
        encoder_seq=1500,
        qkv_bias=True,
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        mlp_activation="gelu",
        is_encoder_decoder=True,
        num_encoder_layers=2,
        encoder_seq=64,
        qkv_bias=True,
        tie_embeddings=True,
        source="arXiv:2212.04356 (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
