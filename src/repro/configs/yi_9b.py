"""Yi-9B — llama-arch dense GQA (kv=4). [arXiv:2403.04652]"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "yi-9b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=48,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        d_ff=11_008,
        vocab_size=64_000,
        mlp_activation="swiglu",
        source="arXiv:2403.04652",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=344,
        vocab_size=512,
        mlp_activation="swiglu",
        source="arXiv:2403.04652 (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
