"""Gemma2-9B — dense, local+global alternating attention, logit softcaps.

[arXiv:2408.00118]
"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "gemma2-9b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        d_ff=14_336,
        vocab_size=256_000,
        head_dim=256,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        sliding_window=4096,
        local_global_period=2,  # alternate local / global
        mlp_activation="swiglu",
        tie_embeddings=True,
        source="arXiv:2408.00118",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=32,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        sliding_window=64,
        local_global_period=2,
        mlp_activation="swiglu",
        tie_embeddings=True,
        source="arXiv:2408.00118 (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
