"""Llama4-Maverick-400B-A17B — MoE 128 routed experts top-1 + shared.

[hf:meta-llama/Llama-4-Scout-17B-16E family]  Early-fusion multimodality
reduced to token embeddings for the assigned dry-run shapes.

Population placement: the 400B model cannot replicate per data-slice, so
the HDO population lives on the ``pod`` axis (2 agents multi-pod, 1
single-pod); experts are sharded over ``data`` (expert parallel) and FFN
over ``model`` (tensor parallel).
"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "llama4-maverick-400b-a17b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202_048,
        head_dim=128,
        mlp_activation="swiglu",
        num_experts=128,
        num_experts_per_tok=1,
        num_shared_experts=1,
        moe_d_ff=8192,
        moe_every=2,  # interleaved dense / MoE (maverick-style)
        rope_theta=500_000.0,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        mlp_activation="swiglu",
        num_experts=4,
        num_experts_per_tok=1,
        num_shared_experts=1,
        moe_d_ff=256,
        moe_every=2,
        source="hf:meta-llama/Llama-4-Scout-17B-16E (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(
        population_axes=("pod",),
        batch_axes=("data",),
        model_axes=("model",),
        expert_axes=("data",),
    )
