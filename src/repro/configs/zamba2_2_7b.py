"""Zamba2-2.7B — hybrid: Mamba2 backbone + shared attention block.

[arXiv:2411.15242]  One weight-tied attention+MLP block is applied every
``shared_attn_every`` Mamba2 layers (the published model's per-invocation
LoRA refinement is not reproduced; see DESIGN.md §5).
"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "zamba2-2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10_240,
        vocab_size=32_000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        conv_kernel=4,
        shared_attn_every=6,
        # full attention in the shared block by default; the long_500k
        # serving variant switches it to sliding-window (see launch).
        sliding_window=None,
        mlp_activation="gelu",
        tie_embeddings=True,
        source="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=32,
        ssm_chunk=32,
        conv_kernel=4,
        shared_attn_every=2,
        sliding_window=64,
        mlp_activation="gelu",
        tie_embeddings=True,
        source="arXiv:2411.15242 (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
