"""The paper's own experimental configurations (HDO, AAAI 2025).

These mirror the Appendix hyperparameter tables:
  - Table 1/6: CNN on MNIST          -> conv net on synthetic 28x28 images
  - Table 2:   ResNet-18 on CIFAR-10 -> conv net on synthetic 32x32 images
  - Table 3:   logistic regression on MNIST (convex case)
  - Table 4:   2-layer Transformer on Brackets (Dyck)
  - Table 5:   MLP on MNIST (rv ablation)
"""
from repro.configs.base import HDOConfig, ModelConfig


def brackets_transformer() -> ModelConfig:
    """Paper Table 4: 2 layers, 2 heads, embedding size 4 (we use a
    hardware-friendly multiple-of-4 width; paper used 4)."""
    return ModelConfig(
        name="paper-brackets-transformer",
        family="dense",
        num_layers=2,
        d_model=16,
        num_heads=2,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=8,  # ( ) PAD BOS EOS + slack
        mlp_activation="gelu",
        source="HDO AAAI-25 Table 4 (emb 4 -> 16 for lane alignment)",
    )


def hdo_brackets() -> HDOConfig:
    """Paper Table 4: 4 FO + 16 ZO, lr 0.05/0.1, momentum 0.8, rv 64."""
    return HDOConfig(
        n_agents=20,
        n_zeroth=16,
        estimator_zo="multi_rv",
        rv=64,
        lr=0.05,
        momentum=0.8,
        warmup_steps=100,
        cosine_steps=1000,
    )


def hdo_convex() -> HDOConfig:
    """Paper Table 3 (regression on MNIST): 24 FO + 256 ZO, rv 128,
    batch 2, no momentum / scheduler."""
    return HDOConfig(
        n_agents=280,
        n_zeroth=256,
        estimator_zo="multi_rv",
        rv=128,
        lr=0.01,
        momentum=0.0,
        warmup_steps=0,
        use_cosine=False,
    )


def hdo_cnn_mnist() -> HDOConfig:
    """Paper Table 1/6: lr 0.01-0.1, momentum 0.9, rv 128."""
    return HDOConfig(
        n_agents=16,
        n_zeroth=8,
        estimator_zo="multi_rv",
        rv=128,
        lr=0.01,
        momentum=0.9,
        warmup_steps=50,
        cosine_steps=1000,
    )
