"""Architecture config registry.

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` /
``get_mesh_config(arch_id)`` resolve any of the 10 assigned
architectures (plus the paper's own tasks via ``paper_tasks``).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    gemma2_9b,
    llama4_maverick,
    mamba2_780m,
    pixtral_12b,
    qwen1_5_0_5b,
    qwen1_5_4b,
    qwen2_moe_a2_7b,
    whisper_base,
    yi_9b,
    zamba2_2_7b,
)
from repro.configs.base import (
    DISPATCH_MODES,
    GOSSIP_MODES,
    INPUT_SHAPES,
    MOMENTUM_DTYPES,
    ZO_ESTIMATORS,
    ZO_IMPLS,
    HDOConfig,
    InputShape,
    MeshConfig,
    ModelConfig,
    RunConfig,
)

_MODULES = {
    qwen1_5_0_5b.ARCH_ID: qwen1_5_0_5b,
    whisper_base.ARCH_ID: whisper_base,
    pixtral_12b.ARCH_ID: pixtral_12b,
    qwen1_5_4b.ARCH_ID: qwen1_5_4b,
    gemma2_9b.ARCH_ID: gemma2_9b,
    llama4_maverick.ARCH_ID: llama4_maverick,
    mamba2_780m.ARCH_ID: mamba2_780m,
    zamba2_2_7b.ARCH_ID: zamba2_2_7b,
    yi_9b.ARCH_ID: yi_9b,
    qwen2_moe_a2_7b.ARCH_ID: qwen2_moe_a2_7b,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].full()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke()


def get_mesh_config(arch_id: str) -> MeshConfig:
    return _MODULES[arch_id].mesh()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS",
    "DISPATCH_MODES",
    "GOSSIP_MODES",
    "INPUT_SHAPES",
    "MOMENTUM_DTYPES",
    "ZO_ESTIMATORS",
    "ZO_IMPLS",
    "HDOConfig",
    "InputShape",
    "MeshConfig",
    "ModelConfig",
    "RunConfig",
    "get_config",
    "get_smoke_config",
    "get_mesh_config",
    "all_configs",
]
