"""Qwen1.5-4B — dense, QKV bias. [hf:Qwen/Qwen1.5-0.5B family card]"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "qwen1.5-4b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=40,
        d_model=2560,
        num_heads=20,
        num_kv_heads=20,
        d_ff=6912,
        vocab_size=151_936,
        qkv_bias=True,
        mlp_activation="swiglu",
        source="hf:Qwen/Qwen1.5-0.5B (4B sibling)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=160,
        num_heads=4,
        num_kv_heads=4,
        d_ff=432,
        vocab_size=512,
        qkv_bias=True,
        mlp_activation="swiglu",
        source="hf:Qwen/Qwen1.5-0.5B (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
