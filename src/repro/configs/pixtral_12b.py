"""Pixtral-12B — VLM backbone: mistral-nemo decoder + stubbed pixtral-ViT.

[hf:mistralai/Pixtral-12B-2409]  The vision encoder + projector are a
stub: ``input_specs()`` provides (batch, num_patches, d_model) patch
embeddings interleaved before the text tokens (allowed carve-out).
"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "pixtral-12b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14_336,
        vocab_size=131_072,
        head_dim=128,
        mlp_activation="swiglu",
        num_patches=256,  # stubbed image tokens prepended
        rope_theta=1_000_000.0,
        source="hf:mistralai/Pixtral-12B-2409",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=384,
        vocab_size=512,
        head_dim=32,
        mlp_activation="swiglu",
        num_patches=8,
        source="hf:mistralai/Pixtral-12B-2409 (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
