"""Configuration dataclasses for the repro framework.

Every assigned architecture gets a ``ModelConfig`` (exact published dims)
plus a ``smoke()`` reduced variant (<=2 layers, d_model<=512, <=4 experts)
used by CPU tests.  ``HDOConfig`` configures the paper's technique;
``MeshConfig`` selects the population placement on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (transformer / SSM / hybrid / MoE)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention variants
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # window size for local layers
    # pattern: how many of every `local_global_period` layers are local.
    # gemma2 alternates local/global -> period 2, 1 local.
    local_global_period: int = 0  # 0 = all global
    rope_theta: float = 10_000.0

    # MLP
    mlp_activation: str = "swiglu"  # swiglu | gelu

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: Optional[int] = None  # per-expert hidden (defaults d_ff)
    moe_every: int = 1  # MoE layer every k layers (1 = all layers MoE)
    router_aux_coef: float = 0.01

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_kernel: int = 4

    # hybrid (zamba2): shared attention block applied every k SSM layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500  # stub frame count

    # VLM (pixtral): number of stubbed image patch embeddings prepended
    num_patches: int = 0

    # norms / misc
    sandwich_norm: bool = False  # gemma2: post-sublayer norms + embed scale
    rms_eps: float = 1e-6
    # perf knobs (beyond-paper; see EXPERIMENTS.md §Perf)
    attn_remat: bool = False  # recompute attention score blocks in bwd
    decode_window_slice: bool = False  # sliding-window decode reads only the window
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # citation for the config source
    source: str = ""

    @property
    def use_rope(self) -> bool:
        # whisper uses absolute (sinusoidal / learned) positions
        return not self.is_encoder_decoder

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, ff, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = 0
        # embeddings (+ output head unless tied)
        total += V * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm",):
            total += L * self._ssm_block_params()
        elif self.family == "hybrid":
            total += L * self._ssm_block_params()
            if self.shared_attn_every:
                total += self._attn_params(d, n_q, n_kv, hd) + self._mlp_params(d, ff)
        else:
            per_layer = self._attn_params(d, n_q, n_kv, hd)
            if self.num_experts:
                eff = self.moe_d_ff or ff
                moe_layer = self.num_experts * self._mlp_params(d, eff)
                if self.num_shared_experts:
                    moe_layer += self._mlp_params(d, eff * self.num_shared_experts)
                moe_layer += d * self.num_experts  # router
                n_moe = L // self.moe_every
                n_dense = L - n_moe
                total += n_moe * (per_layer + moe_layer)
                total += n_dense * (per_layer + self._mlp_params(d, ff))
            else:
                total += L * (per_layer + self._mlp_params(d, ff))
        if self.is_encoder_decoder:
            # encoder layers + decoder cross-attn
            total += self.num_encoder_layers * (
                self._attn_params(d, n_q, n_kv, hd) + self._mlp_params(d, ff)
            )
            total += self.num_layers * self._attn_params(d, n_q, n_kv, hd)  # cross attn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-in experts)."""
        if not self.num_experts:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        eff = self.moe_d_ff or ff
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        n_moe = L // self.moe_every
        n_dense = L - n_moe
        per_attn = self._attn_params(d, self.num_heads, self.num_kv_heads, hd)
        active_moe = self.num_experts_per_tok * self._mlp_params(d, eff)
        if self.num_shared_experts:
            active_moe += self._mlp_params(d, eff * self.num_shared_experts)
        total += n_moe * (per_attn + active_moe + d * self.num_experts)
        total += n_dense * (per_attn + self._mlp_params(d, ff))
        return total

    def _attn_params(self, d, n_q, n_kv, hd) -> int:
        return d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d

    def _mlp_params(self, d, ff) -> int:
        mult = 3 if self.mlp_activation == "swiglu" else 2
        return mult * d * ff

    def _ssm_block_params(self) -> int:
        d, di, ds = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_heads
        # in_proj -> [z, x, B, C, dt]; out_proj; conv; A, D, dt_bias, norm
        in_proj = d * (2 * di + 2 * ds + nh)
        out_proj = di * d
        conv = (di + 2 * ds) * self.conv_kernel
        return in_proj + out_proj + conv + 2 * nh + di + d


# ---------------------------------------------------------------------------
# HDO (the paper's technique)
# ---------------------------------------------------------------------------

# the legal values for HDOConfig's string knobs, validated at
# construction so a typo fails at config time, not deep inside a trace.
# These tuples are the single source for every CLI ``choices=`` list.
ZO_ESTIMATORS = ("biased_1pt", "biased_2pt", "multi_rv", "fwd_grad")
ZO_IMPLS = ("tree", "fused")
OPTIMIZERS = ("sgd", "adamw")
DISPATCH_MODES = ("select", "split", "shard_cond")
GOSSIP_MODES = (
    "dense", "rr_static", "rr_ppermute", "all_reduce", "none",
    "graph", "graph_ppermute",
)
TOPOLOGIES = (
    "ring", "torus", "hypercube", "erdos_renyi",
    "tv_round_robin", "tv_erdos_renyi",
)
# gossip modes the sharded (mesh) round supports; "graph" and
# "graph_ppermute" are the same ppermute lowering under shard_map
SHARD_GOSSIP_MODES = ("none", "all_reduce", "graph", "graph_ppermute")
MOMENTUM_DTYPES = ("float32", "bfloat16")
PARAM_LAYOUTS = ("tree", "plane")
COMPRESSIONS = ("none", "topk", "qsgd")


@dataclasses.dataclass(frozen=True)
class HDOConfig:
    """Hybrid decentralized optimization population settings (Alg. 1)."""

    n_agents: int = 16
    n_zeroth: int = 8  # n0; n1 = n_agents - n_zeroth
    estimator_zo: str = "multi_rv"  # biased_1pt | biased_2pt | multi_rv | fwd_grad
    rv: int = 4  # random vectors per ZO estimate
    nu: float = 1e-4  # smoothing radius (paper: nu = eta / sqrt(d))
    nu_from_lr: bool = False  # if True use nu = lr / sqrt(d) per Theorem 1
    # -- heterogeneous populations (the paper's central setting: noisy /
    #    possibly-biased ZO agents with *different* oracles coexisting) --
    # Per-agent overrides of the scalar knobs above.  ``sigmas`` / ``rvs``
    # / ``estimators_zo`` describe the ZO cohort (length ``n_zeroth``,
    # agents 0..n0-1); ``lrs`` covers the whole population (length
    # ``n_agents``).  ``None`` means "homogeneous: every agent uses the
    # scalar knob".  ``core/population.py`` resolves these into the
    # stacked per-agent tables consumed by ``build_hdo_step``; a fully
    # uniform override is collapsed back onto the homogeneous path, so
    # all-equal values are bit-identical to not setting them (pinned by
    # tests/test_population.py).
    sigmas: Optional[Tuple[float, ...]] = None  # per-ZO-agent smoothing radius
    rvs: Optional[Tuple[int, ...]] = None  # per-ZO-agent random-vector count
    lrs: Optional[Tuple[float, ...]] = None  # per-agent base learning rate
    estimators_zo: Optional[Tuple[str, ...]] = None  # per-ZO-agent kind (mixed)
    # ZO estimator implementation:
    #   "tree"  — pytree estimators (tree_normal materializes each
    #             Gaussian u_r: O(rv*d) extra HBM traffic per estimate);
    #   "fused" — flat-parameter engine over the counter-RNG Pallas
    #             kernels: u_r regenerated in VMEM, so the Gaussian
    #             materialization cost drops to zero and only the
    #             candidate evals' own traffic remains (core/flatzo.py).
    #             Covers every estimator kind — ``fwd_grad`` runs the
    #             zo_tangent kernel + jvp path (flatzo.flat_fwd_grad).
    zo_impl: str = "tree"
    # gossip scheme: dense | rr_static | rr_ppermute | all_reduce | none
    #   | graph | graph_ppermute
    # ("rr_static" = trace-time round-robin tournament, the CPU/single-
    #  host derandomization; "rr_ppermute" = its shard_map/ppermute
    #  lowering, needs mesh + one agent per population shard; "graph" =
    #  weighted mixing-matrix gossip over a static neighbor topology
    #  (repro.topology), "graph_ppermute" = its shard_map lowering for
    #  permutation-column topologies)
    gossip: str = "dense"
    # graph-gossip knobs (used when gossip is "graph"/"graph_ppermute"):
    #   topology       — neighbor graph family (repro.topology constructors)
    #   topology_p     — Erdős–Rényi edge probability
    #   topology_seed  — seed for randomized topologies
    #   topology_rounds— cycle length for tv_erdos_renyi (tv_round_robin's
    #                    cycle is structurally n-1 tournament rounds)
    topology: str = "ring"
    topology_p: float = 0.3
    topology_seed: int = 0
    topology_rounds: int = 8
    lr: float = 0.01
    # first-moment decay of the local update: sgd momentum / adamw b1
    momentum: float = 0.9
    # local-update rule applied between the estimate and the gossip
    # phases ("sgd" is the paper's momentum-SGD; "adamw" plugs the
    # repro.optim AdamW transform into the same slot — beyond-paper)
    optimizer: str = "sgd"
    # communication-reducing local steps: H estimate+update iterations
    # per gossip round (H=1 is the paper's Algorithm 1; H>1 is periodic
    # averaging a la Omidvar et al. / Sahu et al. — the Mixer runs once
    # per round, so communication drops by 1/H per estimator pass)
    local_steps: int = 1
    # per-agent global-norm gradient clip applied before the optimizer
    # update (0 disables; uses optim.clip_by_global_norm per agent)
    clip_norm: float = 0.0
    # decoupled weight decay for optimizer="adamw" (0 = plain Adam;
    # ignored by sgd, which matches the paper's rule)
    weight_decay: float = 0.0
    warmup_steps: int = 50
    cosine_steps: int = 1000
    use_cosine: bool = True
    seed: int = 0
    # SPMD dispatch mode:
    #   "select" — computes FO+ZO everywhere and masks (paper-faithful
    #              uniform program; agents are anonymous);
    #   "split"  — slices the (sorted: ZO first) population so each
    #              agent computes ONLY its own estimator kind — with the
    #              population sharded over a mesh axis every device runs
    #              one kind (beyond-paper optimization, see §Perf).
    dispatch: str = "select"
    # first-moment accumulator dtype ("float32" paper-faithful;
    # "bfloat16" halves that state's HBM — beyond-paper memory
    # optimization).  Covers sgd momentum in both layouts and adamw
    # ``mu`` under param_layout="plane"; the adamw variance term ``nu``
    # always stays float32 (it needs the range; see core/localupdate.py)
    momentum_dtype: str = "float32"
    # persistent parameter layout of the stacked population:
    #   "tree"  — stacked model pytree (one leading-agent-axis array per
    #             leaf; the original layout, per-leaf kernel dispatch);
    #   "plane" — one contiguous BLOCK-aligned flat buffer per agent
    #             (core/plane.py): estimate/update/mix all run O(d)
    #             whole-vector passes with O(#agents) kernel dispatches,
    #             the pytree is only rebuilt at the loss/jvp boundary,
    #             and adamw rides the fused kernel.  Single-step output
    #             is pinned bit-identical to "tree" for sgd and allclose
    #             for adamw (tests/test_plane.py).
    param_layout: str = "tree"
    # -- communication-reduced + fault-tolerant gossip (graph modes) ----
    # payload compression of the gossip exchange (repro.topology.compress):
    #   "none" — raw params on the wire (bit-identical to the plain
    #            graph mixers; the pinned pass-through);
    #   "topk" — each agent broadcasts only its compress_k
    #            largest-magnitude coordinates per payload vector;
    #   "qsgd" — stochastic quantization to 2^compress_bits - 1 levels
    #            per coordinate (unbiased in expectation), scaled by the
    #            payload's inf-norm.  Both mix in difference form
    #            x_i += sum_j W_ij (q_j - q_i), which preserves the
    #            population mean exactly for ANY compressor.
    compression: str = "none"
    compress_k: int = 0  # topk: coordinates kept per payload vector
    compress_bits: int = 4  # qsgd: bits per coordinate (1..8)
    # error feedback: each agent accumulates what its compressor failed
    # to transmit (residual e_i, a new HDOState stream) and adds it to
    # the next payload — sent + residual telescopes to the raw signal
    error_feedback: bool = True
    # stale/asynchronous mixing bound tau: agents rebroadcast on a
    # staggered round-robin schedule every tau+1 rounds, so neighbors
    # mix against last-broadcast payloads at most tau rounds old
    # (0 = synchronous: fresh payloads every round)
    staleness: int = 0
    # fault-injection harness (repro.topology.faults) — per-round,
    # per-agent Bernoulli draws from a counter-derived RNG keyed on
    # (fault_seed, step, agent), so runs are exactly replayable:
    #   drop       — the agent is offline this round (sends nothing,
    #                mixes nothing; its edges vanish symmetrically)
    #   straggler  — the agent fails to refresh its broadcast buffer
    #                (neighbors keep mixing against its stale payload)
    #   byzantine  — the agent's broadcast is adversarially corrupted
    #                (scaled sign-flip by fault_byzantine_scale)
    fault_drop_rate: float = 0.0
    fault_straggler_rate: float = 0.0
    fault_byzantine_rate: float = 0.0
    fault_byzantine_scale: float = 10.0
    fault_seed: int = 0

    def __post_init__(self):
        if self.estimator_zo not in ZO_ESTIMATORS:
            raise ValueError(
                f"estimator_zo must be one of {ZO_ESTIMATORS}, got {self.estimator_zo!r}"
            )
        if self.zo_impl not in ZO_IMPLS:
            raise ValueError(f"zo_impl must be one of {ZO_IMPLS}, got {self.zo_impl!r}")
        if self.dispatch not in DISPATCH_MODES:
            raise ValueError(
                f"dispatch must be one of {DISPATCH_MODES}, got {self.dispatch!r}"
            )
        if self.gossip not in GOSSIP_MODES:
            raise ValueError(f"gossip must be one of {GOSSIP_MODES}, got {self.gossip!r}")
        if self.topology not in TOPOLOGIES:
            raise ValueError(
                f"topology must be one of {TOPOLOGIES}, got {self.topology!r}"
            )
        if not 0.0 < self.topology_p <= 1.0:
            raise ValueError(f"topology_p must lie in (0, 1], got {self.topology_p}")
        if self.topology_rounds < 1:
            raise ValueError(
                f"topology_rounds must be >= 1, got {self.topology_rounds}"
            )
        if self.optimizer not in OPTIMIZERS:
            raise ValueError(
                f"optimizer must be one of {OPTIMIZERS}, got {self.optimizer!r}"
            )
        if self.local_steps < 1:
            raise ValueError(f"local_steps must be >= 1, got {self.local_steps}")
        if self.clip_norm < 0.0:
            raise ValueError(
                f"clip_norm must be >= 0 (0 disables), got {self.clip_norm}"
            )
        if self.weight_decay < 0.0:
            raise ValueError(
                f"weight_decay must be >= 0, got {self.weight_decay}"
            )
        if self.momentum_dtype not in MOMENTUM_DTYPES:
            raise ValueError(
                f"momentum_dtype must be one of {MOMENTUM_DTYPES}, "
                f"got {self.momentum_dtype!r}"
            )
        if self.param_layout not in PARAM_LAYOUTS:
            raise ValueError(
                f"param_layout must be one of {PARAM_LAYOUTS}, "
                f"got {self.param_layout!r}"
            )
        if self.compression not in COMPRESSIONS:
            raise ValueError(
                f"compression must be one of {COMPRESSIONS}, "
                f"got {self.compression!r}"
            )
        if self.compression == "topk" and self.compress_k < 1:
            raise ValueError(
                f"compression='topk' needs compress_k >= 1 (coordinates "
                f"kept per payload vector), got {self.compress_k}"
            )
        if self.compression == "qsgd" and not 1 <= self.compress_bits <= 8:
            raise ValueError(
                f"compression='qsgd' needs compress_bits in [1, 8], "
                f"got {self.compress_bits}"
            )
        if self.staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {self.staleness}")
        for fname in ("fault_drop_rate", "fault_straggler_rate",
                      "fault_byzantine_rate"):
            r = getattr(self, fname)
            if not 0.0 <= r < 1.0:
                raise ValueError(f"{fname} must lie in [0, 1), got {r}")
        faults_on = (self.fault_drop_rate > 0 or self.fault_straggler_rate > 0
                     or self.fault_byzantine_rate > 0)
        comm_active = (self.compression != "none" or self.staleness > 0
                       or faults_on)
        if comm_active:
            if self.gossip not in ("graph", "graph_ppermute"):
                raise ValueError(
                    "compression/staleness/fault injection are built on the "
                    "graph mixers — set gossip='graph' (or 'graph_ppermute' "
                    f"for compression alone), got gossip={self.gossip!r}"
                )
            if self.topology.startswith("tv_"):
                raise ValueError(
                    "compression/staleness/fault injection need a static "
                    f"topology, got time-varying {self.topology!r}"
                )
        if self.gossip == "graph_ppermute" and (self.staleness > 0 or faults_on):
            raise ValueError(
                "gossip='graph_ppermute' supports the fresh compressed path "
                "only — staleness and fault injection need gossip='graph'"
            )
        if not 0 <= self.n_zeroth <= self.n_agents:
            raise ValueError(
                f"n_zeroth must lie in [0, n_agents={self.n_agents}], got {self.n_zeroth}"
            )
        if self.rv < 1:
            raise ValueError(f"rv must be >= 1, got {self.rv}")
        self._check_per_agent_knobs()

    def _check_per_agent_knobs(self) -> None:
        # normalize lists -> tuples so the frozen config stays hashable
        for name in ("sigmas", "rvs", "lrs", "estimators_zo"):
            v = getattr(self, name)
            if v is not None and not isinstance(v, tuple):
                object.__setattr__(self, name, tuple(v))

        def check_len(name, vals, want, cohort):
            if len(vals) != want:
                raise ValueError(
                    f"{name} must have one entry per {cohort} "
                    f"({want}), got {len(vals)}"
                )

        if self.estimators_zo is not None:
            check_len("estimators_zo", self.estimators_zo, self.n_zeroth, "ZO agent")
            for k in self.estimators_zo:
                if k not in ZO_ESTIMATORS:
                    raise ValueError(
                        f"estimators_zo entries must be one of {ZO_ESTIMATORS}, "
                        f"got {k!r}"
                    )
        if self.sigmas is not None:
            check_len("sigmas", self.sigmas, self.n_zeroth, "ZO agent")
            if any(s <= 0 for s in self.sigmas):
                raise ValueError(f"sigmas must all be > 0, got {self.sigmas}")
            if self.nu_from_lr:
                raise ValueError(
                    "sigmas conflicts with nu_from_lr=True (Theorem-1 derives "
                    "the smoothing radius from the learning rate; use lrs for "
                    "per-agent heterogeneity instead)"
                )
        if self.rvs is not None:
            check_len("rvs", self.rvs, self.n_zeroth, "ZO agent")
            if any(r < 1 for r in self.rvs):
                raise ValueError(f"rvs must all be >= 1, got {self.rvs}")
        if self.lrs is not None:
            check_len("lrs", self.lrs, self.n_agents, "agent")
            if any(lr <= 0 for lr in self.lrs):
                raise ValueError(f"lrs must all be > 0, got {self.lrs}")

    @property
    def n_first(self) -> int:
        return self.n_agents - self.n_zeroth


# ---------------------------------------------------------------------------
# Mesh / distribution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """How the HDO population and the model map onto the device mesh."""

    # axes forming the HDO population (agents). Remaining axes are used
    # for intra-agent parallelism.
    population_axes: Tuple[str, ...] = ("data",)
    # axis used for per-agent batch data parallelism (None -> population
    # axis carries the batch of its own agent only)
    batch_axes: Tuple[str, ...] = ()
    # tensor-parallel axis for d_ff / heads
    model_axes: Tuple[str, ...] = ("model",)
    # expert-parallel axis for MoE (llama4: ("data",))
    expert_axes: Tuple[str, ...] = ()
    # fsdp axis sharding the param leading dim inside an agent
    fsdp_axes: Tuple[str, ...] = ()


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Top-level config: model + HDO + mesh + shape."""

    model: ModelConfig
    hdo: HDOConfig = HDOConfig()
    mesh: MeshConfig = MeshConfig()
    shape: InputShape = INPUT_SHAPES["train_4k"]
