"""Qwen1.5-0.5B — dense, QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "qwen1.5-0.5b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=2816,
        vocab_size=151_936,
        qkv_bias=True,
        mlp_activation="swiglu",
        tie_embeddings=True,
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=352,
        vocab_size=512,
        qkv_bias=True,
        mlp_activation="swiglu",
        tie_embeddings=True,
        source="hf:Qwen/Qwen1.5-0.5B (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
