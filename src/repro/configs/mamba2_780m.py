"""Mamba2-780M — attention-free SSM, SSD (state-space duality).

[arXiv:2405.21060]
"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "mamba2-780m"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50_280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        conv_kernel=4,
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        num_layers=2,
        d_model=128,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=32,
        ssm_chunk=32,
        conv_kernel=4,
        tie_embeddings=True,
        source="arXiv:2405.21060 (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
