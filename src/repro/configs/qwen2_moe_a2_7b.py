"""Qwen2-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import MeshConfig, ModelConfig

ARCH_ID = "qwen2-moe-a2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=151_936,
        qkv_bias=True,
        mlp_activation="swiglu",
        num_experts=60,
        num_experts_per_tok=4,
        num_shared_experts=4,
        moe_d_ff=1408,
        moe_every=1,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=96,
        vocab_size=512,
        qkv_bias=True,
        mlp_activation="swiglu",
        num_experts=4,
        num_experts_per_tok=2,
        num_shared_experts=2,
        moe_d_ff=96,
        moe_every=1,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B (reduced)",
    )


def mesh() -> MeshConfig:
    return MeshConfig(population_axes=("pod", "data"), model_axes=("model",))
