"""Data substrate: synthetic tasks, Brackets (Dyck), per-agent sharding."""
from repro.data import brackets, synthetic
from repro.data.sharding import AgentBatcher, agent_data_splits

__all__ = ["brackets", "synthetic", "AgentBatcher", "agent_data_splits"]
