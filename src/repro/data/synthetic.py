"""Synthetic datasets standing in for MNIST / CIFAR-10 in the offline
container (see DESIGN.md §5) plus synthetic LM token streams for the
big-architecture smoke paths.

The classification tasks are Gaussian prototype mixtures: class k has a
fixed prototype mu_k; samples are mu_k + sigma * noise.  They are
learnable by both linear (convex case, paper Fig 2) and nonconvex
models, with tunable difficulty.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np


class PrototypeClassification:
    """MNIST-like: d-dimensional inputs, `n_classes` Gaussian prototypes."""

    def __init__(self, d: int = 64, n_classes: int = 10, noise: float = 1.0, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.d, self.n_classes, self.noise = d, n_classes, noise
        self.prototypes = rng.normal(size=(n_classes, d)).astype(np.float32)

    def sample(self, rng: np.random.Generator, n: int) -> Tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, self.n_classes, size=n)
        x = self.prototypes[y] + self.noise * rng.normal(size=(n, self.d)).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    def eval_set(self, n: int = 2048, seed: int = 1234):
        return self.sample(np.random.default_rng(seed), n)


class PrototypeImages(PrototypeClassification):
    """CIFAR-like variant returning (n, H, W, C) images."""

    def __init__(self, hw: int = 16, channels: int = 3, n_classes: int = 10, noise: float = 1.0, seed: int = 0):
        super().__init__(d=hw * hw * channels, n_classes=n_classes, noise=noise, seed=seed)
        self.hw, self.channels = hw, channels

    def sample(self, rng, n):
        x, y = super().sample(rng, n)
        return x.reshape(n, self.hw, self.hw, self.channels), y


def lm_token_stream(vocab: int, seed: int = 0):
    """Learnable synthetic LM distribution: 2nd-order Markov chain with
    a sparse transition structure (so next-token CE is reducible)."""
    rng = np.random.default_rng(seed)
    fanout = 4
    table = rng.integers(0, vocab, size=(vocab, fanout)).astype(np.int32)

    def sample(rng_s: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        toks = np.empty((batch, seq), dtype=np.int32)
        toks[:, 0] = rng_s.integers(0, vocab, size=batch)
        choice = rng_s.integers(0, fanout, size=(batch, seq))
        for t in range(1, seq):
            toks[:, t] = table[toks[:, t - 1], choice[:, t]]
        return toks

    return sample


def lm_batch(sample_fn, rng: np.random.Generator, batch: int, seq: int) -> Dict[str, np.ndarray]:
    toks = sample_fn(rng, batch, seq + 1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
