"""Per-agent data sharding for the HDO population.

Paper setup: *two copies* of the training data are distributed — one
split among the n1 first-order agents, one among the n0 zeroth-order
agents (so either sub-population alone still covers the full data).
Agents 0..n0-1 are ZO (matching ``core.hdo.zo_mask``).
"""
from __future__ import annotations

from typing import Dict, Iterator, Sequence

import numpy as np


def agent_data_splits(n_samples: int, n_zeroth: int, n_first: int, seed: int = 0):
    """Returns a list of index arrays, one per agent (ZO agents first)."""
    rng = np.random.default_rng(seed)
    shards = []
    if n_zeroth:
        perm = rng.permutation(n_samples)
        shards += [s for s in np.array_split(perm, n_zeroth)]
    if n_first:
        perm = rng.permutation(n_samples)
        shards += [s for s in np.array_split(perm, n_first)]
    return shards


class AgentBatcher:
    """Cycles per-agent minibatches from a fixed dataset."""

    def __init__(self, arrays: Dict[str, np.ndarray], n_zeroth: int, n_first: int, batch: int, seed: int = 0):
        n = len(next(iter(arrays.values())))
        self.arrays = arrays
        self.batch = batch
        self.shards = agent_data_splits(n, n_zeroth, n_first, seed)
        self.rng = np.random.default_rng(seed + 1)

    def next_batches(self) -> Dict[str, np.ndarray]:
        """Leaves shaped (n_agents, batch, ...)."""
        out = {k: [] for k in self.arrays}
        for shard in self.shards:
            idx = self.rng.choice(shard, size=self.batch, replace=len(shard) < self.batch)
            for k, a in self.arrays.items():
                out[k].append(a[idx])
        return {k: np.stack(v) for k, v in out.items()}
