"""The paper's "Brackets" (Dyck-1) dataset, generated exactly as
described: sequences of '(' / ')'; the task is to classify whether the
whole sequence is correctly bracketed (every opener has a closer).

Paper: 25,600 train / 2,560 validation samples
(Ebrahimi, Gelda & Zhang 2020 motivate Dyck as a CFL probe).

Token ids: 0 PAD, 1 '(', 2 ')', 3 CLS-query, 4 label-false, 5 label-true.
The LM-style interface marks every label position -1 except the final
CLS position, whose gold token is 4/5 — so the same cross-entropy loss
used everywhere doubles as the sequence classifier.
"""
from __future__ import annotations

import numpy as np

PAD, OPEN, CLOSE, CLS, LBL_FALSE, LBL_TRUE = 0, 1, 2, 3, 4, 5
VOCAB = 8


def _balanced(rng: np.random.Generator, n_pairs: int) -> np.ndarray:
    """Random balanced Dyck-1 word of length 2*n_pairs (random walk
    constrained to stay non-negative and end at zero)."""
    seq = []
    opens = closes = 0
    for _ in range(2 * n_pairs):
        can_open = opens < n_pairs
        can_close = closes < opens
        if can_open and can_close:
            go_open = rng.random() < 0.5
        else:
            go_open = can_open
        if go_open:
            seq.append(OPEN)
            opens += 1
        else:
            seq.append(CLOSE)
            closes += 1
    return np.asarray(seq, dtype=np.int32)


def _corrupt(rng: np.random.Generator, seq: np.ndarray) -> np.ndarray:
    """Flip brackets until the sequence is invalid."""
    out = seq.copy()
    while True:
        i = rng.integers(len(out))
        out[i] = OPEN + CLOSE - out[i]
        if not is_valid(out):
            return out


def is_valid(seq: np.ndarray) -> bool:
    depth = 0
    for s in seq:
        if s == OPEN:
            depth += 1
        elif s == CLOSE:
            depth -= 1
            if depth < 0:
                return False
    return depth == 0


def make_dataset(
    n_samples: int = 25_600,
    seq_len: int = 32,
    seed: int = 0,
):
    """Returns (tokens (N, seq_len), labels (N, seq_len)) LM-style.

    tokens = brackets + CLS; labels = -1 except at the CLS position
    where the gold is LBL_TRUE / LBL_FALSE.
    """
    rng = np.random.default_rng(seed)
    n_pairs = (seq_len - 1) // 2
    toks = np.zeros((n_samples, seq_len), dtype=np.int32)
    labs = np.full((n_samples, seq_len), -1, dtype=np.int32)
    for i in range(n_samples):
        seq = _balanced(rng, n_pairs)
        positive = rng.random() < 0.5
        if not positive:
            seq = _corrupt(rng, seq)
        L = len(seq)
        toks[i, :L] = seq
        toks[i, L] = CLS
        labs[i, L] = LBL_TRUE if positive else LBL_FALSE
    return toks, labs


def accuracy(logits_at_cls: np.ndarray, gold: np.ndarray) -> float:
    """logits_at_cls: (N, V) at the CLS position; gold: (N,) in {4,5}."""
    pred = np.where(logits_at_cls[:, LBL_TRUE] > logits_at_cls[:, LBL_FALSE], LBL_TRUE, LBL_FALSE)
    return float((pred == gold).mean())
