"""Partition rules: map every parameter / batch / cache leaf to a
``PartitionSpec`` over the production mesh.

Population placement (DESIGN.md §3): parameters carry a leading
``n_agents`` axis sharded over ``MeshConfig.population_axes``; within an
agent, tensor-parallel over ``model_axes`` and (MoE) expert-parallel
over ``expert_axes``.  Every rule checks divisibility against the mesh
so reduced smoke configs on 1 device fall back to replication
automatically.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig

PyTree = Any

# leaf names that shard their LAST dim over model axes
_LAST_MODEL = {"wq", "wk", "wv", "wi", "wg", "bq", "bk", "bv", "in_proj", "conv_w", "conv_b", "lm_head"}
# leaf names that shard their FIRST (non-population) dim over model axes
_FIRST_MODEL = {"wo", "out_proj"}
# replicated small leaves
_REPLICATED = {"ln", "ln1", "ln2", "lnx", "ln1_post", "ln2_post", "final_norm",
               "enc_final_norm", "A_log", "D", "dt_bias", "router"}


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _maybe(axes: Tuple[str, ...], dim: int, mesh: Mesh):
    """The subset of ``axes`` present on the mesh, if it divides dim.

    Axes absent from the mesh are dropped (e.g. population over
    ("pod", "data") falls back to ("data",) on the single-pod mesh).
    """
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    if dim % _axes_size(mesh, axes) != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def _names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return tuple(out)


# tree keys whose children carry a leading stacked-layer dimension
_STACKED_KEYS = {"blocks", "blocks_moe", "blocks_dense", "encoder"}


def param_pspec(
    path,
    shape: Tuple[int, ...],
    mcfg: MeshConfig,
    mesh: Mesh,
    *,
    population: bool,
) -> P:
    names = _names(path)
    name = names[-1] if names else ""
    spec: list = [None] * len(shape)
    off = 0
    if population and len(shape) >= 1:
        spec[0] = _maybe(mcfg.population_axes, shape[0], mesh)
        off = 1
    if any(n in _STACKED_KEYS for n in names):
        off += 1  # stacked-layer dim (replicated; scanned over)
    body = shape[off:]
    # expert-stacked MoE weights: routed experts live under "moe" and are
    # (E, d, ff) / (E, ff, d) after the layer dim; shared experts are 2-D
    is_expert = "moe" in names and "shared" not in names and len(body) == 3

    def set_last(axes):
        spec[-1] = _maybe(axes, shape[-1], mesh)

    def set_first(axes):
        spec[off] = _maybe(axes, shape[off], mesh)

    if name in _REPLICATED or not body:
        pass
    elif name == "embed":
        set_first(mcfg.model_axes)  # vocab-sharded embedding
    elif name == "norm":  # mamba gated-norm over d_inner
        set_last(mcfg.model_axes)
    elif name in _LAST_MODEL:
        if is_expert:  # (E, d, ff)
            set_first(mcfg.expert_axes)
        else:
            # FSDP: shard the contraction dim over fsdp_axes; XLA
            # all-gathers per use and reduce-scatters the gradient
            set_first(mcfg.fsdp_axes)
        set_last(mcfg.model_axes)
    elif name in _FIRST_MODEL:
        if is_expert:  # (E, ff, d)
            set_first(mcfg.expert_axes)
            spec[off + 1] = _maybe(mcfg.model_axes, shape[off + 1], mesh)
        else:
            set_first(mcfg.model_axes)
            if mcfg.fsdp_axes:
                spec[-1] = _maybe(mcfg.fsdp_axes, shape[-1], mesh)
    return P(*spec)


def params_pspecs(params_shapes: PyTree, mcfg: MeshConfig, mesh: Mesh, *, population: bool) -> PyTree:
    """params_shapes: pytree of ShapeDtypeStruct (e.g. from eval_shape)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf.shape, mcfg, mesh, population=population),
        params_shapes,
    )


def plane_pspec(n_agents: int, dim: int, mcfg: MeshConfig, mesh: Mesh) -> P:
    """Partition rule for the bare ``(n_agents, dim)`` parameter plane
    (``HDOConfig.param_layout="plane"``, core/plane.py).

    The agent axis shards over ``population_axes`` and the flat dim
    axis FSDP-shards over ``model_axes`` — but only when every model
    shard gets a whole number of BLOCK-aligned chunks (the plane ZO
    kernels address whole BLOCKs; ``plane.rng_tables_sharded`` carries
    the same constraint), falling back to replicating the dim axis
    otherwise.  Used by ``launch/dryrun.py`` and mirrored by the
    sharded round's in-shard layout (core/shardround.py).
    """
    from repro.kernels.zo_combine import BLOCK

    pop = _maybe(mcfg.population_axes, n_agents, mesh)
    mdl = tuple(a for a in mcfg.model_axes if a in mesh.shape)
    m = _axes_size(mesh, mdl)
    if m > 1 and dim % (m * BLOCK) == 0:
        return P(pop, mdl if len(mdl) > 1 else mdl[0])
    return P(pop)


def batch_pspecs(batch_shapes: PyTree, mcfg: MeshConfig, mesh: Mesh, *, population: bool) -> PyTree:
    """Training batches: (n_agents, per_batch, ...) leaves."""

    def spec(path, leaf):
        shape = leaf.shape
        s: list = [None] * len(shape)
        if population:
            s[0] = _maybe(mcfg.population_axes, shape[0], mesh)
            if len(shape) > 1 and mcfg.batch_axes:
                s[1] = _maybe(mcfg.batch_axes, shape[1], mesh)
        else:
            # inference batches shard over pod+data when available
            s[0] = _maybe(("pod", "data"), shape[0], mesh)
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_pspecs(cache_shapes: PyTree, mcfg: MeshConfig, mesh: Mesh) -> PyTree:
    """Decode caches.  KV leaves are (L, B, S, n_kv, hd); mamba conv is
    (L, B, k, C); mamba ssm state is (L, B, nh, hp, ds).

    Batch shards over "data" when divisible; for B == 1 (long-context)
    the sequence dim shards over "data" instead (flash-decoding style).
    """

    batch_axes = ("pod", "data")

    def spec(path, leaf):
        names = _names(path)
        shape = leaf.shape
        s: list = [None] * len(shape)
        is_kv = names[-1].startswith(("k", "v", "ek", "ev")) and len(shape) == 5
        if is_kv:
            L, B, S, nkv, hd = shape
            if B > 1 and _maybe(batch_axes, B, mesh):
                s[1] = _maybe(batch_axes, B, mesh)
            elif _maybe(batch_axes, S, mesh):
                s[2] = _maybe(batch_axes, S, mesh)
            if _maybe(mcfg.model_axes, nkv, mesh):
                s[3] = _maybe(mcfg.model_axes, nkv, mesh)
            elif _maybe(mcfg.model_axes, hd, mesh):
                s[4] = _maybe(mcfg.model_axes, hd, mesh)
        elif names and names[-1] == "conv" or (len(shape) == 4 and "mamba" in names):
            # (L, B, k, C)
            s[1] = _maybe(batch_axes, shape[1], mesh)
            s[-1] = _maybe(mcfg.model_axes, shape[-1], mesh)
        elif len(shape) == 5:  # ssm state (L, B, nh, hp, ds)
            s[1] = _maybe(batch_axes, shape[1], mesh)
            s[2] = _maybe(mcfg.model_axes, shape[2], mesh)
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def to_shardings(pspecs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
