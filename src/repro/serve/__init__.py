"""Continuous-batching serving engine over the HDO population.

See ``docs/serving.md``: ``Engine`` (jitted scan decode over a fixed
slot pool), ``Scheduler`` (host-side continuous batching at token
granularity), and the population layer (``population_params`` /
``load_population``: gossip-mean snapshot vs per-agent ensemble
routing, both param layouts).
"""
from repro.serve.engine import Engine, EngineConfig
from repro.serve.population import (
    POPULATIONS,
    load_population,
    population_params,
)
from repro.serve.scheduler import Request, RequestResult, Scheduler, percentile

__all__ = [
    "Engine",
    "EngineConfig",
    "Request",
    "RequestResult",
    "Scheduler",
    "percentile",
    "POPULATIONS",
    "population_params",
    "load_population",
]
