"""Host half of the serving engine: request queue + continuous-batching
scheduler.

The scheduler owns everything the device program cannot: the pending
queue, arrival times (wall-clock for offered-load benches, or
deterministic *decode ticks* for replayable tests), slot assignment,
request routing (ensemble mode), per-request timing attribution, and
the engine metrics stream.

Timing honesty
--------------
Every request record splits **queue / prefill / decode** instead of
lumping teacher-forced prefill steps into decode throughput (the bug
the per-token loop had): the scheduler fences at chunk boundaries
(reading the engine's per-slot state forces the sync) and attributes
each chunk's wall time to a slot's prefill vs decode phases by its
exact step counts inside the chunk (known from ``pos`` before/after vs
``prompt_len``).  With ``chunk=1`` the attribution is per-token exact;
larger chunks are exact up to intra-chunk step-time variance.
``tokens_per_s`` is decode-only: generated tokens after the first,
divided by decode wall time (the first new token is priced into
prefill, where its latency actually lives).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serve.engine import Engine


@dataclasses.dataclass
class Request:
    """One generation request.

    ``arrival_tick`` schedules the request in *decode ticks* (engine
    scan steps) — fully deterministic, wall-clock free (the parity /
    invariant tests).  ``arrival_s`` (seconds after ``run()`` starts)
    overrides it for offered-load benchmarking.  ``agent`` routes the
    request to one cohort member on an ensemble engine.
    """

    request_id: int
    prompt: np.ndarray
    max_gen: int
    agent: int = 0
    arrival_tick: int = 0
    arrival_s: Optional[float] = None


@dataclasses.dataclass
class RequestResult:
    request_id: int
    agent: int
    tokens: np.ndarray  # prompt echo + generated tokens
    prompt_tokens: int
    gen_tokens: int
    finish_reason: str  # "budget" | "eos"
    queue_ms: float
    prefill_ms: float
    decode_ms: float
    latency_ms: float
    tokens_per_s: float  # decode-only throughput (see module docstring)


@dataclasses.dataclass
class _Running:
    req: Request
    slot: int
    eligible_t: float
    admit_t: float
    prefill_ms: float = 0.0
    decode_ms: float = 0.0
    decode_steps: int = 0
    pos_before: int = 0


class Scheduler:
    """Continuous batching over an :class:`Engine`: admit queued
    requests into freed slots and evict finished ones at token
    granularity, emitting ``serve_request`` records plus per-chunk
    engine metrics (queue depth, slot occupancy, prefill-vs-decode
    split) through the ``repro.obs`` logger."""

    def __init__(self, engine: Engine, *, logger=None, log_every: int = 1,
                 time_fn=time.perf_counter):
        self.engine = engine
        self.logger = logger
        self.log_every = max(1, log_every)
        self._time = time_fn
        self.pending: List[Request] = []
        self.results: List[RequestResult] = []
        self.ticks = 0  # total decode steps dispatched
        self._chunks = 0
        self._seen: set = set()

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.request_id in self._seen:
            raise ValueError(f"duplicate request_id {req.request_id}")
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        self.engine.validate(len(prompt), req.max_gen, req.agent)
        self._seen.add(req.request_id)
        self.pending.append(dataclasses.replace(req, prompt=prompt))

    def _due(self, now_s: float) -> List[Request]:
        out = []
        for r in self.pending:
            if r.arrival_s is not None:
                if now_s >= r.arrival_s:
                    out.append(r)
            elif self.ticks >= r.arrival_tick:
                out.append(r)
        return out

    # -- the loop -----------------------------------------------------------
    def run(self) -> List[RequestResult]:
        """Drive the engine until every submitted request completes.
        FIFO admission (submission order) among due requests."""
        eng = self.engine
        t0 = self._time()
        running: Dict[int, _Running] = {}  # slot -> running record
        eligible_at: Dict[int, float] = {}

        while self.pending or running:
            now = self._time() - t0
            due = self._due(now)
            for r in due:
                eligible_at.setdefault(r.request_id, self._time())
            free = [s for s in eng.free_slots() if s not in running]
            while due and free:
                r, due = due[0], due[1:]
                slot = free.pop(0)
                self.pending.remove(r)
                t_adm = self._time()
                eng.admit(slot, r.prompt, r.max_gen, agent=r.agent)
                running[slot] = _Running(
                    req=r, slot=slot,
                    eligible_t=eligible_at.get(r.request_id, t_adm),
                    admit_t=t_adm, pos_before=0,
                )
            if not running:
                self._advance_idle(t0)
                continue

            t_c0 = self._time()
            n_pf, n_dc = eng.run_chunk()  # fenced: syncs pos/active
            chunk_ms = (self._time() - t_c0) * 1e3
            self.ticks += eng.config.chunk
            self._chunks += 1
            self._attribute(running, chunk_ms)
            self._log_chunk(n_pf, n_dc, chunk_ms, len(running))
            t_fence = self._time()
            for slot in [s for s, rr in running.items()
                         if not eng.active[s]]:
                self._finish(running.pop(slot), t_fence)
        return self.results

    def _advance_idle(self, t0: float) -> None:
        """Nothing active: jump the clock to the next arrival instead of
        spinning (ticks fast-forward; wall arrivals sleep)."""
        tick_next = [r.arrival_tick for r in self.pending
                     if r.arrival_s is None]
        wall_next = [r.arrival_s for r in self.pending
                     if r.arrival_s is not None]
        if tick_next and (not wall_next):
            self.ticks = max(self.ticks, min(tick_next))
            return
        if wall_next:
            wait = min(wall_next) - (self._time() - t0)
            if wait > 0:
                time.sleep(min(wait, 0.05))
            if tick_next:
                self.ticks = max(self.ticks, min(tick_next))

    def _attribute(self, running: Dict[int, _Running], chunk_ms: float) -> None:
        chunk = self.engine.config.chunk
        for rr in running.values():
            pos_after = int(self.engine.pos[rr.slot])
            steps = pos_after - rr.pos_before
            plen = len(rr.req.prompt)
            pf = min(max(plen - rr.pos_before, 0), steps)
            dc = steps - pf
            rr.prefill_ms += chunk_ms * pf / chunk
            rr.decode_ms += chunk_ms * dc / chunk
            rr.decode_steps += dc
            rr.pos_before = pos_after

    def _finish(self, rr: _Running, t_fence: float) -> None:
        eng = self.engine
        toks = eng.collect(rr.slot)
        plen = len(rr.req.prompt)
        gen = len(toks) - plen
        reason = "budget" if gen >= rr.req.max_gen else "eos"
        dec_s = rr.decode_ms / 1e3
        res = RequestResult(
            request_id=rr.req.request_id,
            agent=rr.req.agent if eng.ensemble else -1,
            tokens=toks,
            prompt_tokens=plen,
            gen_tokens=gen,
            finish_reason=reason,
            queue_ms=(rr.admit_t - rr.eligible_t) * 1e3,
            prefill_ms=rr.prefill_ms,
            decode_ms=rr.decode_ms,
            latency_ms=(t_fence - rr.eligible_t) * 1e3,
            tokens_per_s=(rr.decode_steps / dec_s) if dec_s > 0 else 0.0,
        )
        self.results.append(res)
        if self.logger is not None and self.logger.enabled:
            self.logger.log_request({
                "request_id": res.request_id,
                "agent_id": res.agent,
                "prompt_tokens": res.prompt_tokens,
                "gen_tokens": res.gen_tokens,
                "queue_ms": res.queue_ms,
                "prefill_ms": res.prefill_ms,
                "decode_ms": res.decode_ms,
                "latency_ms": res.latency_ms,
                "tokens_per_s": res.tokens_per_s,
            })

    def _log_chunk(self, n_pf: int, n_dc: int, chunk_ms: float,
                   n_running: int) -> None:
        if self.logger is None or not self.logger.enabled:
            return
        if (self._chunks - 1) % self.log_every:
            return
        n_slots = self.engine.config.n_slots
        self.logger.log_round(self._chunks - 1, {
            "queue_depth": len(self.pending),
            "slots_active": n_running,
            "slots_free": n_slots - n_running,
            "prefill_tokens": n_pf,
            "decode_tokens": n_dc,
            "chunk_ms": chunk_ms,
        })


def percentile(values, q) -> float:
    """p50/p99 helper over a list of floats (empty -> 0.0)."""
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


__all__ = ["Request", "RequestResult", "Scheduler", "percentile"]
