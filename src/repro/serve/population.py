"""Population-aware serving: resolve an HDO cohort into servable
params.

An HDO cohort is naturally an ensemble — the paper trains ``n_agents``
models that gossip toward consensus — so the engine serves either

* ``population="mean"`` — one snapshot of the gossip-averaged
  population (the consensus estimate x̄), or
* ``population="ensemble"`` — the stacked per-agent params, with a
  slot→agent routing table so different requests decode against
  different cohort members in the same batch.

Both work for BOTH persistent parameter layouts: ``"tree"`` (stacked
pytree) and ``"plane"`` (one contiguous ``(n_agents, dim)`` buffer —
``core/plane.py``); the plane unpacks ONLY here, at the serving
boundary.  ``load_population`` restores a training checkpoint through
the existing ``checkpoint.read_meta`` guards (param_layout +
manifest_hash checked BEFORE any array load).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs.base import HDOConfig
from repro.core import plane as planelib

PyTree = Any

POPULATIONS = ("mean", "ensemble")


def _plane_manifest(template: PyTree) -> planelib.PlaneManifest:
    return planelib.build_manifest(template)


def population_params(params: PyTree, *, mode: str,
                      param_layout: str = "tree",
                      template: Optional[PyTree] = None) -> PyTree:
    """Servable params from an ``HDOState.params`` population.

    ``mode="mean"`` returns one model pytree (the population mean);
    ``mode="ensemble"`` returns the stacked ``(n_agents, ...)`` pytree
    for per-slot routing.  ``param_layout="plane"`` needs ``template``
    (any single-model pytree of the architecture) to rebuild the leaf
    manifest.
    """
    if mode not in POPULATIONS:
        raise ValueError(f"population must be one of {POPULATIONS}, got {mode!r}")
    if param_layout == "plane":
        if template is None:
            raise ValueError(
                "param_layout='plane' needs a template pytree to rebuild "
                "the leaf manifest (pass e.g. model.init(key))"
            )
        man = _plane_manifest(template)
        if mode == "mean":
            return planelib.unpack(man, jnp.mean(params, axis=0))
        return planelib.unpack_stacked(man, params)
    if mode == "mean":
        return jax.tree.map(lambda a: jnp.mean(a, axis=0), params)
    return params


def load_population(path: str, model, *,
                    hcfg: Optional[HDOConfig] = None,
                    seed: int = 0) -> Tuple[Any, HDOConfig]:
    """Restore a trained population for serving.

    Reads the sidecar meta first and runs the pre-restore guards
    (``checkpoint.check_meta_compat``: param_layout + manifest_hash), so
    serving a checkpoint from a drifted model or layout fails with a
    clear message before any array load.  The ``HDOConfig`` is rebuilt
    from the checkpoint meta when not passed (train.py records it).

    Returns ``(HDOState, HDOConfig)``.
    """
    from repro.core import init_state  # deferred: core imports are heavy

    meta = checkpoint.read_meta(path)
    if hcfg is None:
        saved = meta.get("hdo")
        if saved is None:
            raise ValueError(
                f"checkpoint {path!r} carries no HDOConfig in its meta — "
                "pass hcfg= matching the training run"
            )
        hcfg = HDOConfig(**saved)
    template = model.init(jax.random.PRNGKey(seed))
    man_hash = planelib.manifest_hash(_plane_manifest(template))
    checkpoint.check_meta_compat(
        meta, param_layout=hcfg.param_layout, manifest_hash=man_hash
    )
    like = init_state(template, hcfg)
    state, _ = checkpoint.restore_state(path, like)
    return state, hcfg


__all__ = ["POPULATIONS", "population_params", "load_population"]
