"""The continuous-batching decode engine: a fixed pool of decode slots
driven by ONE jitted ``lax.scan`` program — no host round-trip per
token.

Design
------
* **Slot pool.**  ``n_slots`` independent decode lanes.  Each slot owns
  a per-slot KV/SSM cache slice, a position counter, an active flag,
  and an output-token row.  The caches are allocated ONCE at
  ``(n_slots, cache_seq)`` via ``model.init_cache`` and never resized;
  ``cache_seq`` is sized independently of the longest request (for
  ring-eligible configs — ``decode.use_ring`` — the KV storage is the
  sliding window, so positions are unbounded).

* **Per-slot positions via vmap.**  ``models/decode.serve_step``
  decodes a *lockstep* batch (one scalar ``pos`` for every sequence).
  Continuous batching needs per-slot positions, so the engine stores
  every cache leaf with an explicit singleton batch axis —
  ``(lead, n_slots, 1, ...)`` — and vmaps a batch-of-1 ``serve_step``
  over the slot axis.  Each lane is then an independent B=1 decode at
  its own position; the per-lane math is identical to the batched
  step, and the engine's greedy token streams are pinned BIT-IDENTICAL
  to the per-token loop (``launch.serve.generate``) for all four text
  families in ``tests/test_serve.py``.

* **Jitted chunk scan.**  ``run_chunk`` dispatches one jitted
  ``lax.scan`` of ``chunk`` decode steps.  Inside the scan every slot
  teacher-forces its own prompt (prefill) and then feeds back its
  greedy argmax (decode); slots flip inactive ON DEVICE the step their
  budget (``total_len``) or ``eos_id`` is hit, so eviction is
  token-granular even with ``chunk > 1``.  Admission happens at chunk
  fences (``chunk=1`` gives full token-granularity scheduling; larger
  chunks amortize dispatch overhead).

* **Population-aware serving.**  ``ensemble=True`` accepts stacked
  ``(n_agents, ...)`` params plus a per-slot routing table: the chunk
  program gathers each slot's cohort member and vmaps params over the
  slot axis, so different requests decode against different agents *in
  the same batch*.  ``ensemble=False`` serves one snapshot (e.g. the
  gossip-averaged population mean — see ``serve.population``).

Inactive slots keep computing (vmap lanes are uniform) but their
per-slot state is frozen by masks and their cache garbage is
unobservable: admission zeroes the slot's cache slice and resets its
position, and attention masks only ever read positions ``<= pos``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as decodelib

PyTree = Any


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine geometry (fixed at build; shapes never change)."""

    n_slots: int = 8
    # per-slot cache sequence capacity.  Non-ring attention families
    # need prompt+gen <= cache_seq per request; ring-eligible configs
    # store only the window and are position-unbounded; SSM state is
    # O(1) and ignores it.
    cache_seq: int = 256
    # output-buffer width per slot: every request needs
    # prompt+gen <= max_total (this bounds host memory, not the cache)
    max_total: int = 256
    # decode steps per jitted dispatch (1 = token-granular scheduling)
    chunk: int = 8
    # generated token that terminates a request early (None: budget only)
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.cache_seq < 1 or self.max_total < 1:
            raise ValueError("cache_seq and max_total must be >= 1")


class Engine:
    """Device half of the serving engine: slot-pool state + the two
    jitted programs (``admit``: reset one slot; ``run_chunk``: scan
    ``chunk`` decode steps over all slots)."""

    def __init__(self, model, params: PyTree, *, config: EngineConfig,
                 ensemble: bool = False):
        self.model = model
        self.cfg = model.cfg
        self.config = config
        self.ensemble = ensemble
        self._params = params
        if ensemble:
            lead = {int(x.shape[0]) for x in jax.tree.leaves(params)}
            if len(lead) != 1:
                raise ValueError(
                    "ensemble=True needs stacked (n_agents, ...) params with "
                    f"a uniform leading axis, got leading dims {sorted(lead)}"
                )
            self.n_agents = lead.pop()
        else:
            self.n_agents = 1
        if self.cfg.family in ("vlm", "audio"):
            raise ValueError(
                "the serve engine covers the text decoders "
                "(dense/moe/ssm/hybrid); vlm/audio decode shapes go through "
                "dryrun"
            )
        # position bound: non-ring attention caches hold cache_seq
        # positions; ring caches and pure-SSM state are unbounded
        ring = decodelib.use_ring(self.cfg, config.cache_seq)
        self._pos_bound = (
            None if ring or self.cfg.family == "ssm" else config.cache_seq
        )
        self._st = self._init_state()
        self._chunk_fn = jax.jit(self._build_chunk_fn())
        self._admit_fn = jax.jit(self._build_admit_fn())
        # host mirror of the small per-slot state, refreshed at fences
        self.pos = np.zeros(config.n_slots, np.int32)
        self.active = np.zeros(config.n_slots, bool)

    # -- state construction -------------------------------------------------
    def _init_state(self) -> Dict[str, Any]:
        c = self.config
        cache = self.model.init_cache(c.n_slots, c.cache_seq)
        # (lead, n_slots, ...) -> (lead, n_slots, 1, ...): the singleton
        # is the B=1 batch axis each vmap lane sees
        cache = jax.tree.map(lambda a: jnp.expand_dims(a, 2), cache)
        n = c.n_slots
        return {
            "cache": cache,
            "cur_tok": jnp.zeros((n,), jnp.int32),
            "pos": jnp.zeros((n,), jnp.int32),
            "active": jnp.zeros((n,), bool),
            "prompt_len": jnp.zeros((n,), jnp.int32),
            "total_len": jnp.zeros((n,), jnp.int32),
            "prompt_buf": jnp.zeros((n, c.max_total), jnp.int32),
            "out_tok": jnp.zeros((n, c.max_total), jnp.int32),
            "route": jnp.zeros((n,), jnp.int32),
        }

    # -- jitted programs ----------------------------------------------------
    def _build_chunk_fn(self):
        cfg, c = self.cfg, self.config
        n, eos = c.n_slots, c.eos_id

        def one(p, cache1, tok, pos):
            logits, cache1 = decodelib.serve_step(p, cfg, cache1, tok[None], pos)
            return logits[0], cache1

        vstep = jax.vmap(one, in_axes=(0 if self.ensemble else None, 1, 0, 0),
                         out_axes=(0, 1))

        def chunk_fn(params, st):
            if self.ensemble:
                # slot -> cohort member: gather once per chunk (routing
                # is fixed between admission fences)
                params = jax.tree.map(lambda a: a[st["route"]], params)
            plen, tlen = st["prompt_len"], st["total_len"]
            pbuf = st["prompt_buf"]

            def body(carry, _):
                cache, tok, pos, active, out, n_pf, n_dc = carry
                logits, cache = vstep(params, cache, tok, pos)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                # a step consuming stream position pos < prompt_len is
                # prefill work; everything after is decode
                step_pref = pos < plen
                n_pf = n_pf + jnp.sum((active & step_pref).astype(jnp.int32))
                n_dc = n_dc + jnp.sum((active & ~step_pref).astype(jnp.int32))
                t1 = pos + 1
                t1c = jnp.minimum(t1, c.max_total - 1)
                p_tok = jnp.take_along_axis(pbuf, t1c[:, None], 1)[:, 0]
                emit = jnp.where(t1 < plen, p_tok, nxt)
                cur = jnp.take_along_axis(out, t1c[:, None], 1)[:, 0]
                out = out.at[jnp.arange(n), t1c].set(
                    jnp.where(active, emit, cur))
                done = t1 >= tlen - 1
                if eos is not None:
                    done = done | ((t1 >= plen) & (emit == eos))
                pos = jnp.where(active, t1, pos)
                tok = jnp.where(active, emit, tok)
                active = active & ~done
                return (cache, tok, pos, active, out, n_pf, n_dc), None

            carry = (st["cache"], st["cur_tok"], st["pos"], st["active"],
                     st["out_tok"], jnp.int32(0), jnp.int32(0))
            carry, _ = jax.lax.scan(body, carry, None, length=c.chunk)
            cache, tok, pos, active, out, n_pf, n_dc = carry
            new = dict(st, cache=cache, cur_tok=tok, pos=pos, active=active,
                       out_tok=out)
            return new, (n_pf, n_dc)

        return chunk_fn

    def _build_admit_fn(self):
        def admit_fn(st, slot, prompt_row, p_len, t_len, agent):
            # zero the slot's cache slice: attention masks make stale
            # positions unobservable, but SSM state is recurrent and
            # MUST reset with the request
            cache = jax.tree.map(lambda a: a.at[:, slot].set(0), st["cache"])
            return dict(
                st,
                cache=cache,
                cur_tok=st["cur_tok"].at[slot].set(prompt_row[0]),
                pos=st["pos"].at[slot].set(0),
                active=st["active"].at[slot].set(True),
                prompt_len=st["prompt_len"].at[slot].set(p_len),
                total_len=st["total_len"].at[slot].set(t_len),
                prompt_buf=st["prompt_buf"].at[slot].set(prompt_row),
                out_tok=st["out_tok"].at[slot].set(
                    jnp.zeros_like(prompt_row).at[0].set(prompt_row[0])),
                route=st["route"].at[slot].set(agent),
            )

        return admit_fn

    # -- host API -----------------------------------------------------------
    def validate(self, prompt_len: int, max_gen: int, agent: int = 0) -> None:
        """Raise ValueError when a request cannot fit this engine."""
        c = self.config
        total = prompt_len + max_gen
        if prompt_len < 1 or max_gen < 1:
            raise ValueError(
                f"need prompt_len >= 1 and max_gen >= 1, got "
                f"({prompt_len}, {max_gen})"
            )
        if total > c.max_total:
            raise ValueError(
                f"request needs {total} output positions but the engine's "
                f"max_total is {c.max_total}"
            )
        if self._pos_bound is not None and total > self._pos_bound:
            raise ValueError(
                f"request needs {total} cache positions but cache_seq is "
                f"{self._pos_bound} (non-ring attention cache; use a "
                f"ring-eligible config or a larger cache_seq)"
            )
        if not 0 <= agent < self.n_agents:
            raise ValueError(
                f"agent {agent} out of range for a population of "
                f"{self.n_agents}"
            )

    def free_slots(self) -> List[int]:
        return [i for i in range(self.config.n_slots) if not self.active[i]]

    def admit(self, slot: int, prompt: np.ndarray, max_gen: int,
              agent: int = 0) -> None:
        """Reset ``slot`` and start decoding ``prompt`` (teacher-forced)
        followed by up to ``max_gen`` greedy tokens."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.validate(len(prompt), max_gen, agent)
        row = np.zeros((self.config.max_total,), np.int32)
        row[: len(prompt)] = prompt
        self._st = self._admit_fn(
            self._st, jnp.int32(slot), jnp.asarray(row),
            jnp.int32(len(prompt)), jnp.int32(len(prompt) + max_gen),
            jnp.int32(agent),
        )
        self.pos[slot] = 0
        self.active[slot] = True

    def run_chunk(self):
        """Dispatch one jitted chunk; sync the small per-slot state back
        to the host (this read is the scheduler's timing fence).
        Returns ``(prefill_tokens, decode_tokens)`` for the chunk."""
        self._st, (n_pf, n_dc) = self._chunk_fn(self._params, self._st)
        # np.array copies: asarray would alias read-only device buffers
        # and break the in-place writes admit() does to these mirrors
        self.pos = np.array(self._st["pos"])
        self.active = np.array(self._st["active"])
        return int(n_pf), int(n_dc)

    def collect(self, slot: int) -> np.ndarray:
        """The slot's emitted stream (prompt echo + generated tokens):
        positions ``0..pos`` of its output row."""
        row = np.asarray(self._st["out_tok"][slot])
        return row[: int(self.pos[slot]) + 1].copy()


__all__ = ["Engine", "EngineConfig"]
