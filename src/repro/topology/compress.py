"""Payload compression for the gossip exchange, with error feedback.

The communication side of the communication-reduced mixers: every
round each agent broadcasts a *compressed* payload m_i = C(u_i) of its
send basis u_i (= params + error-feedback residual), and the receivers
mix in difference form

    x_i <- x_i + sum_s w[s] * (m_{nbr(i,s)} - m_i),

which preserves the population mean exactly for ANY compressor (the
doubly-stochastic weights cancel telescopically over symmetric edges),
so consensus diagnostics stay honest under lossy payloads.

Two compressors (``HDOConfig.compression``):

  * ``topk`` — transmit only the k largest-magnitude coordinates
    (biased; error feedback recovers the dropped mass over rounds);
  * ``qsgd`` — stochastic quantization to 2^bits - 1 levels per
    coordinate, scaled by the payload's inf-norm (unbiased in
    expectation: E[C(u)] = u), with the rounding randomness drawn from
    the counter-based RNG at (seed, step, agent, position) so every
    round is exactly replayable and the fused kernel regenerates it
    bit-exactly in VMEM.

With ``error_feedback`` each agent carries a residual stream e_i in
``HDOState.comm`` (plane-shaped under ``param_layout="plane"``: the
streams mirror the params tree, so the plane's single (n_agents, dim)
leaf stays one buffer): e_i' = u_i - m_i, giving the telescoping
identity  m_i + e_i' == x_i + e_i  (sent + residual == raw) that the
contract tests pin.

This module owns the payload math and the ``HDOState.comm`` structure
(``init_comm`` / ``comm_pspecs``); the round logic lives in
``topology.mixer`` (CompressedGraphMixer) and the fused O(d) pass in
``kernels/compress_mix.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.compress_mix import quantize

PyTree = Any

__all__ = [
    "Compressor",
    "make_compressor",
    "payload_seeds",
    "comm_stream_flags",
    "init_comm",
    "comm_pspecs",
]

# qsgd scale floor: an all-zero payload quantizes to zero, not NaN
_SCALE_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class Compressor:
    """One payload compressor: mode + its static knob.

    ``thresholds`` computes the per-payload scalar statistic (the O(d)
    reduction the fused kernel takes as an operand); ``apply`` is the
    dense compress+decompress (the jnp mixers and oracles);
    ``bytes_on_wire`` / ``delta`` are the accounting and the spectral
    model's energy-fraction parameter.
    """

    mode: str  # "topk" | "qsgd"
    k: int = 0
    bits: int = 0

    def thresholds(self, u: jnp.ndarray) -> jnp.ndarray:
        """u: (n, d) f32 payload rows -> (n,) scalar statistic per row:
        topk: the k-th largest |u| (kept-set threshold, ties keep >= k);
        qsgd: the row's inf-norm, clamped > 0."""
        if self.mode == "topk":
            kk = min(self.k, u.shape[-1])
            return jax.lax.top_k(jnp.abs(u), kk)[0][..., -1]
        return jnp.maximum(jnp.max(jnp.abs(u), axis=-1),
                           jnp.float32(_SCALE_EPS))

    def apply(self, u: jnp.ndarray, thr: jnp.ndarray,
              seeds: jnp.ndarray) -> jnp.ndarray:
        """Dense compress+decompress: u (n, d) f32, thr (n,), seeds (n,)
        uint32 -> (n, d) f32 decompressed payloads (the receiver's
        view).  Elementwise math shared with the fused kernel."""
        d = u.shape[-1]
        idx = jnp.arange(d, dtype=jnp.uint32)
        return quantize(u, thr[..., None], seeds[..., None].astype(jnp.uint32),
                        idx[None, :], mode=self.mode, bits=self.bits)

    def bytes_on_wire(self, d: int) -> int:
        """Bytes one agent broadcasts per payload vector of dim d
        (raw f32 baseline: 4 * d)."""
        if self.mode == "topk":
            # (f32 value + u32 index) per kept coordinate
            return 8 * min(self.k, d)
        # sign + bits per coordinate, plus the f32 scale
        return math.ceil(d * (self.bits + 1) / 8) + 4

    def delta(self, d: int) -> float:
        """Energy-fraction parameter of the spectral model in (0, 1]:
        the per-round fraction of deviation mass the payload carries
        (topk: k/d worst case; qsgd: 1/(1 + omega) with the standard
        variance bound omega = min(d/s^2, sqrt(d)/s))."""
        if self.mode == "topk":
            return min(self.k, d) / float(d)
        s = float((1 << self.bits) - 1)
        omega = min(d / (s * s), math.sqrt(d) / s)
        return 1.0 / (1.0 + omega)


def make_compressor(cfg) -> Optional[Compressor]:
    """The configured Compressor, or None for ``compression="none"``."""
    if cfg.compression == "none":
        return None
    if cfg.compression == "topk":
        return Compressor(mode="topk", k=cfg.compress_k)
    return Compressor(mode="qsgd", bits=cfg.compress_bits)


# python-int mix constants (distinct from kernels.rng's), folded as
# literals so the payload seed stream never collides with the ZO draws
_K_STEP = 0x9E3779B9
_K_AGENT = 0x85EBCA6B
_K_BASE = 2654435761


def payload_seeds(seed, step, n: int) -> jnp.ndarray:
    """(n,) uint32 payload seeds for one round — a pure function of
    (config seed, step, agent), so compression randomness is replayable
    and identical across the gather and ppermute lowerings."""
    agents = jnp.arange(n, dtype=jnp.uint32)
    return (
        jnp.uint32(seed % (1 << 32)) * jnp.uint32(_K_BASE)
        + jnp.asarray(step, jnp.uint32) * jnp.uint32(_K_STEP)
        + agents * jnp.uint32(_K_AGENT)
    )


def comm_stream_flags(cfg) -> Tuple[bool, bool]:
    """(has_residual, has_bcast) — the single source for which streams
    ``HDOState.comm`` carries under this config (mirrored by the
    compressed mixers; checkpoint structure follows from it)."""
    if cfg.n_agents == 1 or cfg.gossip not in ("graph", "graph_ppermute"):
        return False, False
    has_residual = cfg.compression != "none" and cfg.error_feedback
    has_bcast = (cfg.gossip == "graph"
                 and (cfg.staleness > 0 or cfg.fault_straggler_rate > 0))
    return has_residual, has_bcast


def init_comm(cfg, stacked_params: PyTree) -> PyTree:
    """The initial ``HDOState.comm`` for a stacked population:

      * ``residual`` — per-agent error-feedback residuals, zero at start
        (nothing has been dropped yet), f32, mirroring the params tree;
      * ``bcast``    — last-broadcast (decompressed) payloads for stale
        mixing, initialized to the start params (every agent "broadcast"
        its init point at round 0).

    Returns ``()`` when neither stream is active, so the default state
    structure — and every existing checkpoint — is unchanged.
    """
    has_residual, has_bcast = comm_stream_flags(cfg)
    comm = {}
    if has_residual:
        comm["residual"] = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), stacked_params)
    if has_bcast:
        comm["bcast"] = jax.tree.map(
            lambda x: jnp.asarray(x, jnp.float32), stacked_params)
    return comm if comm else ()


def comm_pspecs(cfg, params_pspecs):
    """PartitionSpecs for ``HDOState.comm`` — every stream shards
    exactly like the params it mirrors (see launch/dryrun.py)."""
    has_residual, has_bcast = comm_stream_flags(cfg)
    comm = {}
    if has_residual:
        comm["residual"] = params_pspecs
    if has_bcast:
        comm["bcast"] = params_pspecs
    return comm if comm else ()
