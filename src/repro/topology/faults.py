"""Replayable fault injection for the gossip exchange.

Three fault processes, each an independent Bernoulli draw per
(round, agent) on the counter-based RNG (``kernels.rng._uniform``) —
NOT on the JAX key stream — so a fault schedule is a pure function of
``(fault_seed, step, agent)``: the same config replays the exact same
drops/stragglers/corruptions through the jitted step, across restarts,
and inside ``lax.scan``.  The contract the fault-injection suite pins.

  * **drop** — the agent is offline this round: it neither broadcasts
    nor mixes.  Because the mixing weights are symmetric, zeroing the
    agent's row AND its appearances in other rows removes its edges
    symmetrically, so the population mean is still preserved exactly.
  * **straggler** — the agent is alive but its broadcast doesn't land:
    neighbors keep mixing against its last buffered payload (the
    ``bcast`` stream), i.e. a randomly-stale link.
  * **byzantine** — the agent broadcasts an adversarial payload
    (``-fault_byzantine_scale`` times the true one) that neighbors
    consume; the agent's own state uses its true payload.

All three compose with compression and staleness in
``topology.mixer.CompressedGraphMixer``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp

from repro.kernels.rng import _uniform

__all__ = ["FaultSpec", "fault_masks"]

# per-process salts for the Bernoulli streams (distinct from the ZO
# Box-Muller salts and compress_mix's qsgd salt 97)
_SALT_DROP = 11
_SALT_STRAGGLER = 13
_SALT_BYZANTINE = 17

# step is folded into the uint32 seed lane (idx carries the agent)
_K_STEP = 0x27D4EB2F


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Static fault configuration (rates in [0, 1), all independent)."""

    drop_rate: float = 0.0
    straggler_rate: float = 0.0
    byzantine_rate: float = 0.0
    byzantine_scale: float = 10.0
    seed: int = 0

    @property
    def enabled(self) -> bool:
        return (self.drop_rate > 0 or self.straggler_rate > 0
                or self.byzantine_rate > 0)

    @classmethod
    def from_config(cls, cfg) -> Optional["FaultSpec"]:
        """The configured FaultSpec, or None when all rates are zero."""
        spec = cls(
            drop_rate=cfg.fault_drop_rate,
            straggler_rate=cfg.fault_straggler_rate,
            byzantine_rate=cfg.fault_byzantine_rate,
            byzantine_scale=cfg.fault_byzantine_scale,
            seed=cfg.fault_seed,
        )
        return spec if spec.enabled else None

    def corrupt(self, payload: jnp.ndarray) -> jnp.ndarray:
        """The byzantine transmission: a scaled sign-flip of the true
        payload — adversarial (points away from consensus) yet
        deterministic, so runs replay bit-exactly."""
        return jnp.float32(-self.byzantine_scale) * payload


def _bernoulli(spec: FaultSpec, step, n: int, salt: int, rate: float):
    """(n,) bool fault mask for one round; rate == 0.0 can never fire
    (the counter uniform lies in (0, 1])."""
    seed = (jnp.uint32(spec.seed % (1 << 32))
            + jnp.asarray(step, jnp.uint32) * jnp.uint32(_K_STEP))
    agents = jnp.arange(n, dtype=jnp.uint32)
    return _uniform(seed, agents, jnp.uint32(salt)) < jnp.float32(rate)


def fault_masks(spec: FaultSpec, step, n: int) -> Dict[str, jnp.ndarray]:
    """The round's fault schedule: dict of (n,) bool masks
    ``{"alive", "straggler", "byzantine"}`` — a pure function of
    (spec.seed, step, agent), identical on every replay."""
    drop = _bernoulli(spec, step, n, _SALT_DROP, spec.drop_rate)
    return {
        "alive": ~drop,
        "straggler": _bernoulli(spec, step, n, _SALT_STRAGGLER,
                                spec.straggler_rate),
        "byzantine": _bernoulli(spec, step, n, _SALT_BYZANTINE,
                                spec.byzantine_rate),
    }
