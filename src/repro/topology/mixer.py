"""The ``Mixer`` interface: one object per gossip scheme.

``build_hdo_step`` used to string-dispatch the interaction step inline
(with the ``rr_ppermute`` shard_map branch hard-coded in the step
body); it now builds a single ``Mixer`` at trace-build time and calls
``mixer(params, key=..., step=...)``.  Every pre-existing mode is an
instance here with unchanged semantics (``dense`` is bit-identical:
same ``sample_matching`` + ``mix_pairwise`` on the same key), and the
graph-topology modes plug in through the same interface.

Mixers over a static weighted graph (``GraphMixer`` and its
shard_map/ppermute lowering ``GraphPpermuteMixer``) also expose
spectral ``diagnostics()`` — lambda_2, spectral gap, and the predicted
per-round Gamma contraction — which the step surfaces as training
metrics next to ``consensus_distance``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import HDOConfig
from repro.core.gossip import (
    mix_all_reduce,
    mix_pairwise,
    round_robin_schedule,
    sample_matching,
)
from repro.kernels import ops
from repro.topology import spectral
from repro.topology.graphs import TimeVaryingTopology, Topology, make_topology

PyTree = Any

__all__ = [
    "Mixer",
    "shard_agent_index",
    "IdentityMixer",
    "AllReduceMixer",
    "DenseMatchingMixer",
    "RoundRobinMixer",
    "GraphMixer",
    "TimeVaryingGraphMixer",
    "RRPpermuteMixer",
    "GraphPpermuteMixer",
    "make_mixer",
]


class Mixer:
    """params (leading axis n_agents), PRNG key, step index -> params.

    Must preserve the population mean; ``diagnostics()`` returns static
    floats merged into the step's metrics (empty when no closed-form
    rate exists, e.g. random matchings).
    """

    def __call__(self, params: PyTree, *, key, step) -> PyTree:
        raise NotImplementedError

    def diagnostics(self) -> Dict[str, float]:
        return {}


class IdentityMixer(Mixer):
    """No communication (``none`` / single-agent populations)."""

    def __call__(self, params, *, key, step):
        return params

    def diagnostics(self):
        return {"gossip_lambda2": 1.0, "gossip_spectral_gap": 0.0,
                "gossip_gamma_contraction": 1.0}


class AllReduceMixer(Mixer):
    """Full population mean every round (W = 11^T/n, lambda_2 = 0)."""

    def __call__(self, params, *, key, step):
        return mix_all_reduce(params)

    def diagnostics(self):
        return {"gossip_lambda2": 0.0, "gossip_spectral_gap": 1.0,
                "gossip_gamma_contraction": 0.0}


class DenseMatchingMixer(Mixer):
    """Paper-faithful random disjoint pairing, sampled in-trace.

    Bit-identical to the pre-Mixer inline path: identical primitives on
    the identical key.  No static diagnostics — the matching is random
    (E[contraction] = 1/2 for even n, but per-round W has slem 1).
    """

    def __init__(self, n: int):
        self.n = n

    def __call__(self, params, *, key, step):
        return mix_pairwise(params, sample_matching(key, self.n))


class RoundRobinMixer(Mixer):
    """``rr_static``: lax.switch over the n-1 tournament matchings —
    each branch's partner table is a trace-time constant."""

    def __init__(self, n: int):
        if n % 2:
            raise ValueError(f"rr_static needs an even population, got n={n}")
        self.n = n
        self.schedule = round_robin_schedule(n)

    def __call__(self, params, *, key, step):
        branches = [
            (lambda p, _r=r: mix_pairwise(p, jnp.asarray(self.schedule[_r])))
            for r in range(len(self.schedule))
        ]
        return jax.lax.switch(step % (self.n - 1), branches, params)


class GraphMixer(Mixer):
    """Weighted mixing over a static topology: X <- W X via a
    trace-time-constant neighbor gather, f32 accumulation.

    ``use_kernel=True`` routes each leaf (raveled per agent) through
    the fused ``gossip_mix`` Pallas kernel instead of the jnp
    weighted-sum.  Note the gather still materializes the (n, k, d)
    neighbor copy here — this path fuses only the combine; the full
    one-O(d)-pass traffic story is ``GraphPpermuteMixer``, where the
    k neighbor buffers arrive shard-local over ICI and feed the kernel
    directly.
    """

    def __init__(self, topo: Topology, *, use_kernel: bool = False):
        self.topo = topo
        self.use_kernel = use_kernel
        self._nbr = jnp.asarray(topo.neighbors)
        self._w = jnp.asarray(topo.weights)
        self._w_self = jnp.asarray(topo.self_weight)

    def __call__(self, params, *, key, step):
        return jax.tree.map(self._mix_leaf, params)

    def _mix_leaf(self, x):
        n, k = self._nbr.shape
        gathered = jnp.take(x, self._nbr.reshape(-1), axis=0).reshape(
            (n, k) + x.shape[1:]
        )
        if self.use_kernel:
            flat = x.reshape(n, -1)
            nbrs = gathered.reshape(n, k, -1)
            out = jax.vmap(ops.gossip_mix)(flat, nbrs, self._w_self, self._w)
            return out.reshape(x.shape)
        tail = (1,) * (x.ndim - 1)
        acc = self._w_self.reshape((n,) + tail) * x.astype(jnp.float32)
        acc = acc + (
            self._w.reshape((n, k) + tail) * gathered.astype(jnp.float32)
        ).sum(axis=1)
        return acc.astype(x.dtype)

    def diagnostics(self):
        return spectral.diagnostics(self.topo)


class TimeVaryingGraphMixer(Mixer):
    """Cycles a static list of graph rounds by step index (lax.switch,
    the same derandomization contract as ``rr_static``)."""

    def __init__(self, topo: TimeVaryingTopology, *, use_kernel: bool = False):
        self.topo = topo
        self._rounds = [GraphMixer(t, use_kernel=use_kernel) for t in topo.rounds]

    def __call__(self, params, *, key, step):
        branches = [
            (lambda p, _m=m: _m(p, key=None, step=None)) for m in self._rounds
        ]
        return jax.lax.switch(step % len(self._rounds), branches, params)

    def diagnostics(self):
        return spectral.diagnostics(self.topo)


def _pop_axes_size(mesh, population_axes) -> Tuple[Tuple[str, ...], int]:
    pop_axes = tuple(a for a in population_axes if a in mesh.shape)
    pop_size = 1
    for a in pop_axes:
        pop_size *= mesh.shape[a]
    return pop_axes, pop_size


def shard_agent_index(mesh, pop_axes, n_local: int = 1):
    """Global index of this shard's first agent inside a shard_map over
    ``pop_axes`` (row-major over the axis tuple, matching the
    ``P(pop_axes)`` population sharding).  Shared by the graph-gossip
    ppermute lowering and ``build_hdo_step``'s shard_cond dispatch so
    the two linearizations can never drift apart."""
    idx = jnp.int32(0)
    stride = n_local
    for a in reversed(pop_axes):
        idx = idx + jax.lax.axis_index(a) * stride
        stride = stride * mesh.shape[a]
    return idx


class RRPpermuteMixer(Mixer):
    """TPU-native round-robin: each agent exchanges ONLY with its round
    partner over ICI (collective-permute) instead of gathering the
    whole population.  Needs one agent per population shard."""

    def __init__(self, n: int, mesh, population_axes):
        if mesh is None:
            raise ValueError("rr_ppermute needs a mesh")
        if n % 2:
            raise ValueError(f"rr_ppermute needs an even population, got n={n}")
        pop_axes, pop_size = _pop_axes_size(mesh, population_axes)
        if n != pop_size:
            raise ValueError(
                f"rr_ppermute needs one agent per population shard "
                f"(n={n}, shards={pop_size})"
            )
        self.n = n
        self.mesh = mesh
        self.pop_axes = pop_axes
        self.rr_table = round_robin_schedule(n)

    def __call__(self, params, *, key, step):
        n = self.n
        axis = self.pop_axes if len(self.pop_axes) > 1 else self.pop_axes[0]
        from jax.sharding import PartitionSpec as P

        def gossip_shard(p_l, t_l):
            def round_branch(r):
                perm = [(i, int(self.rr_table[r][i])) for i in range(n)]

                def b(p):
                    partner = jax.tree.map(
                        lambda x: jax.lax.ppermute(x, axis_name=axis, perm=perm), p
                    )
                    return jax.tree.map(
                        lambda a_, b_: (
                            (a_.astype(jnp.float32) + b_.astype(jnp.float32)) * 0.5
                        ).astype(a_.dtype),
                        p,
                        partner,
                    )

                return b

            return jax.lax.switch(
                t_l % (n - 1), [round_branch(r) for r in range(n - 1)], p_l
            )

        pspec = P(axis)
        return compat.shard_map(
            gossip_shard,
            mesh=self.mesh,
            in_specs=(pspec, P()),
            out_specs=pspec,
            axis_names=set(self.pop_axes),
            check_vma=False,
        )(params, step)


class GraphPpermuteMixer(Mixer):
    """shard_map/ppermute lowering of ``GraphMixer`` for topologies
    whose neighbor-table columns are permutations (ring / torus /
    hypercube): one point-to-point exchange per neighbor slot, then the
    per-agent weighted combine — through the ``gossip_mix`` kernel when
    ``use_kernel`` is set."""

    def __init__(self, topo: Topology, mesh, population_axes, *,
                 use_kernel: bool = False):
        if mesh is None:
            raise ValueError("graph_ppermute needs a mesh")
        if not topo.columns_are_permutations():
            raise ValueError(
                f"graph_ppermute needs permutation neighbor columns; "
                f"topology {topo.name!r} is irregular — use gossip='graph'"
            )
        pop_axes, pop_size = _pop_axes_size(mesh, population_axes)
        if topo.n != pop_size:
            raise ValueError(
                f"graph_ppermute needs one agent per population shard "
                f"(n={topo.n}, shards={pop_size})"
            )
        self.topo = topo
        self.mesh = mesh
        self.pop_axes = pop_axes
        self.use_kernel = use_kernel

    def __call__(self, params, *, key, step):
        topo = self.topo
        n, k = topo.n, topo.k
        axis = self.pop_axes if len(self.pop_axes) > 1 else self.pop_axes[0]
        w = jnp.asarray(topo.weights)
        w_self = jnp.asarray(topo.self_weight)
        from jax.sharding import PartitionSpec as P

        def gossip_shard(p_l):
            idx = shard_agent_index(self.mesh, self.pop_axes)
            w_i = w[idx]  # (k,)
            ws_i = w_self[idx]
            recvs = []
            for s in range(k):
                perm = [(int(topo.neighbors[j, s]), j) for j in range(n)]
                recvs.append(jax.tree.map(
                    lambda x: jax.lax.ppermute(x, axis_name=axis, perm=perm), p_l
                ))

            def combine(x, *nbrs):
                if self.use_kernel:
                    out = ops.gossip_mix(
                        x.reshape(-1),
                        jnp.stack([b.reshape(-1) for b in nbrs]),
                        ws_i, w_i,
                    )
                    return out.reshape(x.shape)
                acc = ws_i * x.astype(jnp.float32)
                for s in range(k):
                    acc = acc + w_i[s] * nbrs[s].astype(jnp.float32)
                return acc.astype(x.dtype)

            return jax.tree.map(combine, p_l, *recvs)

        pspec = P(axis)
        return compat.shard_map(
            gossip_shard,
            mesh=self.mesh,
            in_specs=(pspec,),
            out_specs=pspec,
            axis_names=set(self.pop_axes),
            check_vma=False,
        )(params)

    def diagnostics(self):
        return spectral.diagnostics(self.topo)


def make_mixer(cfg: HDOConfig, *, mesh=None, population_axes: Tuple[str, ...] = (),
               use_kernel: Optional[bool] = None) -> Mixer:
    """Builds the Mixer for ``cfg.gossip`` (+ topology knobs).

    ``use_kernel`` routes the graph mixers' combine through the fused
    ``gossip_mix`` Pallas kernel; default off the kernel is used on TPU
    only (the jnp path is the interpret-friendly oracle elsewhere).
    """
    n = cfg.n_agents
    if cfg.gossip == "none" or n == 1:
        return IdentityMixer()
    if cfg.gossip == "all_reduce":
        return AllReduceMixer()
    if cfg.gossip == "dense":
        return DenseMatchingMixer(n)
    if cfg.gossip == "rr_static":
        return RoundRobinMixer(n)
    if cfg.gossip == "rr_ppermute":
        return RRPpermuteMixer(n, mesh, population_axes)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if cfg.gossip in ("graph", "graph_ppermute"):
        topo = make_topology(
            cfg.topology, n, p=cfg.topology_p, seed=cfg.topology_seed,
            rounds=cfg.topology_rounds,
        )
        if cfg.gossip == "graph_ppermute":
            if isinstance(topo, TimeVaryingTopology):
                raise ValueError(
                    "graph_ppermute supports static topologies only; "
                    f"got time-varying {topo.name!r}"
                )
            return GraphPpermuteMixer(topo, mesh, population_axes,
                                      use_kernel=use_kernel)
        if isinstance(topo, TimeVaryingTopology):
            return TimeVaryingGraphMixer(topo, use_kernel=use_kernel)
        return GraphMixer(topo, use_kernel=use_kernel)
    raise ValueError(f"unknown gossip mode {cfg.gossip!r}")
