"""The ``Mixer`` interface: one object per gossip scheme.

``build_hdo_step`` used to string-dispatch the interaction step inline
(with the ``rr_ppermute`` shard_map branch hard-coded in the step
body); it now builds a single ``Mixer`` at trace-build time and calls
``mixer(params, key=..., step=...)``.  Every pre-existing mode is an
instance here with unchanged semantics (``dense`` is bit-identical:
same ``sample_matching`` + ``mix_pairwise`` on the same key), and the
graph-topology modes plug in through the same interface.

Mixers over a static weighted graph (``GraphMixer`` and its
shard_map/ppermute lowering ``GraphPpermuteMixer``) also expose
spectral ``diagnostics()`` — lambda_2, spectral gap, and the predicted
per-round Gamma contraction — which the step surfaces as training
metrics next to ``consensus_distance``.

Communication-reduced, fault-tolerant rounds live in the *stateful*
lift of the protocol: ``init_comm(params)`` builds the communication
state carried in ``HDOState.comm`` (error-feedback residuals,
stale-broadcast buffers) and ``mix(params, key=..., step=..., comm=...)``
threads it through the round.  Stateless mixers inherit defaults that
carry the empty pytree, so ``compression="none"`` runs are structurally
(and bit-) identical to the plain mixers; ``CompressedGraphMixer`` /
``CompressedGraphPpermuteMixer`` implement compression (topology.
compress), error feedback, staleness-bounded broadcasts, and fault
injection (topology.faults) on top of the same graph machinery.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import HDOConfig
from repro.core.gossip import (
    mix_all_reduce,
    mix_pairwise,
    round_robin_schedule,
    sample_matching,
)
from repro.kernels import ops
from repro.kernels.compress_mix import quantize
from repro.topology import compress as compresslib
from repro.topology import faults as faultlib
from repro.topology import spectral
from repro.topology.graphs import TimeVaryingTopology, Topology, make_topology

PyTree = Any

__all__ = [
    "Mixer",
    "shard_agent_index",
    "IdentityMixer",
    "AllReduceMixer",
    "DenseMatchingMixer",
    "RoundRobinMixer",
    "GraphMixer",
    "TimeVaryingGraphMixer",
    "RRPpermuteMixer",
    "GraphPpermuteMixer",
    "CompressedGraphMixer",
    "CompressedGraphPpermuteMixer",
    "make_mixer",
]


class Mixer:
    """params (leading axis n_agents), PRNG key, step index -> params.

    Must preserve the population mean; ``diagnostics()`` returns static
    floats merged into the step's metrics (empty when no closed-form
    rate exists, e.g. random matchings).
    """

    def __call__(self, params: PyTree, *, key, step) -> PyTree:
        raise NotImplementedError

    def init_comm(self, params: PyTree) -> PyTree:
        """Communication state carried across rounds in ``HDOState.comm``
        (error-feedback residuals, stale-broadcast buffers).  Stateless
        mixers carry none — the empty pytree keeps the state (and every
        existing checkpoint) structurally unchanged."""
        return ()

    def mix(self, params: PyTree, *, key, step,
            comm: PyTree) -> Tuple[PyTree, PyTree]:
        """Stateful entry point used by ``build_hdo_step``: mix and
        thread the comm state.  Default: the stateless ``__call__``
        with the comm passed through untouched."""
        return self(params, key=key, step=step), comm

    def diagnostics(self) -> Dict[str, float]:
        return {}

    def wire_bytes_per_agent(self, d: Optional[int]) -> Optional[int]:
        """Nominal payload bytes ONE broadcasting agent puts on the
        wire per round for a flat parameter dim ``d`` — dense f32
        (``4*d``) for every uncompressed exchange; the compressed
        mixers override with ``Compressor.bytes_on_wire``.  None when
        ``d`` is unknown.  Extended metrics multiply this by the
        round's measured broadcasting-agent count (staleness/faults
        reduce it) to get ``gossip_wire_bytes``."""
        return None if d is None else 4 * int(d)


class IdentityMixer(Mixer):
    """No communication (``none`` / single-agent populations)."""

    def __call__(self, params, *, key, step):
        return params

    def diagnostics(self):
        return {"gossip_lambda2": 1.0, "gossip_spectral_gap": 0.0,
                "gossip_gamma_contraction": 1.0}

    def wire_bytes_per_agent(self, d):
        return 0 if d is not None else None


class AllReduceMixer(Mixer):
    """Full population mean every round (W = 11^T/n, lambda_2 = 0)."""

    def __call__(self, params, *, key, step):
        return mix_all_reduce(params)

    def diagnostics(self):
        return {"gossip_lambda2": 0.0, "gossip_spectral_gap": 1.0,
                "gossip_gamma_contraction": 0.0}


class DenseMatchingMixer(Mixer):
    """Paper-faithful random disjoint pairing, sampled in-trace.

    Bit-identical to the pre-Mixer inline path: identical primitives on
    the identical key.  No static diagnostics — the matching is random
    (E[contraction] = 1/2 for even n, but per-round W has slem 1).
    """

    def __init__(self, n: int):
        self.n = n

    def __call__(self, params, *, key, step):
        return mix_pairwise(params, sample_matching(key, self.n))


class RoundRobinMixer(Mixer):
    """``rr_static``: lax.switch over the n-1 tournament matchings —
    each branch's partner table is a trace-time constant."""

    def __init__(self, n: int):
        if n % 2:
            raise ValueError(f"rr_static needs an even population, got n={n}")
        self.n = n
        self.schedule = round_robin_schedule(n)

    def __call__(self, params, *, key, step):
        branches = [
            (lambda p, _r=r: mix_pairwise(p, jnp.asarray(self.schedule[_r])))
            for r in range(len(self.schedule))
        ]
        return jax.lax.switch(step % (self.n - 1), branches, params)


class GraphMixer(Mixer):
    """Weighted mixing over a static topology: X <- W X via a
    trace-time-constant neighbor gather, f32 accumulation.

    ``use_kernel=True`` routes each leaf (raveled per agent) through
    the fused ``gossip_mix`` Pallas kernel instead of the jnp
    weighted-sum.  Note the gather still materializes the (n, k, d)
    neighbor copy here — this path fuses only the combine; the full
    one-O(d)-pass traffic story is ``GraphPpermuteMixer``, where the
    k neighbor buffers arrive shard-local over ICI and feed the kernel
    directly.
    """

    def __init__(self, topo: Topology, *, use_kernel: bool = False):
        self.topo = topo
        self.use_kernel = use_kernel
        self._nbr = jnp.asarray(topo.neighbors)
        self._w = jnp.asarray(topo.weights)
        self._w_self = jnp.asarray(topo.self_weight)

    def __call__(self, params, *, key, step):
        return jax.tree.map(self._mix_leaf, params)

    def _mix_leaf(self, x):
        n, k = self._nbr.shape
        gathered = jnp.take(x, self._nbr.reshape(-1), axis=0).reshape(
            (n, k) + x.shape[1:]
        )
        if self.use_kernel:
            flat = x.reshape(n, -1)
            nbrs = gathered.reshape(n, k, -1)
            out = jax.vmap(ops.gossip_mix)(flat, nbrs, self._w_self, self._w)
            return out.reshape(x.shape)
        tail = (1,) * (x.ndim - 1)
        acc = self._w_self.reshape((n,) + tail) * x.astype(jnp.float32)
        acc = acc + (
            self._w.reshape((n, k) + tail) * gathered.astype(jnp.float32)
        ).sum(axis=1)
        return acc.astype(x.dtype)

    def diagnostics(self):
        return spectral.diagnostics(self.topo)


class CompressedGraphMixer(GraphMixer):
    """Communication-reduced, fault-tolerant lift of ``GraphMixer``.

    Each round every agent broadcasts a compressed payload
    m_i = C(x_i + e_i) (e_i the error-feedback residual) and mixes in
    difference form  x_i <- x_i + sum_s w[i,s] * (m_s - m_i), which
    preserves the population mean for ANY compressor (symmetric
    doubly-stochastic weights cancel telescopically).  Three optional
    layers compose on top:

      * error feedback — e_i' = u_i - m_i carried in ``comm["residual"]``;
      * staleness bound tau — agents refresh their broadcast buffer
        (``comm["bcast"]``) on the staggered schedule
        (step + i) % (tau+1) == 0, neighbors mix against the buffer, so
        every consumed payload is at most tau rounds old;
      * faults (topology.faults) — dropped agents leave the round
        symmetrically (mean still preserved), stragglers skip their
        buffer refresh, byzantine agents transmit a corrupted payload
        while keeping their own state honest.

    The fresh path (no faults, no staleness) routes through the fused
    ``compress_mix`` Pallas kernel under ``use_kernel``; the buffered /
    fault path is the jnp lowering of the same math.  Constructed only
    when communication features are on — plain configs keep the exact
    ``GraphMixer`` object, so ``compression="none"`` stays bit-identical.
    """

    def __init__(self, topo: Topology, *, compressor=None,
                 error_feedback: bool = True, staleness: int = 0,
                 faults: Optional[faultlib.FaultSpec] = None, seed: int = 0,
                 use_kernel: bool = False, param_dim: Optional[int] = None):
        super().__init__(topo, use_kernel=use_kernel)
        self.compressor = compressor
        self.error_feedback = bool(error_feedback and compressor is not None)
        self.staleness = int(staleness)
        self.faults = faults
        self.seed = seed
        self.param_dim = param_dim
        self._buffered = (self.staleness > 0
                          or (faults is not None and faults.straggler_rate > 0))
        self._general = self._buffered or faults is not None

    def __call__(self, params, *, key, step):
        raise TypeError(
            "CompressedGraphMixer is stateful; use "
            ".mix(params, key=..., step=..., comm=...)")

    def init_comm(self, params):
        comm = {}
        if self.error_feedback:
            comm["residual"] = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
        if self._buffered:
            comm["bcast"] = jax.tree.map(
                lambda x: jnp.asarray(x, jnp.float32), params)
        return comm if comm else ()

    def mix(self, params, *, key, step, comm):
        comm = comm if isinstance(comm, dict) else {}
        resid = comm.get("residual")
        bcast = comm.get("bcast")
        p_leaves, tdef = jax.tree.flatten(params)
        nleaf = len(p_leaves)
        r_leaves = jax.tree.leaves(resid) if resid is not None else [None] * nleaf
        b_leaves = jax.tree.leaves(bcast) if bcast is not None else [None] * nleaf
        masks = (faultlib.fault_masks(self.faults, step, self.topo.n)
                 if self.faults is not None else None)
        outs = [self._mix_leaf_compressed(x, e, b, step, masks)
                for x, e, b in zip(p_leaves, r_leaves, b_leaves)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_comm = {}
        if resid is not None:
            new_comm["residual"] = jax.tree.unflatten(
                jax.tree.structure(resid), [o[1] for o in outs])
        if bcast is not None:
            new_comm["bcast"] = jax.tree.unflatten(
                jax.tree.structure(bcast), [o[2] for o in outs])
        return new_params, (new_comm if new_comm else ())

    def _mix_leaf_compressed(self, x, e, b, step, masks):
        n, k = self._nbr.shape
        shape = x.shape
        x2 = x.reshape(n, -1)
        d = x2.shape[1]
        xf = x2.astype(jnp.float32)
        u = xf + e.reshape(n, d) if e is not None else xf
        comp = self.compressor
        if comp is not None:
            thr = comp.thresholds(u)
            seeds = compresslib.payload_seeds(self.seed, step, n)

        if not self._general:
            # fresh path: every payload is this round's, no faults —
            # the fused-kernel shape (comp is always set here: plain
            # configs never construct this mixer)
            if self.use_kernel:
                gathered = jnp.take(u, self._nbr.reshape(-1), axis=0
                                    ).reshape(n, k, d)
                thr_rows = jnp.concatenate(
                    [thr[:, None], thr[self._nbr]], axis=1)
                seed_rows = jnp.concatenate(
                    [seeds[:, None], seeds[self._nbr]], axis=1)
                mode, bits = comp.mode, comp.bits

                def one(xi, ui, gi, wi, ti, si):
                    return ops.compress_mix(xi, ui, gi, wi, ti, si, mode, bits)

                out, new_e = jax.vmap(one)(x2, u, gathered, self._w,
                                           thr_rows, seed_rows)
            else:
                m = comp.apply(u, thr, seeds)
                m_nbr = jnp.take(m, self._nbr.reshape(-1), axis=0
                                 ).reshape(n, k, d)
                acc = (self._w[:, :, None]
                       * (m_nbr - m[:, None, :])).sum(axis=1)
                out = (xf + acc).astype(x.dtype)
                new_e = u - m
            new_e = new_e.reshape(shape) if self.error_feedback else None
            return out.reshape(shape), new_e, None

        # general path: staleness-buffered broadcasts and/or faults
        m = comp.apply(u, thr, seeds) if comp is not None else u
        if masks is not None:
            alive, straggler, byz = (masks["alive"], masks["straggler"],
                                     masks["byzantine"])
        else:
            alive = jnp.ones((n,), bool)
            straggler = byz = jnp.zeros((n,), bool)
        if self.staleness > 0:
            sched = ((jnp.asarray(step, jnp.int32)
                      + jnp.arange(n, dtype=jnp.int32))
                     % (self.staleness + 1)) == 0
        else:
            sched = jnp.ones((n,), bool)
        refresh = sched & alive & ~straggler
        b_prev = b.reshape(n, d) if b is not None else m
        b_new = jnp.where(refresh[:, None], m, b_prev)
        if self.faults is not None:
            payload = jnp.where((byz & alive)[:, None],
                                self.faults.corrupt(b_new), b_new)
        else:
            payload = b_new
        gathered = jnp.take(payload, self._nbr.reshape(-1), axis=0
                            ).reshape(n, k, d)
        # dropped agents vanish from BOTH sides of each edge, so the
        # deleted terms cancel pairwise and the mean is still exact
        wa = self._w * alive[self._nbr].astype(jnp.float32)  # (n, k)
        acc = (wa[:, :, None] * (gathered - b_new[:, None, :])).sum(axis=1)
        out = (xf + alive[:, None].astype(jnp.float32) * acc).astype(x.dtype)
        if self.error_feedback:
            new_e = jnp.where(refresh[:, None], u - m, e.reshape(n, d))
            new_e = new_e.reshape(shape)
        else:
            new_e = None
        new_b = b_new.reshape(shape) if b is not None else None
        return out.reshape(shape), new_e, new_b

    def diagnostics(self):
        delta = (self.compressor.delta(self.param_dim)
                 if self.compressor is not None and self.param_dim else 1.0)
        return spectral.compressed_diagnostics(
            self.topo, delta=delta, staleness=self.staleness)

    def wire_bytes_per_agent(self, d):
        if d is None:
            return None
        if self.compressor is None:  # faults/staleness only: dense f32
            return 4 * int(d)
        return self.compressor.bytes_on_wire(int(d))


class TimeVaryingGraphMixer(Mixer):
    """Cycles a static list of graph rounds by step index (lax.switch,
    the same derandomization contract as ``rr_static``)."""

    def __init__(self, topo: TimeVaryingTopology, *, use_kernel: bool = False):
        self.topo = topo
        self._rounds = [GraphMixer(t, use_kernel=use_kernel) for t in topo.rounds]

    def __call__(self, params, *, key, step):
        branches = [
            (lambda p, _m=m: _m(p, key=None, step=None)) for m in self._rounds
        ]
        return jax.lax.switch(step % len(self._rounds), branches, params)

    def diagnostics(self):
        return spectral.diagnostics(self.topo)


def _pop_axes_size(mesh, population_axes) -> Tuple[Tuple[str, ...], int]:
    pop_axes = tuple(a for a in population_axes if a in mesh.shape)
    pop_size = 1
    for a in pop_axes:
        pop_size *= mesh.shape[a]
    return pop_axes, pop_size


def shard_agent_index(mesh, pop_axes, n_local: int = 1):
    """Global index of this shard's first agent inside a shard_map over
    ``pop_axes`` (row-major over the axis tuple, matching the
    ``P(pop_axes)`` population sharding).  Shared by the graph-gossip
    ppermute lowering and ``build_hdo_step``'s shard_cond dispatch so
    the two linearizations can never drift apart."""
    idx = jnp.int32(0)
    stride = n_local
    for a in reversed(pop_axes):
        idx = idx + jax.lax.axis_index(a) * stride
        stride = stride * mesh.shape[a]
    return idx


class RRPpermuteMixer(Mixer):
    """TPU-native round-robin: each agent exchanges ONLY with its round
    partner over ICI (collective-permute) instead of gathering the
    whole population.  Needs one agent per population shard."""

    def __init__(self, n: int, mesh, population_axes):
        if mesh is None:
            raise ValueError("rr_ppermute needs a mesh")
        if n % 2:
            raise ValueError(f"rr_ppermute needs an even population, got n={n}")
        pop_axes, pop_size = _pop_axes_size(mesh, population_axes)
        if n != pop_size:
            raise ValueError(
                f"rr_ppermute needs one agent per population shard "
                f"(n={n}, shards={pop_size})"
            )
        self.n = n
        self.mesh = mesh
        self.pop_axes = pop_axes
        self.rr_table = round_robin_schedule(n)

    def __call__(self, params, *, key, step):
        n = self.n
        axis = self.pop_axes if len(self.pop_axes) > 1 else self.pop_axes[0]
        from jax.sharding import PartitionSpec as P

        def gossip_shard(p_l, t_l):
            def round_branch(r):
                perm = [(i, int(self.rr_table[r][i])) for i in range(n)]

                def b(p):
                    partner = jax.tree.map(
                        lambda x: jax.lax.ppermute(x, axis_name=axis, perm=perm), p
                    )
                    return jax.tree.map(
                        lambda a_, b_: (
                            (a_.astype(jnp.float32) + b_.astype(jnp.float32)) * 0.5
                        ).astype(a_.dtype),
                        p,
                        partner,
                    )

                return b

            return jax.lax.switch(
                t_l % (n - 1), [round_branch(r) for r in range(n - 1)], p_l
            )

        pspec = P(axis)
        return compat.shard_map(
            gossip_shard,
            mesh=self.mesh,
            in_specs=(pspec, P()),
            out_specs=pspec,
            axis_names=set(self.pop_axes),
            check_vma=False,
        )(params, step)


class GraphPpermuteMixer(Mixer):
    """shard_map/ppermute lowering of ``GraphMixer``.

    Permutation-column topologies (ring / torus / hypercube) keep the
    original schedule: one point-to-point exchange per neighbor slot,
    then the per-agent weighted combine — through the ``gossip_mix``
    kernel when ``use_kernel`` is set.  Irregular topologies (ER) are
    decomposed into partial-permutation rounds
    (``topology.shardmix.plan_shard_mix`` — at most ``2*Delta - 1``
    exchanges), so *every* static topology mixes over point-to-point
    ppermute instead of an all-gather."""

    def __init__(self, topo: Topology, mesh, population_axes, *,
                 use_kernel: bool = False):
        if mesh is None:
            raise ValueError("graph_ppermute needs a mesh")
        pop_axes, pop_size = _pop_axes_size(mesh, population_axes)
        if topo.n != pop_size:
            raise ValueError(
                f"graph_ppermute needs one agent per population shard "
                f"(n={topo.n}, shards={pop_size})"
            )
        # deferred to dodge a topology.__init__ import cycle
        from repro.topology import shardmix

        self.topo = topo
        self.mesh = mesh
        self.pop_axes = pop_axes
        self.use_kernel = use_kernel
        self._plan = (None if topo.columns_are_permutations()
                      else shardmix.plan_shard_mix(topo, topo.n))

    def __call__(self, params, *, key, step):
        topo = self.topo
        n, k = topo.n, topo.k
        axis = self.pop_axes if len(self.pop_axes) > 1 else self.pop_axes[0]
        w = jnp.asarray(topo.weights)
        w_self = jnp.asarray(topo.self_weight)
        from jax.sharding import PartitionSpec as P

        from repro.topology import shardmix

        def gossip_shard(p_l):
            idx = shard_agent_index(self.mesh, self.pop_axes)
            if self._plan is not None:
                # irregular topology: round-decomposed exchange; each
                # leaf is locally (1, ...) = this agent's row
                return jax.tree.map(
                    lambda x: shardmix.mix_local(
                        self._plan, topo, x, axis, idx,
                        use_kernel=self.use_kernel),
                    p_l,
                )
            w_i = w[idx]  # (k,)
            ws_i = w_self[idx]
            recvs = []
            for s in range(k):
                perm = [(int(topo.neighbors[j, s]), j) for j in range(n)]
                recvs.append(jax.tree.map(
                    lambda x: jax.lax.ppermute(x, axis_name=axis, perm=perm), p_l
                ))

            def combine(x, *nbrs):
                if self.use_kernel:
                    out = ops.gossip_mix(
                        x.reshape(-1),
                        jnp.stack([b.reshape(-1) for b in nbrs]),
                        ws_i, w_i,
                    )
                    return out.reshape(x.shape)
                acc = ws_i * x.astype(jnp.float32)
                for s in range(k):
                    acc = acc + w_i[s] * nbrs[s].astype(jnp.float32)
                return acc.astype(x.dtype)

            return jax.tree.map(combine, p_l, *recvs)

        pspec = P(axis)
        return compat.shard_map(
            gossip_shard,
            mesh=self.mesh,
            in_specs=(pspec,),
            out_specs=pspec,
            axis_names=set(self.pop_axes),
            check_vma=False,
        )(params)

    def diagnostics(self):
        return spectral.diagnostics(self.topo)


class CompressedGraphPpermuteMixer(GraphPpermuteMixer):
    """shard_map/ppermute lowering of the *fresh* compressed round: each
    neighbor slot ppermutes the (send basis, threshold, payload seed)
    triple over ICI, and every shard runs the fused ``compress_mix``
    kernel (or its jnp lowering) locally.  Payload seeds and thresholds
    match ``CompressedGraphMixer`` exactly, so the two lowerings agree
    bit-for-bit on the kernel path.  Staleness and fault injection are
    config-rejected for this mixer (buffered rounds need the gather
    path); error feedback is supported."""

    def __init__(self, topo: Topology, mesh, population_axes, *,
                 compressor, error_feedback: bool = True, seed: int = 0,
                 use_kernel: bool = False, param_dim: Optional[int] = None):
        super().__init__(topo, mesh, population_axes, use_kernel=use_kernel)
        if compressor is None:
            raise ValueError("CompressedGraphPpermuteMixer needs a compressor")
        self.compressor = compressor
        self.error_feedback = bool(error_feedback)
        self.seed = seed
        self.param_dim = param_dim

    def __call__(self, params, *, key, step):
        raise TypeError(
            "CompressedGraphPpermuteMixer is stateful; use "
            ".mix(params, key=..., step=..., comm=...)")

    def init_comm(self, params):
        if not self.error_feedback:
            return ()
        return {"residual": jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)}

    def mix(self, params, *, key, step, comm):
        topo = self.topo
        comp = self.compressor
        n, k = topo.n, topo.k
        ef = self.error_feedback
        axis = self.pop_axes if len(self.pop_axes) > 1 else self.pop_axes[0]
        weights = jnp.asarray(topo.weights)
        seeds_all = compresslib.payload_seeds(self.seed, step, n)  # (n,)
        from jax.sharding import PartitionSpec as P

        def gossip_shard(p_l, e_l, seeds_l):
            # every leaf is locally (1, ...); seeds_l is the shard's (1,)
            from repro.topology import shardmix

            idx = shard_agent_index(self.mesh, self.pop_axes)
            w_i = weights[idx]  # (k,)
            p_leaves, tdef = jax.tree.flatten(p_l)
            e_leaves = (jax.tree.leaves(e_l) if ef
                        else [None] * len(p_leaves))
            us, thrs = [], []
            for x, e in zip(p_leaves, e_leaves):
                u = x.astype(jnp.float32).reshape(-1)
                if e is not None:
                    u = u + e.reshape(-1)
                us.append(u)
                thrs.append(comp.thresholds(u[None, :]))  # (1,)
            if self._plan is not None:
                # irregular topology: exchange the (send basis,
                # threshold, seed) triple through the plan's rounds and
                # gather each slot's payload from its receive buffer
                plan = self._plan
                sb = jax.lax.dynamic_slice(
                    jnp.asarray(plan.src_buf), (idx, 0, 0), (1, 1, k))[0, 0]
                bufs_us = [shardmix.exchange_blocks(plan, u, axis)
                           for u in us]
                bufs_th = [shardmix.exchange_blocks(plan, t, axis)
                           for t in thrs]
                bufs_se = shardmix.exchange_blocks(plan, seeds_l, axis)
            else:
                recvs = []
                for s in range(k):
                    perm = [(int(topo.neighbors[j, s]), j) for j in range(n)]

                    def pp(z, _perm=perm):
                        return jax.lax.ppermute(z, axis_name=axis, perm=_perm)

                    recvs.append(([pp(u) for u in us],
                                  [pp(t) for t in thrs],
                                  pp(seeds_l)))
            outs_p, outs_e = [], []
            for li, (x, u) in enumerate(zip(p_leaves, us)):
                if self._plan is not None:
                    nbrs = bufs_us[li][sb]  # (k, d)
                    thr_vec = jnp.concatenate(
                        [thrs[li], bufs_th[li][sb][:, 0]])
                    seed_vec = jnp.concatenate(
                        [seeds_l, bufs_se[sb][:, 0]])
                else:
                    nbrs = jnp.stack([recvs[s][0][li] for s in range(k)])
                    thr_vec = jnp.concatenate(
                        [thrs[li]] + [recvs[s][1][li] for s in range(k)])
                    seed_vec = jnp.concatenate(
                        [seeds_l] + [recvs[s][2] for s in range(k)])
                flat = x.reshape(-1)
                if self.use_kernel:
                    out, new_e = ops.compress_mix(
                        flat, u, nbrs, w_i, thr_vec, seed_vec,
                        comp.mode, comp.bits)
                else:
                    d = u.shape[0]
                    pos = jnp.arange(d, dtype=jnp.uint32)
                    m_self = quantize(u, thr_vec[0], seed_vec[0], pos,
                                      mode=comp.mode, bits=comp.bits)
                    acc = flat.astype(jnp.float32)
                    for s in range(k):
                        m_s = quantize(nbrs[s], thr_vec[s + 1],
                                       seed_vec[s + 1], pos,
                                       mode=comp.mode, bits=comp.bits)
                        acc = acc + w_i[s] * (m_s - m_self)
                    out = acc.astype(x.dtype)
                    new_e = u - m_self
                outs_p.append(out.reshape(x.shape))
                outs_e.append(new_e.reshape(x.shape))
            new_p = jax.tree.unflatten(tdef, outs_p)
            if ef:
                return new_p, jax.tree.unflatten(
                    jax.tree.structure(e_l), outs_e)
            return new_p, ()

        pspec = P(axis)
        e_arg = comm["residual"] if ef else ()
        new_params, new_e = compat.shard_map(
            gossip_shard,
            mesh=self.mesh,
            in_specs=(pspec, pspec, pspec),
            out_specs=(pspec, pspec),
            axis_names=set(self.pop_axes),
            check_vma=False,
        )(params, e_arg, seeds_all)
        return new_params, ({"residual": new_e} if ef else ())

    def diagnostics(self):
        delta = (self.compressor.delta(self.param_dim)
                 if self.param_dim else 1.0)
        return spectral.compressed_diagnostics(self.topo, delta=delta)

    def wire_bytes_per_agent(self, d):
        return None if d is None else self.compressor.bytes_on_wire(int(d))


def make_mixer(cfg: HDOConfig, *, mesh=None, population_axes: Tuple[str, ...] = (),
               use_kernel: Optional[bool] = None,
               param_dim: Optional[int] = None) -> Mixer:
    """Builds the Mixer for ``cfg.gossip`` (+ topology knobs).

    ``use_kernel`` routes the graph mixers' combine through the fused
    ``gossip_mix`` / ``compress_mix`` Pallas kernels; default off the
    kernel is used on TPU only (the jnp path is the interpret-friendly
    oracle elsewhere).  ``param_dim`` (total flat parameter count, when
    the caller knows it) feeds the compression-aware spectral
    diagnostics.  When compression / staleness / faults are enabled the
    graph modes route to their stateful Compressed* lifts; otherwise
    the exact plain mixer objects are returned, keeping
    ``compression="none"`` bit-identical to the uncompressed path.
    """
    n = cfg.n_agents
    if cfg.gossip == "none" or n == 1:
        return IdentityMixer()
    if cfg.gossip == "all_reduce":
        return AllReduceMixer()
    if cfg.gossip == "dense":
        return DenseMatchingMixer(n)
    if cfg.gossip == "rr_static":
        return RoundRobinMixer(n)
    if cfg.gossip == "rr_ppermute":
        return RRPpermuteMixer(n, mesh, population_axes)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if cfg.gossip in ("graph", "graph_ppermute"):
        topo = make_topology(
            cfg.topology, n, p=cfg.topology_p, seed=cfg.topology_seed,
            rounds=cfg.topology_rounds,
        )
        compressor = compresslib.make_compressor(cfg)
        fault_spec = faultlib.FaultSpec.from_config(cfg)
        comm_active = (compressor is not None or cfg.staleness > 0
                       or fault_spec is not None)
        if cfg.gossip == "graph_ppermute":
            if isinstance(topo, TimeVaryingTopology):
                raise ValueError(
                    "graph_ppermute supports static topologies only; "
                    f"got time-varying {topo.name!r}"
                )
            if comm_active:
                return CompressedGraphPpermuteMixer(
                    topo, mesh, population_axes, compressor=compressor,
                    error_feedback=cfg.error_feedback, seed=cfg.seed,
                    use_kernel=use_kernel, param_dim=param_dim)
            return GraphPpermuteMixer(topo, mesh, population_axes,
                                      use_kernel=use_kernel)
        if isinstance(topo, TimeVaryingTopology):
            if comm_active:
                raise ValueError(
                    "compression/staleness/faults need a static topology")
            return TimeVaryingGraphMixer(topo, use_kernel=use_kernel)
        if comm_active:
            return CompressedGraphMixer(
                topo, compressor=compressor,
                error_feedback=cfg.error_feedback, staleness=cfg.staleness,
                faults=fault_spec, seed=cfg.seed, use_kernel=use_kernel,
                param_dim=param_dim)
        return GraphMixer(topo, use_kernel=use_kernel)
    raise ValueError(f"unknown gossip mode {cfg.gossip!r}")
