"""Round-decomposed ppermute lowering of static-graph gossip.

The static topologies (``topology/graphs.py``) carry slot-structured
neighbor tables ``neighbors: (n, k)``.  When the cohort is split into
``n_shards`` contiguous blocks of ``n_local`` agents (one block per
mesh shard), every cross-shard neighbor edge becomes a directed
shard-to-shard transfer of one ``(n_local, d)`` block.  This module
plans those transfers as a sequence of *partial permutations* — each
round is a set of ``(src_shard, dst_shard)`` pairs with distinct
sources and distinct destinations, exactly the contract of
``lax.ppermute`` — so the mix phase moves ``O(degree)`` blocks per
shard instead of the ``O(n_shards)`` blocks an all-gather pays.

Greedy edge coloring in slot-major discovery order needs at most
``2*Delta - 1`` rounds (Delta = max directed shard degree); for
permutation-column topologies with one agent per shard it reproduces
the slot structure exactly (one round per slot).

The combine mirrors ``GraphMixer._mix_leaf``'s jnp expression term for
term, so plan-based mixing is bit-identical to the dense gather —
``tests/test_shard.py`` pins both the numpy simulation against
``topo.mixing_matrix() @ X`` and the sharded round against the
unsharded one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.topology.graphs import Topology

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardMixPlan:
    """Static exchange plan for one topology at one shard count.

    ``rounds[r]`` is the partial permutation of round ``r``; buffer 0 is
    the shard's own block and buffer ``r + 1`` holds what round ``r``
    delivered.  ``src_buf``/``src_row`` are ``(n_shards, n_local, k)``
    gather tables: agent ``(s, i)``'s slot-``c`` neighbor lives at row
    ``src_row[s, i, c]`` of buffer ``src_buf[s, i, c]``.
    """
    n: int
    n_shards: int
    n_local: int
    rounds: Tuple[Tuple[Tuple[int, int], ...], ...]
    src_buf: np.ndarray
    src_row: np.ndarray
    n_edges: int  # directed cross-shard block edges (sum over rounds)

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def ppermute_bytes(self, d_local: int, itemsize: int = 4) -> int:
        """Cross-device bytes ONE mix moves, summed over shards: every
        directed shard edge carries one ``(n_local, d_local)`` block."""
        return self.n_edges * self.n_local * int(d_local) * itemsize

    def allgather_bytes(self, d_local: int, itemsize: int = 4) -> int:
        """What the dense fallback pays: every shard receives the other
        ``n_shards - 1`` blocks."""
        return (self.n_shards * (self.n_shards - 1)
                * self.n_local * int(d_local) * itemsize)


def plan_shard_mix(topo: Topology, n_shards: int) -> ShardMixPlan:
    """Decompose ``topo``'s neighbor table into ppermute rounds over
    ``n_shards`` contiguous agent blocks."""
    n, k = topo.n, topo.k
    if n_shards < 1 or n % n_shards != 0:
        raise ValueError(
            f"n_shards={n_shards} must divide the cohort (n={n})")
    n_local = n // n_shards
    nbr = np.asarray(topo.neighbors)

    # discover directed cross-shard edges in slot-major order so that
    # permutation-column topologies at n_shards == n color to exactly
    # one round per slot (the legacy per-slot ppermute schedule)
    edges: List[Tuple[int, int]] = []
    seen = set()
    for c in range(k):
        for i in range(n):
            src = int(nbr[i, c]) // n_local
            dst = i // n_local
            if src == dst or (src, dst) in seen:
                continue
            seen.add((src, dst))
            edges.append((src, dst))

    # greedy edge coloring: first round where both endpoints are free
    rounds: List[List[Tuple[int, int]]] = []
    round_src: List[set] = []
    round_dst: List[set] = []
    edge_round = {}
    for (src, dst) in edges:
        for r in range(len(rounds)):
            if src not in round_src[r] and dst not in round_dst[r]:
                break
        else:
            rounds.append([])
            round_src.append(set())
            round_dst.append(set())
            r = len(rounds) - 1
        rounds[r].append((src, dst))
        round_src[r].add(src)
        round_dst[r].add(dst)
        edge_round[(src, dst)] = r

    src_buf = np.zeros((n_shards, n_local, k), np.int32)
    src_row = np.zeros((n_shards, n_local, k), np.int32)
    for i in range(n):
        s, il = divmod(i, n_local)
        for c in range(k):
            j = int(nbr[i, c])
            src_row[s, il, c] = j % n_local
            t = j // n_local
            src_buf[s, il, c] = 0 if t == s else 1 + edge_round[(t, s)]

    return ShardMixPlan(
        n=n, n_shards=n_shards, n_local=n_local,
        rounds=tuple(tuple(r) for r in rounds),
        src_buf=src_buf, src_row=src_row, n_edges=len(edges))


def simulate_mix(plan: ShardMixPlan, topo: Topology, x: np.ndarray) -> np.ndarray:
    """Pure-numpy reference of the round-decomposed exchange + combine.

    Float64; must equal ``topo.mixing_matrix() @ x`` — the device-free
    correctness oracle for the plan (a shard not addressed in a round
    receives zeros, exactly like ``lax.ppermute``).
    """
    x = np.asarray(x, np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n_local, d = plan.n_local, x.shape[-1]
    blocks = x.reshape(plan.n_shards, n_local, d)
    bufs = np.zeros((plan.n_shards, plan.n_rounds + 1, n_local, d))
    bufs[:, 0] = blocks
    for r, perm in enumerate(plan.rounds):
        for (src, dst) in perm:
            bufs[dst, r + 1] = blocks[src]
    w = np.asarray(topo.weights, np.float64)
    w_self = np.asarray(topo.self_weight, np.float64)
    out = np.zeros_like(x)
    for i in range(plan.n):
        s, il = divmod(i, n_local)
        acc = w_self[i] * x[i]
        for c in range(topo.k):
            acc = acc + w[i, c] * bufs[s, plan.src_buf[s, il, c],
                                       plan.src_row[s, il, c]]
        out[i] = acc
    return out


# ---------------------------------------------------------------------------
# jax side: exchange + combine on one shard's local block


def exchange_blocks(plan: ShardMixPlan, x_local, axis_name):
    """ppermute the local block through the plan's rounds.

    Returns the stacked ``(n_rounds + 1, n_local, ...)`` receive buffers
    (buffer 0 = the shard's own block).  With no cross-shard edges
    (n_shards == 1, or a shard-local topology) no collective is issued.
    """
    import jax
    import jax.numpy as jnp

    bufs = [x_local]
    for perm in plan.rounds:
        bufs.append(jax.lax.ppermute(
            x_local, axis_name=axis_name, perm=list(perm)))
    return jnp.stack(bufs)


def gather_tables(plan: ShardMixPlan, topo: Topology, shard_idx):
    """Runtime-select this shard's gather/weight tables.

    ``shard_idx`` is a traced scalar (``shard_agent_index`` over the
    population axes), so the same program serves every shard.
    Returns ``(src_buf, src_row, weights, self_weight)`` local slices.
    """
    import jax
    import jax.numpy as jnp

    n_local, k = plan.n_local, topo.k
    sb = jax.lax.dynamic_slice(
        jnp.asarray(plan.src_buf), (shard_idx, 0, 0), (1, n_local, k))[0]
    sr = jax.lax.dynamic_slice(
        jnp.asarray(plan.src_row), (shard_idx, 0, 0), (1, n_local, k))[0]
    row0 = shard_idx * n_local
    w = jax.lax.dynamic_slice(
        jnp.asarray(topo.weights), (row0, 0), (n_local, k))
    w_self = jax.lax.dynamic_slice(
        jnp.asarray(topo.self_weight), (row0,), (n_local,))
    return sb, sr, w, w_self


def combine_local(x_local, bufs, sb, sr, w, w_self, *, use_kernel=False):
    """The Metropolis–Hastings combine on one shard's rows.

    Mirrors ``GraphMixer._mix_leaf``'s jnp expression term for term so
    sharded mixing stays bit-identical to the dense gather (padded
    self-loop slots carry weight 0 and gather the agent's own row, same
    as the dense path).  ``use_kernel`` routes through the fused
    ``gossip_mix`` Pallas kernel like ``GraphMixer``'s kernel path.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    n_local, k = sb.shape
    gathered = bufs[sb, sr]  # (n_local, k, ...)
    if use_kernel:
        flat = x_local.reshape(n_local, -1)
        nbrs = gathered.reshape(n_local, k, -1)
        out = jax.vmap(ops.gossip_mix)(flat, nbrs, w_self, w)
        return out.reshape(x_local.shape)
    tail = (1,) * (x_local.ndim - 1)
    acc = w_self.reshape((n_local,) + tail) * x_local.astype(jnp.float32)
    acc = acc + (w.reshape((n_local, k) + tail)
                 * gathered.astype(jnp.float32)).sum(axis=1)
    return acc.astype(x_local.dtype)


def mix_local(plan: ShardMixPlan, topo: Topology, x_local, axis_name,
              shard_idx, *, use_kernel=False):
    """exchange + combine for one leaf's local ``(n_local, ...)`` block."""
    bufs = exchange_blocks(plan, x_local, axis_name)
    sb, sr, w, w_self = gather_tables(plan, topo, shard_idx)
    return combine_local(x_local, bufs, sb, sr, w, w_self,
                         use_kernel=use_kernel)
