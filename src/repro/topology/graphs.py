"""Graph topologies for weighted gossip (the paper's interaction step,
generalized).

The paper mixes with random disjoint pairings; any symmetric
doubly-stochastic mixing matrix W contracts the consensus potential
Gamma_t the same way, at a rate set by W's second-largest eigenvalue
modulus (see ``repro.topology.spectral``).  This module builds the
standard communication graphs and equips them with Metropolis–Hastings
weights

    W_ij = 1 / (1 + max(deg_i, deg_j))   for j in N(i),
    W_ii = 1 - sum_j W_ij,

which are symmetric doubly-stochastic for *any* undirected graph, so
every topology here preserves the population mean exactly.

A ``Topology`` stores a static padded neighbor table (``(n, k)``;
nodes with fewer than k neighbors are padded with themselves at weight
0) so the mixing step is a trace-time-constant gather — and, when
every neighbor-table column is a permutation (ring / torus /
hypercube by construction), a ``ppermute``-lowerable exchange.
Time-varying topologies are a cycle of static rounds selected by step
index, the same derandomization contract as ``rr_static``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.gossip import round_robin_schedule

__all__ = [
    "Topology",
    "TimeVaryingTopology",
    "ring",
    "torus",
    "hypercube",
    "erdos_renyi",
    "matching_topology",
    "tv_round_robin",
    "tv_erdos_renyi",
    "make_topology",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A static undirected communication graph with MH mixing weights.

    ``neighbors[i, s]`` is node i's s-th neighbor (slot order is
    direction-structured for the lattice graphs, so columns are
    permutations); ``weights[i, s]`` its mixing weight (0 on padded
    self-slots); ``self_weight[i]`` = W_ii.
    """

    name: str
    n: int
    neighbors: np.ndarray  # (n, k) int32
    weights: np.ndarray  # (n, k) float32
    self_weight: np.ndarray  # (n,) float32

    @property
    def k(self) -> int:
        return self.neighbors.shape[1]

    def mixing_matrix(self) -> np.ndarray:
        """Dense (n, n) float64 W — the analysis-side view."""
        W = np.zeros((self.n, self.n), np.float64)
        for i in range(self.n):
            W[i, i] += float(self.self_weight[i])
            for s in range(self.k):
                W[i, int(self.neighbors[i, s])] += float(self.weights[i, s])
        return W

    def columns_are_permutations(self) -> bool:
        """True when every neighbor slot is a global permutation — the
        precondition for the shard_map/ppermute lowering."""
        ar = np.arange(self.n)
        return all(
            np.array_equal(np.sort(self.neighbors[:, s]), ar)
            for s in range(self.k)
        )


@dataclasses.dataclass(frozen=True)
class TimeVaryingTopology:
    """A cycle of static topologies selected by ``step % len(rounds)``."""

    name: str
    n: int
    rounds: Tuple[Topology, ...]

    @property
    def cycle_len(self) -> int:
        return len(self.rounds)


def _mh_topology(name: str, n: int, nbr_lists: Sequence[Sequence[int]]) -> Topology:
    """Builds a Topology from slot-ordered adjacency lists with
    Metropolis–Hastings weights, padding ragged rows with self-loops at
    weight 0."""
    deg = np.array([len(nb) for nb in nbr_lists], np.int64)
    if n > 1 and (deg == 0).any():
        raise ValueError(f"{name}: isolated node (zero degree) in topology")
    k = int(deg.max()) if n > 1 else 1
    neighbors = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, k))
    weights = np.zeros((n, k), np.float32)
    for i, nbrs in enumerate(nbr_lists):
        if len(set(nbrs)) != len(nbrs):
            raise ValueError(f"{name}: duplicate neighbor in slot list of node {i}")
        for s, j in enumerate(nbrs):
            if j == i:
                raise ValueError(f"{name}: self-loop listed as neighbor of node {i}")
            neighbors[i, s] = j
            weights[i, s] = 1.0 / (1.0 + max(int(deg[i]), int(deg[j])))
    self_weight = (1.0 - weights.sum(axis=1)).astype(np.float32)
    topo = Topology(name=name, n=n, neighbors=neighbors, weights=weights,
                    self_weight=self_weight)
    W = topo.mixing_matrix()
    assert np.allclose(W, W.T), name
    assert np.allclose(W.sum(axis=1), 1.0) and (W >= -1e-12).all(), name
    return topo


def ring(n: int) -> Topology:
    """Cycle graph; slots are (left, right) shifts, so columns are
    permutations.  n == 2 degenerates to the single-edge matching."""
    if n < 2:
        raise ValueError(f"ring needs n >= 2, got {n}")
    if n == 2:
        return _mh_topology("ring", 2, [[1], [0]])
    return _mh_topology("ring", n, [[(i - 1) % n, (i + 1) % n] for i in range(n)])


def _torus_dims(n: int) -> Tuple[int, int]:
    r = int(np.sqrt(n))
    while r >= 2:
        if n % r == 0:
            return r, n // r
        r -= 1
    raise ValueError(f"torus needs n = rows * cols with rows, cols >= 2, got n={n}")


def torus(n: int, rows: int | None = None) -> Topology:
    """2-D periodic lattice; slots are (up, down, left, right) shifts
    (deduplicated when a dimension has length 2)."""
    if rows is None:
        rows, cols = _torus_dims(n)
    else:
        if rows < 2 or n % rows or n // rows < 2:
            raise ValueError(f"torus: invalid rows={rows} for n={n}")
        cols = n // rows
    nbrs: List[List[int]] = []
    for i in range(n):
        r, c = divmod(i, cols)
        cand = [
            ((r - 1) % rows) * cols + c,
            ((r + 1) % rows) * cols + c,
            r * cols + (c - 1) % cols,
            r * cols + (c + 1) % cols,
        ]
        seen: List[int] = []
        for j in cand:
            if j not in seen:
                seen.append(j)
        nbrs.append(seen)
    return _mh_topology("torus", n, nbrs)


def hypercube(n: int) -> Topology:
    """log2(n)-dimensional hypercube; slot b flips bit b (an involution
    permutation)."""
    if n < 2 or n & (n - 1):
        raise ValueError(f"hypercube needs n a power of two >= 2, got {n}")
    dim = n.bit_length() - 1
    return _mh_topology("hypercube", n, [[i ^ (1 << b) for b in range(dim)] for i in range(n)])


def erdos_renyi(n: int, p: float = 0.3, seed: int = 0, *,
                require_connected: bool = True, max_tries: int = 100) -> Topology:
    """G(n, p) random graph.  With ``require_connected`` the sample is
    redrawn (seed+1, seed+2, ...) until connected — a disconnected W
    has lambda_2 = 1 and never reaches consensus."""
    if n < 2:
        raise ValueError(f"erdos_renyi needs n >= 2, got {n}")
    for attempt in range(max_tries):
        rng = np.random.default_rng(seed + attempt)
        upper = rng.random((n, n)) < p
        adj = np.triu(upper, 1)
        adj = adj | adj.T
        if not require_connected or _connected(adj):
            nbrs = [list(np.flatnonzero(adj[i])) for i in range(n)]
            return _mh_topology("erdos_renyi", n, nbrs)
    raise ValueError(
        f"erdos_renyi(n={n}, p={p}): no connected sample in {max_tries} tries "
        "(raise topology_p)"
    )


def _connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.flatnonzero(adj[i]):
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


def matching_topology(partner: np.ndarray, name: str = "matching") -> Topology:
    """A perfect matching as a 1-regular graph.  MH weights give each
    pair (1/2, 1/2) — exactly the paper's pairwise averaging."""
    n = len(partner)
    assert (partner[partner] == np.arange(n)).all(), "not an involution"
    return _mh_topology(name, n, [[int(partner[i])] for i in range(n)])


def tv_round_robin(n: int) -> TimeVaryingTopology:
    """The round-robin tournament expressed as a time-varying graph:
    round r is the matching rr_schedule[r], so this reproduces
    ``rr_static``'s averaging semantics through the weighted-mixing
    path.  The cycle length is structurally n - 1."""
    if n % 2 or n < 2:
        raise ValueError(f"tv_round_robin needs an even population, got n={n}")
    sched = round_robin_schedule(n)
    rounds = tuple(
        matching_topology(sched[r], name=f"rr_match_{r}") for r in range(len(sched))
    )
    return TimeVaryingTopology(name="tv_round_robin", n=n, rounds=rounds)


def tv_erdos_renyi(n: int, p: float = 0.3, seed: int = 0, rounds: int = 8) -> TimeVaryingTopology:
    """A cycle of independent G(n, p) samples — randomized gossip with a
    trace-time-static schedule."""
    tops = tuple(
        erdos_renyi(n, p, seed=seed + 1000 * r) for r in range(rounds)
    )
    return TimeVaryingTopology(name="tv_erdos_renyi", n=n, rounds=tops)


def make_topology(name: str, n: int, *, p: float = 0.3, seed: int = 0,
                  rounds: int = 8):
    """Topology factory keyed by ``HDOConfig.topology``."""
    if name == "ring":
        return ring(n)
    if name == "torus":
        return torus(n)
    if name == "hypercube":
        return hypercube(n)
    if name == "erdos_renyi":
        return erdos_renyi(n, p, seed)
    if name == "tv_round_robin":
        return tv_round_robin(n)
    if name == "tv_erdos_renyi":
        return tv_erdos_renyi(n, p, seed, rounds)
    raise ValueError(f"unknown topology {name!r}")
