"""Graph-topology communication subsystem.

Generalizes the paper's random-pairing interaction step to weighted
mixing-matrix gossip over static (and time-varying) neighbor graphs:

  * ``graphs``   — topology constructors (ring, torus, hypercube,
                   Erdős–Rényi, time-varying variants) emitting
                   Metropolis–Hastings doubly-stochastic weights with
                   static neighbor tables;
  * ``spectral`` — lambda_2 / spectral-gap diagnostics and the
                   predicted per-round Gamma_t contraction;
  * ``mixer``    — the ``Mixer`` interface ``build_hdo_step`` consumes
                   (all legacy gossip modes + the graph modes and their
                   shard_map/ppermute lowerings).

plus the communication-reduced, fault-tolerant layer on top:

  * ``compress`` — payload compressors (top-k, qsgd stochastic
                   quantization) with error feedback, the bytes-on-wire
                   accounting, and the ``HDOState.comm`` structure;
  * ``faults``   — replayable drop / straggler / byzantine injection on
                   the counter-based RNG.

See ``kernels/gossip_mix.py`` for the fused k-neighbor combine kernel
and ``kernels/compress_mix.py`` for its compressed difference-form
sibling.
"""
from repro.topology import compress, faults
from repro.topology.compress import Compressor, make_compressor
from repro.topology.faults import FaultSpec, fault_masks
from repro.topology.graphs import (
    TimeVaryingTopology,
    Topology,
    erdos_renyi,
    hypercube,
    make_topology,
    matching_topology,
    ring,
    torus,
    tv_erdos_renyi,
    tv_round_robin,
)
from repro.topology.mixer import (
    AllReduceMixer,
    CompressedGraphMixer,
    CompressedGraphPpermuteMixer,
    DenseMatchingMixer,
    GraphMixer,
    GraphPpermuteMixer,
    IdentityMixer,
    Mixer,
    RRPpermuteMixer,
    RoundRobinMixer,
    TimeVaryingGraphMixer,
    make_mixer,
)
from repro.topology.spectral import (
    compressed_diagnostics,
    compression_delta,
    diagnostics,
    effective_slem,
    mixing_eigenvalues,
    predicted_contraction,
    predicted_contraction_empirical,
    slem,
    spectral_gap,
    tail_rate,
)

__all__ = [
    "Topology",
    "TimeVaryingTopology",
    "ring",
    "torus",
    "hypercube",
    "erdos_renyi",
    "matching_topology",
    "tv_round_robin",
    "tv_erdos_renyi",
    "make_topology",
    "Mixer",
    "IdentityMixer",
    "AllReduceMixer",
    "DenseMatchingMixer",
    "RoundRobinMixer",
    "GraphMixer",
    "TimeVaryingGraphMixer",
    "RRPpermuteMixer",
    "GraphPpermuteMixer",
    "CompressedGraphMixer",
    "CompressedGraphPpermuteMixer",
    "make_mixer",
    "compress",
    "faults",
    "Compressor",
    "make_compressor",
    "FaultSpec",
    "fault_masks",
    "mixing_eigenvalues",
    "slem",
    "spectral_gap",
    "predicted_contraction",
    "diagnostics",
    "compressed_diagnostics",
    "compression_delta",
    "effective_slem",
    "predicted_contraction_empirical",
    "tail_rate",
]
