"""Graph-topology communication subsystem.

Generalizes the paper's random-pairing interaction step to weighted
mixing-matrix gossip over static (and time-varying) neighbor graphs:

  * ``graphs``   — topology constructors (ring, torus, hypercube,
                   Erdős–Rényi, time-varying variants) emitting
                   Metropolis–Hastings doubly-stochastic weights with
                   static neighbor tables;
  * ``spectral`` — lambda_2 / spectral-gap diagnostics and the
                   predicted per-round Gamma_t contraction;
  * ``mixer``    — the ``Mixer`` interface ``build_hdo_step`` consumes
                   (all legacy gossip modes + the graph modes and their
                   shard_map/ppermute lowerings).

See ``kernels/gossip_mix.py`` for the fused k-neighbor combine kernel.
"""
from repro.topology.graphs import (
    TimeVaryingTopology,
    Topology,
    erdos_renyi,
    hypercube,
    make_topology,
    matching_topology,
    ring,
    torus,
    tv_erdos_renyi,
    tv_round_robin,
)
from repro.topology.mixer import (
    AllReduceMixer,
    DenseMatchingMixer,
    GraphMixer,
    GraphPpermuteMixer,
    IdentityMixer,
    Mixer,
    RRPpermuteMixer,
    RoundRobinMixer,
    TimeVaryingGraphMixer,
    make_mixer,
)
from repro.topology.spectral import (
    diagnostics,
    mixing_eigenvalues,
    predicted_contraction,
    slem,
    spectral_gap,
)

__all__ = [
    "Topology",
    "TimeVaryingTopology",
    "ring",
    "torus",
    "hypercube",
    "erdos_renyi",
    "matching_topology",
    "tv_round_robin",
    "tv_erdos_renyi",
    "make_topology",
    "Mixer",
    "IdentityMixer",
    "AllReduceMixer",
    "DenseMatchingMixer",
    "RoundRobinMixer",
    "GraphMixer",
    "TimeVaryingGraphMixer",
    "RRPpermuteMixer",
    "GraphPpermuteMixer",
    "make_mixer",
    "mixing_eigenvalues",
    "slem",
    "spectral_gap",
    "predicted_contraction",
    "diagnostics",
]
