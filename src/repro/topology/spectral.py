"""Spectral diagnostics for gossip mixing matrices.

For a symmetric doubly-stochastic W applied to the stacked population
X (one agent per row), the deviation from the mean evolves as

    X_{t+1} - 1 mu = (W - 11^T/n) (X_t - 1 mu),

so the consensus potential Gamma_t = (1/n) ||X_t - 1 mu||_F^2
contracts per gossip round by (asymptotically exactly, for generic X)

    Gamma_{t+1} / Gamma_t -> slem(W)^2,

where slem is the second-largest eigenvalue *modulus* (the spectral
radius of W restricted to the consensus-orthogonal subspace).  These
are the numbers ``build_hdo_step`` surfaces as training metrics next
to ``consensus_distance``, and the prediction the empirical tests in
``tests/test_topology.py`` validate against measured Gamma_t.

For a time-varying cycle W_0, ..., W_{L-1} the per-cycle deviation
operator is M = (W_{L-1} - J) ... (W_0 - J) (J = 11^T/n); we report
the per-round geometric mean ||M||_2^(2/L) as the predicted
contraction.  A single matching round has slem = 1 (it only averages
within pairs), yet the full cycle can contract strongly — the
per-cycle norm captures that.
"""
from __future__ import annotations

from typing import Union

import numpy as np

from repro.topology.graphs import TimeVaryingTopology, Topology

__all__ = [
    "mixing_eigenvalues",
    "slem",
    "spectral_gap",
    "predicted_contraction",
    "diagnostics",
    "compression_delta",
    "effective_slem",
    "compressed_diagnostics",
    "tail_rate",
    "predicted_contraction_empirical",
]

AnyTopology = Union[Topology, TimeVaryingTopology]


def mixing_eigenvalues(topo: Topology) -> np.ndarray:
    """Eigenvalues of W, descending (W symmetric => real)."""
    return np.linalg.eigvalsh(topo.mixing_matrix())[::-1]


def _deviation_norm(topo: Topology) -> float:
    """||W - J||_2 on the full space == slem on the 1-orthogonal
    subspace (J = 11^T/n is W's projection onto the consensus line)."""
    n = topo.n
    M = topo.mixing_matrix() - np.ones((n, n)) / n
    return float(np.linalg.norm(M, 2))


def slem(topo: AnyTopology) -> float:
    """Second-largest eigenvalue modulus of W (per-round, for
    time-varying: geometric mean over the cycle of the product norm)."""
    if isinstance(topo, TimeVaryingTopology):
        return float(_cycle_norm(topo) ** (1.0 / topo.cycle_len))
    return _deviation_norm(topo)


def _cycle_norm(topo: TimeVaryingTopology) -> float:
    n = topo.n
    J = np.ones((n, n)) / n
    M = np.eye(n)
    for t in topo.rounds:  # round 0 applied first => left-multiplied first
        M = (t.mixing_matrix() - J) @ M
    return float(np.linalg.norm(M, 2))


def spectral_gap(topo: AnyTopology) -> float:
    """1 - slem: the consensus-rate figure of merit (bigger = faster)."""
    return 1.0 - slem(topo)


def predicted_contraction(topo: AnyTopology) -> float:
    """Predicted asymptotic per-round Gamma_{t+1}/Gamma_t (= slem^2)."""
    return slem(topo) ** 2


def diagnostics(topo: AnyTopology) -> dict:
    """The metric dict ``build_hdo_step`` merges into training metrics."""
    s = slem(topo)
    return {
        "gossip_lambda2": s,
        "gossip_spectral_gap": 1.0 - s,
        "gossip_gamma_contraction": s * s,
    }


# ---------------------------------------------------------------------------
# Compression / staleness-aware predictions
#
# Under payload compression only a fraction delta in (0, 1] of the
# deviation mass moves per round (topology.compress.Compressor.delta),
# and under staleness bound tau each agent refreshes its broadcast only
# every tau+1 rounds, so the effective per-round averaging strength
# scales by delta / (1 + tau):
#
#     effective_slem = 1 - (1 - slem) * delta / (1 + tau).
#
# That closed form is the cheap static diagnostic.  The honest
# test-grade prediction is ``predicted_contraction_empirical`` below: an
# independent numpy Monte-Carlo of the exact round dynamics (difference
# form, error feedback, staggered refresh) on Gaussian ensembles — the
# number the fault-injection suite compares measured Gamma against.
# ---------------------------------------------------------------------------


def compression_delta(mode: str, d: int, *, k: int = 0, bits: int = 0) -> float:
    """Energy fraction delta in (0, 1] a payload carries per round
    (matches topology.compress.Compressor.delta; "none" -> 1.0)."""
    if mode == "none":
        return 1.0
    if mode == "topk":
        return min(k, d) / float(d)
    s = float((1 << bits) - 1)
    omega = min(d / (s * s), float(np.sqrt(d)) / s)
    return 1.0 / (1.0 + omega)


def effective_slem(topo: AnyTopology, *, delta: float = 1.0,
                   staleness: int = 0) -> float:
    """Closed-form effective slem under compression ratio ``delta`` and
    staleness bound ``staleness`` (reduces to slem when delta=1, tau=0)."""
    s = slem(topo)
    return 1.0 - (1.0 - s) * delta / (1.0 + staleness)


def compressed_diagnostics(topo: AnyTopology, *, delta: float = 1.0,
                           staleness: int = 0) -> dict:
    """``diagnostics`` extended with the compression/staleness-aware
    contraction: ``gossip_lambda2`` stays the raw graph slem, while
    ``gossip_gamma_contraction`` becomes effective_slem^2."""
    s = slem(topo)
    se = effective_slem(topo, delta=delta, staleness=staleness)
    return {
        "gossip_lambda2": s,
        "gossip_spectral_gap": 1.0 - s,
        "gossip_gamma_contraction": se * se,
        "gossip_effective_lambda2": se,
        "gossip_compress_delta": float(delta),
        "gossip_staleness": float(staleness),
    }


def _compress_np(u: np.ndarray, mode: str, k: int, bits: int,
                 rng: np.random.Generator) -> np.ndarray:
    """Row-wise compress+decompress in pure numpy — an independent
    reimplementation of the payload math (NOT shared with the kernels),
    so the Monte-Carlo prediction cannot inherit a kernel bug."""
    if mode == "none":
        return u.copy()
    if mode == "topk":
        kk = min(k, u.shape[1])
        thr = -np.sort(-np.abs(u), axis=1)[:, kk - 1]
        return np.where(np.abs(u) >= thr[:, None], u, 0.0)
    if mode == "qsgd":
        s = float((1 << bits) - 1)
        scale = np.maximum(np.abs(u).max(axis=1), 1e-12)
        y = np.abs(u) / scale[:, None] * s
        lo = np.floor(y)
        b = (rng.random(u.shape) < (y - lo)).astype(np.float64)
        return np.sign(u) * scale[:, None] * (lo + b) / s
    raise ValueError(f"unknown compression mode {mode!r}")


def tail_rate(gammas, *, staleness: int = 0, warmup: int | None = None) -> float:
    """Per-round geometric-mean contraction over the tail of a Gamma_t
    trace, with the span aligned to a multiple of the staleness period
    (tau + 1) so the staggered-refresh oscillation averages out.  The
    SAME estimator is applied to measured and Monte-Carlo traces."""
    g = np.asarray(gammas, dtype=np.float64)
    warm = len(g) // 3 if warmup is None else warmup
    period = staleness + 1
    span = ((len(g) - 1 - warm) // period) * period
    if span <= 0:
        raise ValueError(f"trace too short: {len(g)} rounds, warmup {warm}")
    start = len(g) - 1 - span
    return float((g[-1] / g[start]) ** (1.0 / span))


def predicted_contraction_empirical(
    topo: Topology,
    *,
    compression: str = "none",
    k: int = 0,
    bits: int = 0,
    error_feedback: bool = True,
    staleness: int = 0,
    rounds: int = 36,
    dim: int = 64,
    trials: int = 4,
    seed: int = 0,
) -> float:
    """Monte-Carlo per-round Gamma contraction under compression +
    staleness: simulates the exact mixer round dynamics (difference-form
    combine, error feedback, staggered broadcast refresh) on Gaussian
    start ensembles in float64 numpy and returns the geometric-mean
    tail rate.  With ``compression="none"`` and ``staleness=0`` this
    converges to ``predicted_contraction`` (= slem^2)."""
    W = np.asarray(topo.mixing_matrix(), dtype=np.float64)
    n = topo.n
    A = W - np.diag(np.diag(W))     # off-diagonal (neighbor) weights
    rows = A.sum(axis=1)            # = 1 - W_ii (the self-subtraction)
    ef = compression != "none" and error_feedback
    rng = np.random.default_rng(seed)
    rates = []
    for _ in range(trials):
        X = rng.standard_normal((n, dim))
        e = np.zeros_like(X)
        b = X.copy()
        gammas = []
        for t in range(rounds):
            u = X + e if ef else X.copy()
            m = _compress_np(u, compression, k, bits, rng)
            refresh = ((t + np.arange(n)) % (staleness + 1)) == 0
            b[refresh] = m[refresh]
            if ef:
                e[refresh] = u[refresh] - m[refresh]
            X = X + A @ b - rows[:, None] * b
            mu = X.mean(axis=0, keepdims=True)
            gammas.append(float(((X - mu) ** 2).sum() / n))
        rates.append(np.log(tail_rate(gammas, staleness=staleness)))
    return float(np.exp(np.mean(rates)))
