"""Spectral diagnostics for gossip mixing matrices.

For a symmetric doubly-stochastic W applied to the stacked population
X (one agent per row), the deviation from the mean evolves as

    X_{t+1} - 1 mu = (W - 11^T/n) (X_t - 1 mu),

so the consensus potential Gamma_t = (1/n) ||X_t - 1 mu||_F^2
contracts per gossip round by (asymptotically exactly, for generic X)

    Gamma_{t+1} / Gamma_t -> slem(W)^2,

where slem is the second-largest eigenvalue *modulus* (the spectral
radius of W restricted to the consensus-orthogonal subspace).  These
are the numbers ``build_hdo_step`` surfaces as training metrics next
to ``consensus_distance``, and the prediction the empirical tests in
``tests/test_topology.py`` validate against measured Gamma_t.

For a time-varying cycle W_0, ..., W_{L-1} the per-cycle deviation
operator is M = (W_{L-1} - J) ... (W_0 - J) (J = 11^T/n); we report
the per-round geometric mean ||M||_2^(2/L) as the predicted
contraction.  A single matching round has slem = 1 (it only averages
within pairs), yet the full cycle can contract strongly — the
per-cycle norm captures that.
"""
from __future__ import annotations

from typing import Union

import numpy as np

from repro.topology.graphs import TimeVaryingTopology, Topology

__all__ = [
    "mixing_eigenvalues",
    "slem",
    "spectral_gap",
    "predicted_contraction",
    "diagnostics",
]

AnyTopology = Union[Topology, TimeVaryingTopology]


def mixing_eigenvalues(topo: Topology) -> np.ndarray:
    """Eigenvalues of W, descending (W symmetric => real)."""
    return np.linalg.eigvalsh(topo.mixing_matrix())[::-1]


def _deviation_norm(topo: Topology) -> float:
    """||W - J||_2 on the full space == slem on the 1-orthogonal
    subspace (J = 11^T/n is W's projection onto the consensus line)."""
    n = topo.n
    M = topo.mixing_matrix() - np.ones((n, n)) / n
    return float(np.linalg.norm(M, 2))


def slem(topo: AnyTopology) -> float:
    """Second-largest eigenvalue modulus of W (per-round, for
    time-varying: geometric mean over the cycle of the product norm)."""
    if isinstance(topo, TimeVaryingTopology):
        return float(_cycle_norm(topo) ** (1.0 / topo.cycle_len))
    return _deviation_norm(topo)


def _cycle_norm(topo: TimeVaryingTopology) -> float:
    n = topo.n
    J = np.ones((n, n)) / n
    M = np.eye(n)
    for t in topo.rounds:  # round 0 applied first => left-multiplied first
        M = (t.mixing_matrix() - J) @ M
    return float(np.linalg.norm(M, 2))


def spectral_gap(topo: AnyTopology) -> float:
    """1 - slem: the consensus-rate figure of merit (bigger = faster)."""
    return 1.0 - slem(topo)


def predicted_contraction(topo: AnyTopology) -> float:
    """Predicted asymptotic per-round Gamma_{t+1}/Gamma_t (= slem^2)."""
    return slem(topo) ** 2


def diagnostics(topo: AnyTopology) -> dict:
    """The metric dict ``build_hdo_step`` merges into training metrics."""
    s = slem(topo)
    return {
        "gossip_lambda2": s,
        "gossip_spectral_gap": 1.0 - s,
        "gossip_gamma_contraction": s * s,
    }
