"""HDO training driver (CPU-runnable).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --smoke --steps 100 --agents 8 --zo 4 --estimator multi_rv

Trains the (reduced) architecture with the HDO population on a
synthetic LM stream, logging per-step metrics and checkpointing at the
end.  ``--arch brackets`` trains the paper's Transformer-on-Dyck task.

Gossip topologies: besides the paper's random pairing (``--gossip
dense``), ``--gossip graph --topology {ring,torus,hypercube,
erdos_renyi,tv_round_robin,tv_erdos_renyi}`` mixes with
Metropolis–Hastings doubly-stochastic weights over a static neighbor
graph (see ``repro.topology``); the step then also logs the spectral
diagnostics (lambda_2, spectral gap, predicted Gamma contraction).

Heterogeneous populations: ``--sigmas/--rvs/--estimators-zo`` take CSV
values cycled over the ZO cohort and ``--lrs`` over the whole
population, e.g. ``--zo 4 --sigmas 1e-3,1e-1`` alternates a clean and
a noisy ZO agent; ``--estimators-zo multi_rv,fwd_grad`` mixes kinds.
The step then logs per-group gradient-estimate variance
(``grad_var_zo_<kind>`` / ``grad_var_fo``) and per-group loss
trajectories (``loss_zo_<kind>_mean``).

Local update: ``--optimizer {sgd,adamw}`` picks the LocalUpdate rule,
``--local-steps H`` runs H estimate+update iterations per gossip round
on H fresh batches (periodic averaging — communication drops to 1/H
per estimator pass), ``--clip-norm`` clips each agent's gradient by
its global norm.  ``--ckpt`` + ``--save-every`` checkpoint the full
HDOState (params + opt_state + step + gossip comm state); ``--resume``
continues a run bit-identically.

Communication-reduced / fault-tolerant gossip (graph modes only):
``--compression {topk,qsgd}`` (+ ``--compress-k`` / ``--compress-bits``)
compresses every broadcast payload with error feedback
(``--no-error-feedback`` disables the residual stream),
``--staleness tau`` lets agents rebroadcast only every tau+1 rounds
(staggered), and ``--fault-drop-rate`` / ``--fault-straggler-rate`` /
``--fault-byzantine-rate`` inject replayable per-round agent faults
(see ``repro.topology.faults``).

Observability (``repro.obs``): every log line flows through the
schema-checked ``MetricsLogger`` (stdout JSON by default).
``--metrics-out run.jsonl`` adds a structured sink (JSONL; ``*.csv`` /
``-`` / ``tb:<logdir>``), writes a run-manifest header (config hash,
plane manifest hash, jax/device identity), turns on the extended
per-agent health metrics (per-agent loss/consensus vectors, fault
counters, measured ``gossip_wire_bytes`` with a cumulative
``wire_mib_total``), and samples fenced per-phase timing records
(``phase_ms_{estimate,update,mix}`` vs the fused round, compile vs
steady state, achieved HBM GB/s).  Wall-clock is honest: the first
(compiling) dispatch is reported once as ``compile_s`` and ``wall_s``
counts steady-state rounds only.  ``--profile-dir`` captures an xprof
trace over a few steady-state rounds; ``--trace-phases`` additionally
dispatches sampled rounds as three separately-jitted phase calls under
``TraceAnnotation``s (observe-only — the training trajectory is
bit-identical with all of this on or off).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import (
    COMPRESSIONS,
    GOSSIP_MODES,
    HDOConfig,
    OPTIMIZERS,
    PARAM_LAYOUTS,
    TOPOLOGIES,
    ZO_ESTIMATORS,
    ZO_IMPLS,
)
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.core import plane as planelib
from repro.core.population import parse_csv, tile
from repro.data import AgentBatcher, brackets, synthetic
from repro.models import build_model
from repro.obs import MetricsLogger, ProfileSchedule, StdoutSink, make_sink, run_manifest
from repro.obs import timing as obstiming


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--zo", type=int, default=4)
    ap.add_argument("--estimator", default="multi_rv", choices=list(ZO_ESTIMATORS))
    ap.add_argument("--zo-impl", default="tree", choices=list(ZO_IMPLS),
                    help="ZO engine: pytree estimators vs the flat-parameter "
                         "fused Pallas path (O(d) HBM traffic per estimate)")
    ap.add_argument("--rv", type=int, default=4)
    # per-agent heterogeneity: CSV values are cycled to the cohort
    # length (one value broadcasts), validated by HDOConfig
    ap.add_argument("--sigmas", default=None, metavar="CSV",
                    help="per-ZO-agent smoothing radii, cycled over the "
                         "ZO cohort (overrides --nu heterogeneously)")
    ap.add_argument("--rvs", default=None, metavar="CSV",
                    help="per-ZO-agent random-vector counts (ragged rv: "
                         "groups pad to their max and mask excess draws)")
    ap.add_argument("--lrs", default=None, metavar="CSV",
                    help="per-agent base learning rates, cycled over ALL "
                         "agents (schedule shape stays shared)")
    ap.add_argument("--estimators-zo", default=None, metavar="CSV",
                    help="per-ZO-agent estimator kinds (mixed populations), "
                         f"each one of {ZO_ESTIMATORS}")
    # choices derive from configs.base so the CLI can never drift from
    # what HDOConfig.__post_init__ accepts (single-source rule); the
    # ppermute lowerings additionally need a mesh (--mesh-agents),
    # validated after parse
    ap.add_argument("--gossip", default="dense",
                    choices=list(GOSSIP_MODES),
                    help="interaction step: paper's random pairing (dense), "
                         "round-robin tournament, graph-topology weighted "
                         "mixing (or its ppermute lowering under "
                         "--mesh-agents), all_reduce, or none")
    ap.add_argument("--mesh-agents", type=int, default=0,
                    help="shard the WHOLE round over an agents x model "
                         "device mesh with this many population shards "
                         "(must divide --agents; 0 = single-host step, "
                         "no mesh).  See docs/sharding.md")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="model-parallel shards of the mesh: under "
                         "--param-layout plane the flat dim axis "
                         "FSDP-shards into BLOCK-aligned chunks "
                         "(needs --mesh-agents)")
    ap.add_argument("--topology", default="ring", choices=list(TOPOLOGIES),
                    help="neighbor graph for --gossip graph/graph_ppermute "
                         "(Metropolis–Hastings doubly-stochastic weights)")
    ap.add_argument("--topology-p", type=float, default=0.3,
                    help="Erdős–Rényi edge probability")
    ap.add_argument("--topology-seed", type=int, default=0,
                    help="seed for randomized topologies")
    ap.add_argument("--topology-rounds", type=int, default=8,
                    help="cycle length for tv_erdos_renyi (tv_round_robin "
                         "always cycles its n-1 tournament rounds)")
    # communication-reduced / fault-tolerant gossip (graph modes only;
    # HDOConfig.__post_init__ validates the combinations)
    ap.add_argument("--compression", default="none", choices=list(COMPRESSIONS),
                    help="gossip payload compression: top-k sparsification "
                         "or qsgd stochastic quantization (difference-form "
                         "mixing keeps the population mean exact)")
    ap.add_argument("--compress-k", type=int, default=0,
                    help="kept coordinates per payload for --compression topk")
    ap.add_argument("--compress-bits", type=int, default=4,
                    help="quantization bits per coordinate for "
                         "--compression qsgd (levels = 2^bits - 1)")
    ap.add_argument("--error-feedback", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="carry per-agent compression residuals in "
                         "HDOState.comm and re-send them next round")
    ap.add_argument("--staleness", type=int, default=0,
                    help="staleness bound tau: agents rebroadcast only every "
                         "tau+1 rounds (staggered), neighbors mix against "
                         "buffered payloads at most tau rounds old")
    ap.add_argument("--fault-drop-rate", type=float, default=0.0,
                    help="per-round probability an agent is offline "
                         "(drops out of the mix symmetrically)")
    ap.add_argument("--fault-straggler-rate", type=float, default=0.0,
                    help="per-round probability an agent's broadcast fails "
                         "to land (neighbors keep its last buffered payload)")
    ap.add_argument("--fault-byzantine-rate", type=float, default=0.0,
                    help="per-round probability an agent transmits an "
                         "adversarially corrupted payload")
    ap.add_argument("--fault-byzantine-scale", type=float, default=10.0,
                    help="magnitude of the byzantine corruption "
                         "(payload -> -scale * payload)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the replayable fault schedule "
                         "(counter-RNG over (seed, round, agent))")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--optimizer", default="sgd", choices=list(OPTIMIZERS),
                    help="local-update rule between estimate and gossip "
                         "(the LocalUpdate phase; sgd is the paper's "
                         "momentum-SGD, adamw the repro.optim transform)")
    ap.add_argument("--local-steps", type=int, default=1,
                    help="estimate+update iterations per gossip round "
                         "(H>1 = periodic averaging: communication drops "
                         "to 1/H per estimator pass)")
    ap.add_argument("--clip-norm", type=float, default=0.0,
                    help="per-agent global-norm gradient clip before the "
                         "optimizer update (0 disables)")
    ap.add_argument("--weight-decay", type=float, default=0.0,
                    help="decoupled weight decay for --optimizer adamw "
                         "(0 = plain Adam; ignored by sgd)")
    ap.add_argument("--param-layout", default="tree", choices=list(PARAM_LAYOUTS),
                    help="population state layout: stacked pytree (tree) or "
                         "the persistent block-aligned flat buffer per agent "
                         "(plane, core/plane.py — O(#agents) kernel "
                         "dispatches per phase)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint path — the full HDOState (params + "
                         "opt_state + step) is written at the end of the run")
    ap.add_argument("--save-every", type=int, default=0,
                    help="also checkpoint to --ckpt every N rounds (0: only "
                         "at the end)")
    ap.add_argument("--resume", default=None, metavar="PATH",
                    help="resume from a checkpoint written by --ckpt (the "
                         "HDOConfig must match; continues bit-identically)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="structured metrics sink: JSONL path (default), "
                         "*.csv, '-' (stdout), or 'tb:<logdir>' (guarded "
                         "TensorBoard).  Also enables the extended "
                         "per-agent/wire metrics and fenced per-phase "
                         "timing samples (repro.obs)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture an xprof trace (jax.profiler start/stop) "
                         "over a few steady-state rounds into DIR")
    ap.add_argument("--trace-phases", action="store_true",
                    help="on sampled rounds, additionally dispatch the round "
                         "as three separately-jitted phase calls under "
                         "profiler TraceAnnotations (observe-only; the "
                         "trajectory is untouched)")
    args = ap.parse_args()
    if args.save_every and not args.ckpt:
        ap.error("--save-every needs --ckpt (there is no path to save to)")
    if args.mesh_model > 1 and not args.mesh_agents:
        ap.error("--mesh-model needs --mesh-agents (the 2-D mesh is built "
                 "only for the sharded round)")
    if args.gossip.endswith("_ppermute") and not args.mesh_agents:
        ap.error(f"--gossip {args.gossip} is a shard_map lowering — it "
                 "needs --mesh-agents")

    hcfg = HDOConfig(
        n_agents=args.agents,
        n_zeroth=args.zo,
        estimator_zo=args.estimator,
        zo_impl=args.zo_impl,
        rv=args.rv,
        sigmas=tile(parse_csv(args.sigmas, float), args.zo),
        rvs=tile(parse_csv(args.rvs, int), args.zo),
        lrs=tile(parse_csv(args.lrs, float), args.agents),
        estimators_zo=tile(parse_csv(args.estimators_zo, str), args.zo),
        gossip=args.gossip,
        topology=args.topology,
        topology_p=args.topology_p,
        topology_seed=args.topology_seed,
        topology_rounds=args.topology_rounds,
        lr=args.lr,
        momentum=args.momentum,
        optimizer=args.optimizer,
        local_steps=args.local_steps,
        clip_norm=args.clip_norm,
        weight_decay=args.weight_decay,
        param_layout=args.param_layout,
        compression=args.compression,
        compress_k=args.compress_k,
        compress_bits=args.compress_bits,
        error_feedback=args.error_feedback,
        staleness=args.staleness,
        fault_drop_rate=args.fault_drop_rate,
        fault_straggler_rate=args.fault_straggler_rate,
        fault_byzantine_rate=args.fault_byzantine_rate,
        fault_byzantine_scale=args.fault_byzantine_scale,
        fault_seed=args.fault_seed,
        warmup_steps=min(50, args.steps // 5),
        cosine_steps=args.steps,
        seed=args.seed,
    )

    if args.arch == "brackets":
        from repro.configs.paper_tasks import brackets_transformer

        cfg = brackets_transformer()
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
        toks, labs = brackets.make_dataset(n_samples=4096, seq_len=args.seq, seed=args.seed)
        batcher = AgentBatcher({"tokens": toks, "labels": labs}, args.zo,
                               args.agents - args.zo, args.batch, seed=args.seed)
        next_batches = batcher.next_batches
    else:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
        sample = synthetic.lm_token_stream(cfg.vocab_size, seed=args.seed)
        rng = np.random.default_rng(args.seed)

        def next_batches():
            toks = sample(rng, args.agents * args.batch, args.seq + 1)
            toks = toks.reshape(args.agents, args.batch, args.seq + 1)
            out = {"tokens": toks[..., :-1], "labels": toks[..., 1:].copy()}
            if cfg.family == "vlm":
                out["patches"] = rng.normal(size=(args.agents, args.batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
            if cfg.family == "audio":
                out["frames"] = rng.normal(size=(args.agents, args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
            return out

    # one ROUND of data: local_steps=H pulls H fresh per-substep batch
    # draws and stacks them under a leading H axis (the lax.scan xs
    # contract of build_hdo_step); H=1 keeps the raw (n, b, ...) draw
    if args.local_steps > 1:
        draw_batches, H = next_batches, args.local_steps

        def round_batches():
            draws = [draw_batches() for _ in range(H)]
            return jax.tree.map(lambda *xs: np.stack(xs), *draws)
    else:
        round_batches = next_batches

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    gossip_desc = args.gossip + (
        f"/{args.topology}" if args.gossip in ("graph", "graph_ppermute") else ""
    )
    est_desc = (
        ",".join(dict.fromkeys(hcfg.estimators_zo))
        if hcfg.estimators_zo else args.estimator
    )
    # resolved homogeneity, not flag presence: a broadcast single value
    # collapses onto the homogeneous path (no grad_var_* metrics)
    from repro.core import resolve_population

    het = not resolve_population(hcfg).homogeneous
    print(f"# arch={cfg.name} params={n_params/1e6:.2f}M agents={args.agents} "
          f"(zo={args.zo}{', heterogeneous' if het else ''}) "
          f"estimator={est_desc}/{args.zo_impl} "
          f"optimizer={args.optimizer}/H={args.local_steps} gossip={gossip_desc}")

    # the extended per-agent/wire metrics ride only structured-sink runs
    # (observe-only: the returned state is bit-identical either way)
    extended = bool(args.metrics_out)
    mesh = None
    n_shards = 1
    if args.mesh_agents:
        from repro.launch.mesh import make_hdo_mesh

        mesh = make_hdo_mesh(args.agents, args.mesh_model,
                             agent_shards=args.mesh_agents)
        n_shards = args.mesh_agents * args.mesh_model
        print(f"# mesh: {args.mesh_agents} agent shards x "
              f"{args.mesh_model} model shards over "
              f"{n_shards} devices (sharded round)")
    step_fn = jax.jit(build_hdo_step(model.loss, hcfg, param_dim=n_params,
                                     params_template=params,
                                     extended_metrics=extended,
                                     shard=mesh is not None, mesh=mesh,
                                     population_axes=("agents",),
                                     model_axes=("model",)))
    # the manifest hash fingerprints the model's leaf set/shapes/dtypes
    # for BOTH layouts, so --resume across a model change fails loudly
    man_hash = planelib.manifest_hash(planelib.build_manifest(params))
    ckpt_meta = {"arch": cfg.name, "hdo": dataclasses.asdict(hcfg),
                 "param_layout": hcfg.param_layout, "manifest_hash": man_hash}
    state = init_state(params, hcfg)
    start = 0
    if args.resume:
        # sidecar-only guard BEFORE any array load: layout or
        # model-shape drift gets a clear message instead of a deep
        # structure/shape mismatch inside restore
        try:
            checkpoint.check_meta_compat(
                checkpoint.read_meta(args.resume),
                param_layout=hcfg.param_layout, manifest_hash=man_hash,
            )
        except ValueError as e:
            raise SystemExit(f"--resume: {e}")
        state, meta = checkpoint.restore_state(args.resume, state)
        saved_hdo = meta.get("hdo")
        if saved_hdo is not None:
            # msgpack round-trips tuples as lists — compare via json
            norm = lambda d: json.loads(json.dumps(d, sort_keys=True))
            cur = norm(dataclasses.asdict(hcfg))
            old = norm(saved_hdo)
            drift = sorted(k for k in cur.keys() | old.keys()
                           if cur.get(k) != old.get(k))
            if drift:
                raise SystemExit(
                    f"--resume config mismatch on {drift}: the checkpoint "
                    f"was written under a different HDOConfig (key stream / "
                    f"schedule / opt state would silently diverge)"
                )
        start = int(state.step)
        # fast-forward the (stateful) batch stream past the rounds the
        # checkpointed run already consumed, so the resumed run sees the
        # same batches an uninterrupted run would at each round (H>1:
        # each round_batches() call consumes H per-substep draws)
        for _ in range(start):
            round_batches()
        print(f"# resumed from {args.resume} at round {start}")

    # -- observability plumbing ----------------------------------------
    # every log line flows through the schema-checked logger (stdout
    # keeps the pre-existing one-JSON-line-per-log format)
    logger = MetricsLogger(
        [StdoutSink()] + ([make_sink(args.metrics_out)]
                          if args.metrics_out else []))
    logger.start_run(run_manifest(
        hcfg, manifest_hash=man_hash, arch=cfg.name, n_params=n_params,
        steps=args.steps))
    prof = ProfileSchedule(args.profile_dir)
    # fenced per-phase sampling: a handful of deterministic steady-state
    # rounds, measured on the pre-round state with outputs discarded
    phase_fns = timer = None
    sample_set = frozenset()
    if extended or args.trace_phases:
        if hcfg.local_steps == 1:
            sample_set = frozenset(obstiming.default_sample_rounds(args.steps))
            phase_fns = obstiming.build_phase_fns(
                model.loss, hcfg, param_dim=n_params, params_template=params,
                shard=mesh is not None, mesh=mesh,
                population_axes=("agents",) if mesh is not None else (),
                model_axes=("model",) if mesh is not None else ())
            if extended:
                timer = obstiming.PhaseTimer(
                    phase_fns,
                    obstiming.analytic_phase_bytes(hcfg, n_params,
                                                   n_shards=n_shards))
        else:
            print("# per-phase timing/tracing skipped: local_steps > 1 has "
                  "no three-call phase decomposition")

    compile_s = None
    wall_start = None
    instr_s = 0.0  # time spent inside observe-only instrumentation,
    # subtracted from wall_s so sampling never pollutes the wall clock
    try:
        for t in range(start, args.steps):
            b = round_batches()
            prof.maybe_start(t)
            if t in sample_set and wall_start is not None:
                t_i = time.perf_counter()
                if timer is not None:
                    logger.log_timing(t, timer.measure(state, b,
                                                       fused_fn=step_fn))
                if args.trace_phases and phase_fns is not None:
                    # annotated three-phase dispatch of the SAME round,
                    # outputs discarded — shows up on the host timeline
                    obstiming.phase_round(phase_fns, state, b, annotate=True)
                instr_s += time.perf_counter() - t_i
            if wall_start is None:
                # first dispatch = trace + compile + run: report it once
                # as compile_s; wall_s counts steady-state rounds only
                t_c = time.perf_counter()
                state, metrics = step_fn(state, b)
                jax.block_until_ready(state.params)
                compile_s = time.perf_counter() - t_c
                wall_start = time.perf_counter()
            else:
                state, metrics = step_fn(state, b)
            prof.maybe_stop(t)
            if t % args.log_every == 0 or t == args.steps - 1:
                gamma = consensus_distance(state.params)
                rec = dict(metrics)
                rec["gamma"] = float(gamma)
                rec["wall_s"] = time.perf_counter() - wall_start - instr_s
                if compile_s is not None:
                    rec["compile_s"] = compile_s
                    compile_s = None
                logger.log_round(t, rec)
            if args.ckpt and args.save_every and (t + 1) % args.save_every == 0:
                checkpoint.save_state(args.ckpt, state, meta=ckpt_meta)
    finally:
        prof.stop()

    if args.ckpt:
        checkpoint.save_state(args.ckpt, state, meta=ckpt_meta)
        print(f"# checkpoint written to {args.ckpt}.npz "
              f"(full HDOState at round {int(state.step)})")
    logger.finish({
        "rounds": int(state.step),
        "wall_s": round(time.perf_counter() - wall_start - instr_s, 3)
        if wall_start is not None else 0.0,
    })


if __name__ == "__main__":
    main()
