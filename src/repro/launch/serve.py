"""Batched serving driver: prefill (teacher-forced) + greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
      --smoke --batch 8 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import synthetic
from repro.models import build_model


def generate(model, params, prompts: jnp.ndarray, max_seq: int, gen: int):
    """prompts: (B, P). Returns (B, P+gen) tokens (greedy)."""
    B, Plen = prompts.shape
    cache = model.init_cache(B, max_seq)
    step = jax.jit(model.serve_step)
    tok = prompts[:, 0]
    out = [tok]
    for t in range(Plen + gen - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = prompts[:, t + 1] if t + 1 < Plen else nxt
        out.append(tok)
    return jnp.stack(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=args.dtype)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve driver supports text decoders; use dryrun for vlm/audio decode shapes")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    sample = synthetic.lm_token_stream(cfg.vocab_size, seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)
    prompts = jnp.asarray(sample(rng, args.batch, args.prompt_len))

    max_seq = args.prompt_len + args.gen
    t0 = time.time()
    toks = generate(model, params, prompts, max_seq, args.gen)
    dt = time.time() - t0
    total_new = args.batch * args.gen
    print(f"# arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"# wall={dt:.2f}s  ({total_new/dt:.1f} tok/s batched greedy decode)")
    for i in range(min(2, args.batch)):
        print(f"seq[{i}]:", np.asarray(toks[i]).tolist())


if __name__ == "__main__":
    main()
