"""Batched serving driver: prefill (teacher-forced) + greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
      --smoke --batch 8 --prompt-len 32 --gen 32

``--metrics-out run.jsonl`` additionally writes a run manifest plus one
``serve_request`` record per sequence (prompt/generated token counts,
end-to-end latency, per-request decode throughput) through the
structured metrics pipeline (repro.obs).  Compile time (the first
dispatch of the jitted serve step) is split out of the reported wall
clock so steady-state tok/s is not polluted by tracing.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import synthetic
from repro.models import build_model


def generate(model, params, prompts: jnp.ndarray, max_seq: int, gen: int):
    """prompts: (B, P). Returns ((B, P+gen) greedy tokens, timing dict).

    timing: ``compile_s`` (first fenced dispatch of the jitted step) and
    ``decode_s`` (fenced wall clock of the remaining steps)."""
    B, Plen = prompts.shape
    cache = model.init_cache(B, max_seq)
    step = jax.jit(model.serve_step)
    tok = prompts[:, 0]
    out = [tok]
    t0 = time.perf_counter()
    logits, cache = step(params, cache, tok, jnp.int32(0))
    jax.block_until_ready(logits)
    compile_s = time.perf_counter() - t0
    t1 = time.perf_counter()
    for t in range(Plen + gen - 1):
        if t > 0:
            logits, cache = step(params, cache, tok, jnp.int32(t))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = prompts[:, t + 1] if t + 1 < Plen else nxt
        out.append(tok)
    toks = jnp.stack(out, axis=1)
    jax.block_until_ready(toks)
    decode_s = time.perf_counter() - t1
    return toks, {"compile_s": compile_s, "decode_s": decode_s}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a run manifest + per-request serve_request "
                         "records (latency, token counts, tok/s) to this "
                         "metrics sink (repro.obs)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=args.dtype)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve driver supports text decoders; use dryrun for vlm/audio decode shapes")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    sample = synthetic.lm_token_stream(cfg.vocab_size, seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)
    prompts = jnp.asarray(sample(rng, args.batch, args.prompt_len))

    max_seq = args.prompt_len + args.gen
    toks, timing = generate(model, params, prompts, max_seq, args.gen)
    dt = timing["compile_s"] + timing["decode_s"]
    total_new = args.batch * args.gen
    print(f"# arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"# wall={dt:.2f}s compile={timing['compile_s']:.2f}s "
          f"({total_new/timing['decode_s']:.1f} tok/s batched greedy decode, "
          f"steady-state)")
    for i in range(min(2, args.batch)):
        print(f"seq[{i}]:", np.asarray(toks[i]).tolist())

    if args.metrics_out:
        from repro.obs import MetricsLogger, make_sink, run_manifest

        logger = MetricsLogger([make_sink(args.metrics_out)])
        logger.start_run(run_manifest(
            {"arch": cfg.name, "batch": args.batch,
             "prompt_len": args.prompt_len, "gen": args.gen,
             "dtype": args.dtype, "seed": args.seed},
            arch=cfg.name, compile_s=round(timing["compile_s"], 6)))
        # batched greedy decode: every sequence shares the batch's wall
        # clock, so per-request latency is the honest end-to-end figure
        # and tokens_per_s is the per-sequence share of decode throughput
        latency_ms = timing["decode_s"] * 1e3
        for i in range(args.batch):
            logger.log_request({
                "request_id": i,
                "prompt_tokens": args.prompt_len,
                "gen_tokens": args.gen,
                "latency_ms": latency_ms,
                "tokens_per_s": args.gen / timing["decode_s"],
            })
        logger.finish({"batch_tokens_per_s": round(
            total_new / timing["decode_s"], 6)})


if __name__ == "__main__":
    main()
