"""Serving driver over the continuous-batching engine (repro.serve).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
      --smoke --requests 8 --n-slots 4 --prompt-len 32 --gen 32

The default path builds the jitted scan-decode :class:`repro.serve.Engine`
(one ``lax.scan`` program per chunk — no host round-trip per token),
admits ``--requests`` generation requests through the continuous-batching
:class:`~repro.serve.Scheduler` (``--offered-rps`` spaces arrivals for an
offered-load run; 0 = all at once), and serves either the population-mean
snapshot or per-agent ensemble-routed requests (``--population``,
``--ckpt`` to serve a trained cohort).  ``--engine loop`` keeps the old
per-token Python loop as the measured baseline.

``--metrics-out run.jsonl`` writes a run manifest, per-chunk engine
metrics (queue depth, slot occupancy, prefill-vs-decode token split) and
one ``serve_request`` record per request with honest queue / prefill /
decode timing through the structured metrics pipeline (repro.obs).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import synthetic
from repro.models import build_model


def generate(model, params, prompts: jnp.ndarray, max_seq: int, gen: int):
    """The per-token-loop baseline: prompts (B, P) -> ((B, P+gen) greedy
    tokens, timing dict).  One jitted ``serve_step`` dispatch per token.

    timing splits the wall clock honestly: ``compile_s`` (first fenced
    dispatch), ``prefill_s`` (the remaining teacher-forced prompt steps,
    through the one producing the first new token), and ``decode_s``
    (the ``gen - 1`` decode steps ONLY — the old code lumped prefill
    into ``decode_s``, overstating per-token decode cost and
    undercounting tok/s)."""
    B, Plen = prompts.shape
    cache = model.init_cache(B, max_seq)
    step = jax.jit(model.serve_step)
    tok = prompts[:, 0]
    out = [tok]
    t0 = time.perf_counter()
    logits, cache = step(params, cache, tok, jnp.int32(0))
    jax.block_until_ready(logits)
    compile_s = time.perf_counter() - t0
    t1 = time.perf_counter()
    prefill_s = 0.0
    t_dec = t1
    for t in range(Plen + gen - 1):
        if t > 0:
            logits, cache = step(params, cache, tok, jnp.int32(t))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = prompts[:, t + 1] if t + 1 < Plen else nxt
        out.append(tok)
        if t == Plen - 1:
            # fence: steps 0..P-1 consumed the prompt (and produced the
            # first new token); everything after is pure decode
            jax.block_until_ready(tok)
            prefill_s = time.perf_counter() - t1
            t_dec = time.perf_counter()
    toks = jnp.stack(out, axis=1)
    jax.block_until_ready(toks)
    decode_s = time.perf_counter() - t_dec
    return toks, {"compile_s": compile_s, "prefill_s": prefill_s,
                  "decode_s": decode_s}


def _build_requests(args, cfg):
    from repro.serve import Request

    sample = synthetic.lm_token_stream(cfg.vocab_size, seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)
    prompts = sample(rng, args.requests, args.prompt_len)
    reqs = []
    for i in range(args.requests):
        reqs.append(Request(
            request_id=i, prompt=prompts[i], max_gen=args.gen,
            agent=(i % args.agents) if args.population == "ensemble" else 0,
            arrival_s=(i / args.offered_rps) if args.offered_rps > 0 else None,
        ))
    return prompts, reqs


def _resolve_params(args, cfg, model):
    """(servable params, stacked?, n_agents) for --population/--ckpt."""
    from repro.serve import load_population, population_params

    if args.ckpt:
        state, hcfg = load_population(args.ckpt, model)
        template = (model.init(jax.random.PRNGKey(args.seed))
                    if hcfg.param_layout == "plane" else None)
        params = population_params(
            state.params, mode=args.population,
            param_layout=hcfg.param_layout, template=template)
        return params, args.population == "ensemble", hcfg.n_agents
    if args.population == "ensemble":
        # no trained cohort on disk: an ensemble of independent inits
        # (each slot routed to a distinct member) still exercises the
        # routing path end to end
        keys = jax.random.split(jax.random.PRNGKey(args.seed), args.agents)
        stacked = jax.vmap(model.init)(keys)
        return stacked, True, args.agents
    return model.init(jax.random.PRNGKey(args.seed)), False, 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", "--batch", type=int, default=8,
                    dest="requests", metavar="N",
                    help="number of generation requests (--batch kept as "
                         "an alias for the pre-engine CLI)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--n-slots", type=int, default=4,
                    help="decode-slot pool size (continuous batching)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="scan steps per jitted dispatch (1 = token-"
                         "granular scheduling)")
    ap.add_argument("--cache-seq", type=int, default=0,
                    help="per-slot cache capacity (0: prompt+gen)")
    ap.add_argument("--population", choices=("mean", "ensemble"),
                    default="mean",
                    help="serve the gossip-mean snapshot, or route each "
                         "request to a cohort member (ensemble)")
    ap.add_argument("--ckpt", default=None, metavar="PATH",
                    help="serve a trained population from a train.py "
                         "checkpoint (restored through the read_meta "
                         "guards)")
    ap.add_argument("--agents", type=int, default=4,
                    help="ensemble size when no --ckpt is given")
    ap.add_argument("--offered-rps", type=float, default=0.0,
                    help="request arrival rate (0: all arrive at once)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="generated token id that terminates a request")
    ap.add_argument("--engine", choices=("scan", "loop"), default="scan",
                    help="scan: the jitted continuous-batching engine; "
                         "loop: the per-token Python-loop baseline")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a run manifest + per-chunk engine metrics "
                         "+ per-request serve_request records to this "
                         "metrics sink (repro.obs)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=args.dtype)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve driver supports text decoders; use dryrun for vlm/audio decode shapes")
    model = build_model(cfg)
    prompts, reqs = _build_requests(args, cfg)

    from repro.obs import MetricsLogger, make_sink, run_manifest

    logger = MetricsLogger([make_sink(args.metrics_out)]
                           if args.metrics_out else [])
    logger.start_run(run_manifest(
        {"arch": cfg.name, "requests": args.requests,
         "prompt_len": args.prompt_len, "gen": args.gen,
         "n_slots": args.n_slots, "chunk": args.chunk,
         "population": args.population, "engine": args.engine,
         "offered_rps": args.offered_rps, "dtype": args.dtype,
         "seed": args.seed},
        arch=cfg.name, engine=args.engine, population=args.population))

    if args.engine == "loop":
        _run_loop(args, cfg, model, prompts, logger)
        return

    from repro.serve import Engine, EngineConfig, Scheduler, percentile

    params, stacked, n_agents = _resolve_params(args, cfg, model)
    total = args.prompt_len + args.gen
    ecfg = EngineConfig(
        n_slots=args.n_slots,
        cache_seq=args.cache_seq or total,
        max_total=total,
        chunk=args.chunk,
        eos_id=args.eos_id,
    )
    t0 = time.perf_counter()
    engine = Engine(model, params, config=ecfg, ensemble=stacked)
    sched = Scheduler(engine, logger=logger)
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    wall = time.perf_counter() - t0

    gen_total = sum(r.gen_tokens for r in results)
    lat = [r.latency_ms for r in results]
    dec_tps = [r.tokens_per_s for r in results if r.tokens_per_s > 0]
    print(f"# arch={cfg.name} engine=scan population={args.population}"
          f"{f'/{n_agents} agents' if stacked else ''} "
          f"slots={args.n_slots} chunk={args.chunk} "
          f"requests={args.requests} prompt={args.prompt_len} gen={args.gen}")
    print(f"# wall={wall:.2f}s {gen_total} new tokens "
          f"({gen_total / wall:.1f} tok/s offered-load wall clock; "
          f"per-request decode median "
          f"{percentile(dec_tps, 50):.1f} tok/s)")
    print(f"# latency p50={percentile(lat, 50):.0f}ms "
          f"p99={percentile(lat, 99):.0f}ms "
          f"queue p99={percentile([r.queue_ms for r in results], 99):.0f}ms")
    for r in results[: min(2, len(results))]:
        print(f"seq[{r.request_id}]"
              + (f" agent={r.agent}" if stacked else "")
              + ":", r.tokens.tolist())
    logger.finish({
        "completed": len(results),
        "wall_s": round(wall, 6),
        "batch_tokens_per_s": round(gen_total / wall, 6),
        "p50_latency_ms": round(percentile(lat, 50), 3),
        "p99_latency_ms": round(percentile(lat, 99), 3),
    })


def _run_loop(args, cfg, model, prompts, logger) -> None:
    """The pre-engine static-batch baseline (per-token dispatches)."""
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = args.prompt_len + args.gen
    toks, timing = generate(model, params, jnp.asarray(prompts), max_seq,
                            args.gen)
    total_new = args.requests * args.gen
    serve_s = timing["prefill_s"] + timing["decode_s"]
    dec_steps = max(args.gen - 1, 0)
    print(f"# arch={cfg.name} engine=loop batch={args.requests} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"# compile={timing['compile_s']:.2f}s "
          f"prefill={timing['prefill_s']:.2f}s decode={timing['decode_s']:.2f}s "
          f"({total_new / serve_s:.1f} tok/s batched greedy decode, "
          f"steady-state)")
    for i in range(min(2, args.requests)):
        print(f"seq[{i}]:", np.asarray(toks[i]).tolist())
    # every sequence shares the batch's wall clock; prefill/decode are
    # split per the timing-honesty fix (decode_ms excludes prompt steps)
    for i in range(args.requests):
        logger.log_request({
            "request_id": i,
            "agent_id": -1,
            "prompt_tokens": args.prompt_len,
            "gen_tokens": args.gen,
            "queue_ms": 0.0,
            "prefill_ms": timing["prefill_s"] * 1e3,
            "decode_ms": timing["decode_s"] * 1e3,
            "latency_ms": serve_s * 1e3,
            "tokens_per_s": (dec_steps / timing["decode_s"]
                             if dec_steps and timing["decode_s"] > 0 else 0.0),
        })
    logger.finish({"completed": args.requests,
                   "batch_tokens_per_s": round(total_new / serve_s, 6)})


if __name__ == "__main__":
    main()
