import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
combination on the production mesh and report roofline terms.

MUST be run as its own process (device count locks at first jax init):

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k [--multi-pod] [--gossip dense] [--rv 2] [--json]

Exit code 0 and a one-line JSON report on success; a skipped
(arch, shape) combination (see DESIGN.md §4) reports {"skipped": ...}.
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import sharding as shardlib
from repro.configs import INPUT_SHAPES, get_config, get_mesh_config
from repro.configs.base import (
    COMPRESSIONS,
    DISPATCH_MODES,
    GOSSIP_MODES,
    MOMENTUM_DTYPES,
    OPTIMIZERS,
    PARAM_LAYOUTS,
    TOPOLOGIES,
    HDOConfig,
)
from repro.core import hdo as hdolib
from repro.core import localupdate
from repro.core import plane as planelib
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models import transformer as tflib

P = jax.sharding.PartitionSpec


def _prefill_step_fn(cfg):
    def prefill_step(params, batch):
        hidden, _ = tflib.forward_hidden(params, cfg, batch)
        head = tflib._head_weight(params, cfg)
        logits = (hidden[:, -1, :] @ head).astype(jnp.float32)
        from repro.models.layers import softcap

        return softcap(logits, cfg.final_logit_softcap)

    return prefill_step


def build_dryrun(arch: str, shape_name: str, *, multi_pod: bool, gossip: str,
                 rv: int, dispatch: str = "select", momentum_dtype: str = "float32",
                 attn_remat: bool = False, window_slice: bool = False,
                 moe_constraint: bool = False, donate: bool = False,
                 fsdp: bool = False, topology: str = "ring",
                 optimizer: str = "sgd", local_steps: int = 1,
                 clip_norm: float = 0.0, param_layout: str = "tree",
                 sigmas=None, rvs=None, lrs=None, estimators_zo=None,
                 compression: str = "none", compress_k: int = 0,
                 compress_bits: int = 4, error_feedback: bool = True,
                 staleness: int = 0):
    """Returns (lowered, mesh, meta) for one combination, or None if skipped."""
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    mcfg = get_mesh_config(arch)
    if fsdp:
        mcfg = dataclasses.replace(mcfg, fsdp_axes=("data",))
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind

    if kind == "decode" and shape_name == "long_500k":
        if arch not in specs.LONG_OK:
            return None
        cfg = specs.long_ctx_variant(cfg)
    if attn_remat:
        cfg = dataclasses.replace(cfg, attn_remat=True)
    if window_slice:
        cfg = dataclasses.replace(cfg, decode_window_slice=True)
    from repro.models import moe as moe_lib

    moe_lib.set_expert_buffer_sharding(None)
    moe_lib.set_ep_context(None)
    if moe_constraint and cfg.num_experts and mcfg.expert_axes:
        if moe_constraint == "ep":
            moe_lib.set_ep_context(mesh, mcfg.expert_axes[0])
        else:
            e_ax = mcfg.expert_axes if len(mcfg.expert_axes) > 1 else mcfg.expert_axes[0]
            b_ax = mcfg.batch_axes if len(mcfg.batch_axes) > 1 else (
                mcfg.batch_axes[0] if mcfg.batch_axes else None)
            moe_lib.set_expert_buffer_sharding(
                jax.NamedSharding(mesh, P(e_ax, None, None)),
                token_sharding=jax.NamedSharding(mesh, P(b_ax, None, None)),
            )

    if kind == "train":
        from repro.core.population import tile

        n_agents = specs.population_size(mcfg, mesh)
        n_zeroth = n_agents // 2
        hcfg = HDOConfig(
            n_agents=n_agents,
            n_zeroth=n_zeroth,
            estimator_zo="multi_rv",
            rv=rv,
            # per-agent CSVs are cycled to the mesh-derived cohort sizes
            # (the caller cannot know n_agents before the mesh is built)
            sigmas=tile(sigmas, n_zeroth),
            rvs=tile(rvs, n_zeroth),
            lrs=tile(lrs, n_agents),
            estimators_zo=tile(estimators_zo, n_zeroth),
            gossip=gossip if n_agents > 1 else "none",
            topology=topology,
            momentum=0.9,
            optimizer=optimizer,
            local_steps=local_steps,
            clip_norm=clip_norm,
            dispatch=dispatch,
            momentum_dtype=momentum_dtype,
            param_layout=param_layout,
            compression=compression if n_agents > 1 else "none",
            compress_k=compress_k,
            compress_bits=compress_bits,
            error_feedback=error_feedback,
            staleness=staleness if n_agents > 1 else 0,
        )
        model = build_model(cfg)
        loss_fn = model.loss
        # the plane layout derives its static leaf manifest from the
        # params template — eval_shape structs carry all it needs
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        step = hdolib.build_hdo_step(
            loss_fn, hcfg, param_dim=cfg.param_count(),
            mesh=mesh, population_axes=mcfg.population_axes,
            params_template=params_sds,
        )

        state_sds = jax.eval_shape(lambda p: hdolib.init_state(p, hcfg), params_sds)
        batch_sds = specs.train_batch_specs(cfg, shape, n_agents)
        batch_psp = shardlib.batch_pspecs(batch_sds, mcfg, mesh, population=True)
        if hcfg.local_steps > 1:
            # local_steps=H consumes a leading per-substep axis on every
            # batches leaf (the lax.scan xs contract); the H axis is
            # unsharded, the per-substep layout shifts right unchanged
            batch_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (hcfg.local_steps,) + s.shape, s.dtype),
                batch_sds)
            batch_psp = jax.tree.map(
                lambda s: P(None, *s), batch_psp,
                is_leaf=lambda x: isinstance(x, P))

        if hcfg.param_layout == "plane":
            # the plane is one bare (n_agents, dim) buffer — the
            # leaf-NAME-based pspec machinery cannot apply; the plane
            # rule shards the agent axis over the population axes and
            # FSDP-shards the dim axis over the model axes when every
            # model shard gets whole BLOCKs (replicated otherwise)
            manifest = planelib.build_manifest(params_sds)
            pspec_params = shardlib.plane_pspec(
                n_agents, manifest.dim, mcfg, mesh)
        else:
            pspec_params = shardlib.params_pspecs(
                state_sds.params, mcfg, mesh, population=True)
        # the opt state shards exactly like the params it tracks
        # (momentum tree for sgd, mu/nu/count for adamw)
        from repro.topology import compress as compresslib

        state_psp = hdolib.HDOState(
            params=pspec_params,
            opt_state=localupdate.opt_state_pspecs(hcfg, pspec_params),
            step=P(),
            # comm streams (EF residuals / bcast buffers) mirror the
            # params layout, so they shard exactly like the params
            comm=compresslib.comm_pspecs(hcfg, pspec_params),
        )

        jitted = jax.jit(
            step,
            in_shardings=(
                jax.tree.map(lambda s: jax.NamedSharding(mesh, s), state_psp,
                             is_leaf=lambda x: isinstance(x, P)),
                jax.tree.map(lambda s: jax.NamedSharding(mesh, s), batch_psp,
                             is_leaf=lambda x: isinstance(x, P)),
            ),
            donate_argnums=(0,) if donate else (),
        )
        lowered = jitted.lower(state_sds, batch_sds)
        meta = {"n_agents": n_agents, "hdo": dataclasses.asdict(hcfg)}
        return lowered, mesh, meta

    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspec_params = shardlib.params_pspecs(params_sds, mcfg, mesh, population=False)
    param_sh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), pspec_params,
                            is_leaf=lambda x: isinstance(x, P))

    if kind == "prefill":
        batch_sds = specs.prefill_batch_specs(cfg, shape)
        batch_psp = shardlib.batch_pspecs(batch_sds, mcfg, mesh, population=False)
        batch_sh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), batch_psp,
                                is_leaf=lambda x: isinstance(x, P))
        fn = _prefill_step_fn(cfg)
        lowered = jax.jit(fn, in_shardings=(param_sh, batch_sh)).lower(params_sds, batch_sds)
        return lowered, mesh, {}

    # decode
    cache_sds, tok_sds, pos_sds = specs.decode_specs(cfg, shape)
    cache_psp = shardlib.cache_pspecs(cache_sds, mcfg, mesh)
    cache_sh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), cache_psp,
                            is_leaf=lambda x: isinstance(x, P))
    B = shape.global_batch
    from repro.sharding import _maybe

    tok_axes = _maybe(("pod", "data"), B, mesh) if B > 1 else None
    tok_sh = jax.NamedSharding(mesh, P(tok_axes) if tok_axes else P())
    pos_sh = jax.NamedSharding(mesh, P())

    def step(params, cache, tokens, pos):
        return model.serve_step(params, cache, tokens, pos)

    lowered = jax.jit(
        step, in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
        donate_argnums=(1,) if donate else (),
    ).lower(params_sds, cache_sds, tok_sds, pos_sds)
    return lowered, mesh, {}


def run_one(arch: str, shape_name: str, *, multi_pod: bool, gossip: str, rv: int,
            dispatch: str = "select", momentum_dtype: str = "float32",
            attn_remat: bool = False, window_slice: bool = False,
            moe_constraint: bool = False, donate: bool = False,
            fsdp: bool = False, label: str = "",
            topology: str = "ring",
            optimizer: str = "sgd", local_steps: int = 1,
            clip_norm: float = 0.0, param_layout: str = "tree",
            sigmas=None, rvs=None, lrs=None, estimators_zo=None,
            compression: str = "none", compress_k: int = 0,
            compress_bits: int = 4, error_feedback: bool = True,
            staleness: int = 0) -> Dict[str, Any]:
    t0 = time.time()
    built = build_dryrun(arch, shape_name, multi_pod=multi_pod, gossip=gossip,
                         rv=rv, dispatch=dispatch, momentum_dtype=momentum_dtype,
                         attn_remat=attn_remat, window_slice=window_slice,
                         moe_constraint=moe_constraint, donate=donate, fsdp=fsdp,
                         topology=topology, optimizer=optimizer,
                         local_steps=local_steps, clip_norm=clip_norm,
                         param_layout=param_layout,
                         sigmas=sigmas, rvs=rvs, lrs=lrs,
                         estimators_zo=estimators_zo,
                         compression=compression, compress_k=compress_k,
                         compress_bits=compress_bits,
                         error_feedback=error_feedback, staleness=staleness)
    if built is None:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": "long_500k requires sub-quadratic attention (DESIGN.md §4)"}
    lowered, mesh, meta = built
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    chips = mesh.devices.size
    roof = hlo_analysis.analyze(compiled, chips)
    mem = hlo_analysis.memory_analysis_dict(compiled)

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens

    total_flops = roof.flops * chips
    report = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "chips": chips,
        "gossip": gossip,
        "label": label or "baseline",
        "variant": {
            "dispatch": dispatch, "momentum_dtype": momentum_dtype,
            "optimizer": optimizer, "local_steps": local_steps,
            "param_layout": param_layout,
            "compression": compression, "staleness": staleness,
            "attn_remat": attn_remat, "window_slice": window_slice,
            "moe_constraint": moe_constraint, "donate": donate, "fsdp": fsdp,
        },
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "model_flops": model_flops,
        "useful_ratio": model_flops / total_flops if total_flops else None,
        **roof.as_dict(),
        "memory": mem,
        **meta,
    }
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--gossip", default="dense", choices=list(GOSSIP_MODES))
    ap.add_argument("--topology", default="ring", choices=list(TOPOLOGIES),
                    help="neighbor graph for --gossip graph/graph_ppermute")
    ap.add_argument("--rv", type=int, default=2)
    # heterogeneous-population CSVs (cycled to the mesh-derived cohort
    # sizes — see launch/train.py for semantics)
    ap.add_argument("--sigmas", default=None, metavar="CSV")
    ap.add_argument("--rvs", default=None, metavar="CSV")
    ap.add_argument("--lrs", default=None, metavar="CSV")
    ap.add_argument("--estimators-zo", default=None, metavar="CSV")
    ap.add_argument("--dispatch", default="select", choices=list(DISPATCH_MODES))
    ap.add_argument("--momentum-dtype", default="float32",
                    choices=list(MOMENTUM_DTYPES))
    ap.add_argument("--optimizer", default="sgd", choices=list(OPTIMIZERS),
                    help="LocalUpdate rule for the train-shape step")
    ap.add_argument("--local-steps", type=int, default=1,
                    help="estimate+update iterations per gossip round")
    ap.add_argument("--clip-norm", type=float, default=0.0,
                    help="per-agent gradient clip (0 disables)")
    ap.add_argument("--param-layout", default="tree",
                    choices=list(PARAM_LAYOUTS),
                    help="stacked pytree vs contiguous per-agent plane "
                         "(core/plane.py)")
    ap.add_argument("--compression", default="none", choices=list(COMPRESSIONS),
                    help="gossip payload compressor (graph modes only)")
    ap.add_argument("--compress-k", type=int, default=0,
                    help="kept coordinates for --compression topk")
    ap.add_argument("--compress-bits", type=int, default=4,
                    help="quantization bits for --compression qsgd")
    ap.add_argument("--error-feedback", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="carry compression residuals across rounds")
    ap.add_argument("--staleness", type=int, default=0,
                    help="staleness bound tau for buffered gossip payloads")
    ap.add_argument("--attn-remat", action="store_true")
    ap.add_argument("--window-slice", action="store_true")
    ap.add_argument("--moe-constraint", nargs="?", const=True, default=False,
                    help="constrain MoE buffers; pass 'ep' for the shard_map all-to-all path")
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--label", default="")
    ap.add_argument("--out", default=None, help="append JSON line to this file")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a repro.obs run-manifest record (config hash "
                         "+ the HLO cost / roofline / memory summary) to "
                         "this metrics sink")
    args = ap.parse_args()

    from repro.core.population import parse_csv

    report = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                     gossip=args.gossip, rv=args.rv, dispatch=args.dispatch,
                     momentum_dtype=args.momentum_dtype, attn_remat=args.attn_remat,
                     window_slice=args.window_slice, moe_constraint=args.moe_constraint,
                     donate=args.donate, fsdp=args.fsdp, label=args.label,
                     topology=args.topology, optimizer=args.optimizer,
                     local_steps=args.local_steps, clip_norm=args.clip_norm,
                     param_layout=args.param_layout,
                     sigmas=parse_csv(args.sigmas, float),
                     rvs=parse_csv(args.rvs, int),
                     lrs=parse_csv(args.lrs, float),
                     estimators_zo=parse_csv(args.estimators_zo, str),
                     compression=args.compression,
                     compress_k=args.compress_k,
                     compress_bits=args.compress_bits,
                     error_feedback=args.error_feedback,
                     staleness=args.staleness)
    line = json.dumps(report)
    print(line)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")
    if args.metrics_out:
        from repro.obs import MetricsLogger, make_sink, run_manifest

        # the manifest identity is the HDO config when the shape builds one
        # (train shapes); otherwise hash the variant knobs so two dryruns of
        # the same combination produce the same config_hash
        ident = report.get("hdo") or {
            "arch": args.arch, "shape": args.shape,
            "variant": report.get("variant"),
        }
        summary = {k: v for k, v in report.items() if k != "hdo"}
        logger = MetricsLogger([make_sink(args.metrics_out)])
        logger.start_run(run_manifest(ident, dryrun=summary))
        logger.finish()
    sys.exit(0)


if __name__ == "__main__":
    main()
