"""Production meshes.

A function (not a module constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """1-device-friendly mesh for CPU smoke paths."""
    n = len(jax.devices())
    if model_parallel < 1 or n % model_parallel != 0:
        raise ValueError(
            f"model_parallel={model_parallel} must divide the device count "
            f"({n} devices visible)")
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def make_hdo_mesh(n_agents: int, model_parallel: int = 1, *,
                  agent_shards: int | None = None):
    """2-D ``agents x model`` mesh for the sharded HDO round.

    The population axis must evenly split the cohort, so the agent-shard
    count is the largest divisor of ``n_agents`` that fits the devices
    left after ``model_parallel`` (or exactly ``agent_shards`` when
    given).  The mesh may use a leading subset of the visible devices —
    a cohort of 6 on an 8-device host gets a (6, 1) mesh, not a crash.
    """
    devices = jax.devices()
    n_dev = len(devices)
    if model_parallel < 1 or n_dev % model_parallel != 0:
        raise ValueError(
            f"model_parallel={model_parallel} must divide the device count "
            f"({n_dev} devices visible)")
    avail = n_dev // model_parallel
    if agent_shards is None:
        agent_shards = max(a for a in range(1, min(n_agents, avail) + 1)
                           if n_agents % a == 0)
    if agent_shards < 1 or n_agents % agent_shards != 0:
        raise ValueError(
            f"agent_shards={agent_shards} must divide n_agents={n_agents}")
    if agent_shards * model_parallel > n_dev:
        raise ValueError(
            f"mesh shape ({agent_shards} agents x {model_parallel} model) "
            f"needs {agent_shards * model_parallel} devices; only {n_dev} visible")
    grid = np.asarray(devices[: agent_shards * model_parallel], dtype=object)
    return jax.sharding.Mesh(grid.reshape(agent_shards, model_parallel),
                             ("agents", "model"))
