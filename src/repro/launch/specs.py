"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair
— weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HDOConfig, InputShape, MeshConfig, ModelConfig

SDS = jax.ShapeDtypeStruct

# archs allowed to run long_500k (sub-quadratic decode; DESIGN.md §4)
LONG_OK = {"mamba2-780m", "zamba2-2.7b", "gemma2-9b"}


def long_ctx_variant(cfg: ModelConfig) -> ModelConfig:
    """Serving variant for long_500k: sliding-window everywhere."""
    if cfg.name.startswith("gemma2"):
        return dataclasses.replace(cfg, local_global_period=0)
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, sliding_window=4096)
    return cfg


def train_batch_specs(cfg: ModelConfig, shape: InputShape, n_agents: int) -> Dict[str, SDS]:
    """Per-agent-stacked training batch: leaves (n_agents, b, ...)."""
    assert shape.global_batch % n_agents == 0, (shape.global_batch, n_agents)
    b = shape.global_batch // n_agents
    S = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        s_text = S - cfg.num_patches
        return {
            "tokens": SDS((n_agents, b, s_text), jnp.int32),
            "labels": SDS((n_agents, b, s_text), jnp.int32),
            "patches": SDS((n_agents, b, cfg.num_patches, cfg.d_model), dt),
        }
    if cfg.family == "audio":
        return {
            "tokens": SDS((n_agents, b, S), jnp.int32),
            "labels": SDS((n_agents, b, S), jnp.int32),
            "frames": SDS((n_agents, b, cfg.encoder_seq, cfg.d_model), dt),
        }
    return {
        "tokens": SDS((n_agents, b, S), jnp.int32),
        "labels": SDS((n_agents, b, S), jnp.int32),
    }


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    """Single-model inference prefill batch (no population axis)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    out = {"tokens": SDS((B, S if cfg.family != "vlm" else S - cfg.num_patches), jnp.int32)}
    if cfg.family == "vlm":
        out["patches"] = SDS((B, cfg.num_patches, cfg.d_model), dt)
    if cfg.family == "audio":
        out["frames"] = SDS((B, cfg.encoder_seq, cfg.d_model), dt)
    # labels unused at inference; provide for the shared loss signature
    out["labels"] = SDS(out["tokens"].shape, jnp.int32)
    return out


def decode_specs(cfg: ModelConfig, shape: InputShape) -> Tuple[Dict[str, SDS], SDS, SDS]:
    """(cache_specs, tokens_spec, pos_spec) for serve_step."""
    from repro.models import decode as _decode

    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: _decode.init_cache(cfg, B, S))
    tokens = SDS((B,), jnp.int32)
    pos = SDS((), jnp.int32)
    return cache, tokens, pos


def population_size(mcfg: MeshConfig, mesh) -> int:
    n = 1
    for a in mcfg.population_axes:
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n
