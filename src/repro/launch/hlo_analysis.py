"""Roofline-term extraction from compiled XLA artifacts.

XLA's built-in ``HloCostAnalysis`` counts ``while`` bodies ONCE — with
scan-over-layers models that under-reports FLOPs by ~the depth of the
network.  This module therefore walks the optimized HLO *text* with a
call-graph cost model:

  * ``while``       -> trip_count x (body + cond)   (trip count parsed
                        from ``backend_config known_trip_count``)
  * ``fusion``      -> FLOPs of the fused computation; bytes only at the
                        fusion boundary (internal traffic stays on-chip)
  * ``conditional`` -> max over branches (upper bound)
  * ``dot``         -> 2 * |out| * contracted_size
  * collectives     -> output bytes (per-device link traffic estimate),
                        multiplied through enclosing loops

Terms (TPU v5e):
  compute    = FLOPs_per_device / 197e12
  memory     = bytes_per_device / 819e9      (fusion-boundary bytes: an
               HBM-traffic estimate; CPU-backend fusion is less
               aggressive than TPU's, so this leans pessimistic)
  collective = collective_bytes_per_device / 50e9

The compiled module under SPMD is the per-device program, so all sums
are per-device; multiply by ``chips`` for cluster totals.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

# ---- TPU v5e constants ----------------------------------------------------
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"
)

# ops that are pure plumbing: no flops, no memory traffic attributed
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "broadcast", "transpose", "slice", "rng-bit-generator",
    "get-dimension-size", "opt-barrier", "custom-call", "domain",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*\))?\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"(?:branch_computations|true_computation|false_computation)=\{?%?([\w\.\-,% ]+)\}?")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) of a possibly-tuple HLO type string."""
    elems = byts = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: Optional[Dict[str, float]] = None
    unknown_trip_counts: int = 0

    def __add__(self, o: "Cost") -> "Cost":
        det = dict(self.coll_detail or {})
        for k, v in (o.coll_detail or {}).items():
            det[k] = det.get(k, 0.0) + v
        return Cost(
            self.flops + o.flops,
            self.bytes + o.bytes,
            self.coll_bytes + o.coll_bytes,
            det,
            self.unknown_trip_counts + o.unknown_trip_counts,
        )

    def scaled(self, k: float) -> "Cost":
        return Cost(
            self.flops * k,
            self.bytes * k,
            self.coll_bytes * k,
            {kk: v * k for kk, v in (self.coll_detail or {}).items()},
            self.unknown_trip_counts,
        )


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[_Op]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    # ---------------- parsing ----------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            om = _OP_HEAD_RE.match(line)
            if not om:
                continue
            name = om.group(1)
            rest = line[om.end():]
            # parse the result type: balanced-paren tuple (may contain
            # /*index=N*/ comments) or a single shape token
            if rest.startswith("("):
                depth = 0
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                type_str = rest[: i + 1]
                rest = rest[i + 1 :]
            else:
                sm = re.match(r"\S+", rest)
                if not sm:
                    continue
                type_str = sm.group(0)
                rest = rest[sm.end():]
            opm = re.match(r"\s+([\w\-]+)\(", rest)
            if not opm:
                continue
            opcode = opm.group(1)
            args = rest[opm.end():]
            depth = 1
            arg_chars = []
            for ch in args:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                arg_chars.append(ch)
            operands = re.findall(r"%([\w\.\-]+)", "".join(arg_chars))
            self.computations[cur].append(_Op(name, type_str, opcode, line, operands))

    # ---------------- cost walk ----------------
    def _shape_of(self, comp: str, name: str) -> str:
        for op in self.computations.get(comp, []):
            if op.name == name:
                return op.type_str
        return ""

    def comp_cost(self, comp: str, inside_fusion: bool = False) -> Cost:
        key = (comp, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost(coll_detail={})
        for op in self.computations.get(comp, []):
            total = total + self.op_cost(comp, op, inside_fusion)
        self._memo[key] = total
        return total

    def op_cost(self, comp: str, op: _Op, inside_fusion: bool) -> Cost:
        oc = op.opcode
        out_elems, out_bytes = _shape_elems_bytes(op.type_str)

        # normalize async pairs
        base = oc[:-6] if oc.endswith("-start") else (None if oc.endswith("-done") else oc)
        if base is None:
            return Cost(coll_detail={})
        oc = base

        if oc in _COLLECTIVE_KINDS:
            det = {oc: float(out_bytes)}
            return Cost(coll_bytes=float(out_bytes), bytes=float(out_bytes), coll_detail=det)

        if oc == "while":
            body = _BODY_RE.search(op.line)
            cond = _COND_RE.search(op.line)
            trip = _TRIP_RE.search(op.line)
            n = int(trip.group(1)) if trip else 1
            c = Cost(coll_detail={}, unknown_trip_counts=0 if trip else 1)
            if body:
                c = c + self.comp_cost(body.group(1)).scaled(n)
            if cond:
                c = c + self.comp_cost(cond.group(1)).scaled(n)
            return c

        if oc == "conditional":
            branches: List[str] = []
            for m in re.finditer(r"(?:true_computation|false_computation)=%?([\w\.\-]+)", op.line):
                branches.append(m.group(1))
            bm = re.search(r"branch_computations=\{([^}]*)\}", op.line)
            if bm:
                branches += re.findall(r"%([\w\.\-]+)", bm.group(1))
            costs = [self.comp_cost(b) for b in branches]
            if not costs:
                return Cost(coll_detail={})
            best = max(costs, key=lambda c: (c.flops, c.bytes))
            return best

        if oc == "fusion":
            callee = _CALLS_RE.search(op.line)
            inner = self.comp_cost(callee.group(1), inside_fusion=True) if callee else Cost(coll_detail={})
            opnd_bytes = self._fusion_operand_bytes(comp, op, callee.group(1) if callee else None)
            return Cost(
                flops=inner.flops,
                bytes=float(out_bytes + opnd_bytes),
                coll_bytes=inner.coll_bytes,
                coll_detail=inner.coll_detail or {},
                unknown_trip_counts=inner.unknown_trip_counts,
            )

        if oc == "call":
            callee = _TOAPPLY_RE.search(op.line)
            return self.comp_cost(callee.group(1)) if callee else Cost(coll_detail={})

        if oc in ("dot", "convolution"):
            flops = 2.0 * out_elems * self._contracted_size(comp, op)
            byts = 0.0 if inside_fusion else float(out_bytes + self._operand_bytes(comp, op))
            return Cost(flops=flops, bytes=byts, coll_detail={})

        if oc in ("reduce", "reduce-window"):
            in_elems = 0
            for nm in op.operands:
                e, _ = _shape_elems_bytes(self._shape_of(comp, nm))
                in_elems += e
            byts = 0.0 if inside_fusion else float(out_bytes + self._operand_bytes(comp, op))
            return Cost(flops=float(in_elems), bytes=byts, coll_detail={})

        if oc in ("sort",):
            e = out_elems * max(1.0, math.log2(max(out_elems, 2)))
            byts = 0.0 if inside_fusion else float(out_bytes + self._operand_bytes(comp, op))
            return Cost(flops=float(e), bytes=byts, coll_detail={})

        if oc in _FREE_OPS:
            return Cost(coll_detail={})

        # sliced reads / writes touch only the slice, not the buffer
        if oc in ("dynamic-slice", "gather"):
            byts = 0.0 if inside_fusion else float(2 * out_bytes)
            return Cost(flops=0.0, bytes=byts, coll_detail={})
        if oc in ("dynamic-update-slice", "scatter"):
            upd = 0
            if len(op.operands) >= 2:
                _, upd = _shape_elems_bytes(self._shape_of(comp, op.operands[1]))
            byts = 0.0 if inside_fusion else float(2 * upd)
            return Cost(flops=0.0, bytes=byts, coll_detail={})

        if oc in ("copy", "copy-start", "concatenate", "pad", "reverse", "convert",
                  "select-and-scatter"):
            byts = 0.0 if inside_fusion else float(out_bytes + self._operand_bytes(comp, op))
            return Cost(flops=0.0, bytes=byts, coll_detail={})

        # generic elementwise arithmetic
        byts = 0.0 if inside_fusion else float(out_bytes + self._operand_bytes(comp, op))
        return Cost(flops=float(out_elems), bytes=byts, coll_detail={})

    def _operand_bytes(self, comp: str, op: _Op) -> int:
        total = 0
        for nm in op.operands:
            _, b = _shape_elems_bytes(self._shape_of(comp, nm))
            total += b
        return total

    def _fusion_operand_bytes(self, comp: str, op: _Op, callee: Optional[str]) -> int:
        """Operand bytes at a fusion boundary, with sliced reads reduced
        to the slice size: a fusion parameter consumed (only) by
        (dynamic-)slice ops reads just the slice, not the buffer — the
        dominant pattern in scan bodies indexing stacked weights."""
        if callee is None or callee not in self.computations:
            return self._operand_bytes(comp, op)
        callee_ops = self.computations[callee]
        # param index -> op name inside callee
        param_names: Dict[int, str] = {}
        for cop in callee_ops:
            if cop.opcode == "parameter":
                m = re.match(r"\s*(\d+)", cop.line.split("parameter(")[-1])
                if m:
                    param_names[int(m.group(1))] = cop.name
        total = 0
        for i, nm in enumerate(op.operands):
            _, full = _shape_elems_bytes(self._shape_of(comp, nm))
            pname = param_names.get(i)
            if pname is None:
                total += full
                continue
            consumers = [c for c in callee_ops if pname in c.operands]
            if consumers and all(c.opcode in ("dynamic-slice", "slice", "gather") for c in consumers):
                sliced = 0
                for c in consumers:
                    _, ob = _shape_elems_bytes(c.type_str)
                    sliced += ob
                total += min(sliced, full)
            else:
                total += full
        return total

    def _contracted_size(self, comp: str, op: _Op) -> int:
        m = _LHS_CONTRACT_RE.search(op.line)
        if not m or not op.operands:
            return 1
        lhs_type = self._shape_of(comp, op.operands[0])
        sm = _SHAPE_RE.search(lhs_type)
        if not sm:
            return 1
        dims = [int(d) for d in sm.group(2).split(",") if d]
        size = 1
        for di in m.group(1).split(","):
            if di:
                idx = int(di)
                if idx < len(dims):
                    size *= dims[idx]
        return size

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    bytes_accessed: float  # per device (fusion-boundary estimate)
    coll_bytes: float  # per device
    chips: int
    coll_detail: Dict[str, float]
    unknown_trip_counts: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "coll_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "coll_detail": {k: v for k, v in self.coll_detail.items() if v},
            "unknown_trip_counts": self.unknown_trip_counts,
        }


def analyze(compiled, chips: int) -> Roofline:
    model = HloCostModel(compiled.as_text())
    cost = model.entry_cost()
    return Roofline(
        flops=cost.flops,
        bytes_accessed=cost.bytes,
        coll_bytes=cost.coll_bytes,
        chips=chips,
        coll_detail=cost.coll_detail or {},
        unknown_trip_counts=cost.unknown_trip_counts,
    )


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for field in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = float(v)
    return out
