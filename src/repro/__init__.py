"""repro: Hybrid Decentralized Optimization (HDO, AAAI-25) as a
multi-pod JAX training/inference framework.  See README.md."""

__version__ = "1.0.0"
