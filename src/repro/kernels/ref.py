"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rng import counter_normal
from repro.models.mamba2 import ssd_reference


def zo_combine_ref(coeffs, seed, d: int, n_active=None):
    """g = (1/n) sum_r coeffs[r] * u_r, u_r = counter_normal(seed, ., r).

    coeffs: (rv,) f32; returns (d,) f32.  ``n_active`` overrides the
    averaging denominator (default: the static rv) — the ragged-rv
    contract of the fused kernel.
    """
    rv = coeffs.shape[0]
    idx = jnp.arange(d, dtype=jnp.uint32)

    def body(acc, r):
        u = counter_normal(jnp.uint32(seed), idx, r.astype(jnp.uint32))
        return acc + coeffs[r] * u, None

    acc, _ = jax.lax.scan(body, jnp.zeros((d,), jnp.float32), jnp.arange(rv))
    denom = jnp.float32(rv) if n_active is None else jnp.asarray(n_active, jnp.float32)
    return acc / denom


def zo_tangent_ref(seed, r: int, d: int, dtype=jnp.float32):
    """u_r = counter_normal(seed, ., r) — the fwd_grad tangent."""
    idx = jnp.arange(d, dtype=jnp.uint32)
    return counter_normal(jnp.uint32(seed), idx, jnp.uint32(r)).astype(dtype)


def zo_perturb_ref(x, seed, r: int, nu: float):
    """x + nu * u_r (flattened parameter perturbation)."""
    d = x.shape[0]
    idx = jnp.arange(d, dtype=jnp.uint32)
    u = counter_normal(jnp.uint32(seed), idx, jnp.uint32(r))
    return (x.astype(jnp.float32) + nu * u).astype(x.dtype)


def zo_perturb_batch_ref(x, seed, rv: int, nu: float):
    """(rv, d) stacked candidates x + nu * u_r."""
    d = x.shape[0]
    idx = jnp.arange(d, dtype=jnp.uint32)

    def row(r):
        u = counter_normal(jnp.uint32(seed), idx, r.astype(jnp.uint32))
        return (x.astype(jnp.float32) + nu * u).astype(x.dtype)

    return jax.vmap(row)(jnp.arange(rv))


def _plane_compact_idx(delta, nvalid, d: int, block: int):
    """(counter index, valid mask) per plane position — the plane
    kernels' compact-stream contract (see core.plane.rng_tables)."""
    idx = jnp.arange(d)
    blk = idx // block
    base = (idx - delta[blk]).astype(jnp.uint32)
    valid = (idx % block) < nvalid[blk]
    return base, valid


def zo_combine_plane_ref(coeffs, seed, delta, nvalid, d: int, block: int,
                         n_active=None):
    """Plane-layout combine oracle: compact counter stream, zeroed pads."""
    rv = coeffs.shape[0]
    base, valid = _plane_compact_idx(delta, nvalid, d, block)

    def body(acc, r):
        u = counter_normal(jnp.uint32(seed), base, r.astype(jnp.uint32))
        return acc + coeffs[r] * u, None

    acc, _ = jax.lax.scan(body, jnp.zeros((d,), jnp.float32), jnp.arange(rv))
    denom = jnp.float32(rv) if n_active is None else jnp.asarray(n_active, jnp.float32)
    return jnp.where(valid, acc / denom, 0.0)


def zo_tangent_plane_ref(seed, r: int, delta, nvalid, d: int, block: int,
                         dtype=jnp.float32):
    """Plane-layout tangent oracle: u_r at compact indices, zeroed pads."""
    base, valid = _plane_compact_idx(delta, nvalid, d, block)
    u = counter_normal(jnp.uint32(seed), base, jnp.uint32(r))
    return jnp.where(valid, u, 0.0).astype(dtype)


def zo_perturb_plane_ref(x, seed, r: int, nu: float, delta, nvalid, block: int):
    """Plane-layout perturb oracle: x + nu*u_r on the compact stream,
    pads pass x through."""
    d = x.shape[0]
    base, valid = _plane_compact_idx(delta, nvalid, d, block)
    u = counter_normal(jnp.uint32(seed), base, jnp.uint32(r))
    cand = (x.astype(jnp.float32) + nu * u).astype(x.dtype)
    return jnp.where(valid, cand, x)


def opt_apply_ref(p, g, m, lr, beta):
    """Fused momentum-SGD apply oracle (the kernel's exact association):
    the new momentum is rounded to ``m.dtype`` *before* the parameter
    update consumes it — the tree path's ``momentum_dtype`` write-back."""
    beta = jnp.asarray(beta, jnp.float32)
    lr = jnp.asarray(lr, jnp.float32)
    new_m = (beta * m.astype(jnp.float32)
             + (1.0 - beta) * g.astype(jnp.float32)).astype(m.dtype)
    new_p = (p.astype(jnp.float32)
             - lr * new_m.astype(jnp.float32)).astype(p.dtype)
    return new_p, new_m


def adamw_apply_ref(p, g, mu, nu, lr, b1, b2, eps, wd, count):
    """Fused AdamW apply oracle (the kernel's exact association): the
    first moment is rounded to ``mu.dtype`` *before* driving the update
    (the sgd kernel's write-back discipline); ``count`` is 1-based."""
    c = jnp.asarray(count, jnp.float32)
    b1 = jnp.asarray(b1, jnp.float32)
    b2 = jnp.asarray(b2, jnp.float32)
    gf = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    new_mu = (b1 * mu.astype(jnp.float32) + (1.0 - b1) * gf).astype(mu.dtype)
    new_nu32 = b2 * nu.astype(jnp.float32) + (1.0 - b2) * gf * gf
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c
    upd = (new_mu.astype(jnp.float32) / bc1
           / (jnp.sqrt(new_nu32 / bc2) + jnp.float32(eps))
           + jnp.float32(wd) * pf)
    new_p = (pf - jnp.asarray(lr, jnp.float32) * upd).astype(p.dtype)
    return new_p, new_mu, new_nu32.astype(nu.dtype)


def gossip_avg_ref(x, y):
    return ((x.astype(jnp.float32) + y.astype(jnp.float32)) * 0.5).astype(x.dtype)


def gossip_mix_ref(x, nbrs, w_self, w):
    """out = w_self * x + sum_s w[s] * nbrs[s], f32 accumulation in the
    kernel's (unrolled, in-order) association so parity is bit-exact."""
    acc = jnp.asarray(w_self, jnp.float32) * x.astype(jnp.float32)
    for s in range(nbrs.shape[0]):
        acc = acc + jnp.asarray(w[s], jnp.float32) * nbrs[s].astype(jnp.float32)
    return acc.astype(x.dtype)


def _quantize_ref(u, thr, seed, idx, *, mode: str, bits: int = 0):
    """Standalone mirror of ``compress_mix.quantize`` (the oracle keeps
    its own copy of the math so kernel drift cannot hide)."""
    from repro.kernels.rng import _uniform

    if mode == "topk":
        return jnp.where(jnp.abs(u) >= thr, u, jnp.float32(0.0))
    if mode == "qsgd":
        levels = float((1 << bits) - 1)
        scaled = jnp.abs(u) / thr * jnp.float32(levels)
        lo = jnp.floor(scaled)
        p = scaled - lo
        b = (_uniform(seed, idx, jnp.uint32(97)) < p).astype(jnp.float32)
        return jnp.sign(u) * thr * (lo + b) * jnp.float32(1.0 / levels)
    raise ValueError(f"unknown compression mode {mode!r}")


def compress_mix_ref(x, u, nbrs, w, thr, seeds, *, mode: str, bits: int = 0):
    """Compressed-gossip round oracle (the kernel's exact association):

        m_j = C(u_j); out = x + sum_s w[s] * (m_s - m_self);
        residual = u_self - m_self

    x: (d,), u: (d,) f32, nbrs: (k, d) f32, w: (k,) f32, thr: (k+1,)
    f32, seeds: (k+1,) uint32 -> (out (d,) x.dtype, residual (d,) f32).
    """
    d = x.shape[0]
    idx = jnp.arange(d, dtype=jnp.uint32)
    u = u.astype(jnp.float32)
    thr = jnp.asarray(thr, jnp.float32)
    seeds = jnp.asarray(seeds, jnp.uint32)
    m_self = _quantize_ref(u, thr[0], seeds[0], idx, mode=mode, bits=bits)
    acc = x.astype(jnp.float32)
    for s in range(nbrs.shape[0]):
        m_s = _quantize_ref(nbrs[s].astype(jnp.float32), thr[s + 1],
                            seeds[s + 1], idx, mode=mode, bits=bits)
        acc = acc + jnp.asarray(w[s], jnp.float32) * (m_s - m_self)
    return acc.astype(x.dtype), u - m_self


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential-recurrence oracle (see models.mamba2.ssd_reference).

    x: (b, s, h, p); dt: (b, s, h); A: (h,); Bm/Cm: (b, s, n).
    Returns y (b, s, h, p).
    """
    y, _ = ssd_reference(x, dt, A, Bm, Cm)
    return y
