"""Pallas TPU kernels (validated in interpret mode on CPU).

  zo_combine / zo_perturb — fused counter-RNG zeroth-order estimator
  zo_tangent              — kernel-side fwd_grad tangent, same RNG stream
  gossip_avg              — streamed pairwise model average
  gossip_mix              — fused k-neighbor weighted gossip combine
  ssd_scan                — Mamba2 chunked SSD scan

See ops.py for the jitted wrappers and ref.py for the jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
