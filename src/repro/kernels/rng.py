"""Counter-based RNG shared by the ZO kernels and their oracles.

A Wang-hash-based generator: stateless, position-indexed, identical
inside a Pallas kernel body and in pure jnp — which is what lets the
fused TPU kernel regenerate perturbation vectors u_r tile-by-tile in
VMEM (no (rv, d) Gaussian ever hits HBM) while remaining bit-exact
against the ``ref.py`` oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

# python-int constants: folded into the kernel as literals (no captured
# tracers inside pallas bodies)
_K_IDX = 2246822519
_K_R = 3266489917
_K_SEED = 2654435761
_U32 = jnp.uint32


def wang_hash(x):
    x = x.astype(_U32)
    x = (x ^ _U32(61)) ^ (x >> 16)
    x = x * _U32(9)
    x = x ^ (x >> 4)
    x = x * _U32(0x27D4EB2D)
    x = x ^ (x >> 15)
    return x


def _uniform(seed, idx, salt):
    key = (
        seed.astype(_U32) * _U32(_K_SEED)
        + idx.astype(_U32) * _U32(_K_IDX)
        + salt.astype(_U32) * _U32(_K_R)
    )
    h = wang_hash(key)
    # 24 high bits -> (0, 1]
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24)) + jnp.float32(
        1.0 / (1 << 25)
    )


def counter_normal(seed, idx, r):
    """Standard normal at global position ``idx`` for draw index ``r``.

    seed: uint32 scalar; idx: uint32 array; r: uint32 scalar.
    Box-Muller on two independent uniforms.
    """
    r = r.astype(_U32) if hasattr(r, "astype") else _U32(r)
    salt1 = r * _U32(2) + _U32(1)
    salt2 = r * _U32(2) + _U32(2)
    u1 = _uniform(seed, idx, salt1)
    u2 = _uniform(seed, idx, salt2)
    radius = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = jnp.float32(2.0 * 3.14159265358979) * u2
    return radius * jnp.cos(theta)
