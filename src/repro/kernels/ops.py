"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in
the CPU container (Pallas interpret mode executes the kernel bodies in
Python) and compile to real Mosaic kernels on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import gossip_avg as _gossip
from repro.kernels import gossip_mix as _gmix
from repro.kernels import opt_apply as _opt
from repro.kernels import ssd_scan as _ssd
from repro.kernels import zo_combine as _zo
from repro.kernels import zo_tangent as _zt

BLOCK = _zo.BLOCK


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to_block(x):
    d = x.shape[0]
    pad = (-d) % BLOCK
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, d


@partial(jax.jit, static_argnames=("d", "out_dtype", "interpret"))
def zo_combine(coeffs, seed, d: int, out_dtype=jnp.float32,
               interpret: bool | None = None, n_active=None):
    interpret = _interpret_default() if interpret is None else interpret
    dp = d + ((-d) % BLOCK)
    out = _zo.zo_combine(coeffs, seed, dp, n_active=n_active,
                         out_dtype=out_dtype, interpret=interpret)
    return out[:d]


@partial(jax.jit, static_argnames=("d", "dtype", "interpret"))
def zo_tangent(seed, r, d: int, dtype=jnp.float32, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    dp = d + ((-d) % BLOCK)
    return _zt.zo_tangent(seed, r, dp, dtype=dtype, interpret=interpret)[:d]


@partial(jax.jit, static_argnames=("interpret",))
def zo_perturb(x, seed, r, nu, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    xp, d = _pad_to_block(x)
    return _zo.zo_perturb(xp, seed, r, nu, interpret=interpret)[:d]


@partial(jax.jit, static_argnames=("rv", "out_dtype", "interpret"))
def zo_perturb_batch(x, seed, rv: int, nu, out_dtype=None, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    xp, d = _pad_to_block(x)
    return _zo.zo_perturb_batch(xp, seed, rv, nu, out_dtype=out_dtype,
                                interpret=interpret)[:, :d]


@partial(jax.jit, static_argnames=("interpret",))
def gossip_avg(x, y, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _gossip.gossip_avg(x, y, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def gossip_mix(x, nbrs, w_self, w, interpret: bool | None = None):
    """x: (d,), nbrs: (k, d), w_self scalar, w: (k,) -> W-row mix of x
    with its k neighbors (one fused O(d) pass)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _gmix.gossip_mix(x, nbrs, w_self, w, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def opt_apply(p, g, m, lr, beta, interpret: bool | None = None):
    """p, g, m: (d,) -> (new_p, new_m): the fused momentum-SGD apply
    ``m' = beta*m + (1-beta)*g; p' = p - lr*m'`` in one O(d) pass
    (f32 accumulate; m' stored in m.dtype before p' consumes it)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _opt.opt_apply(p, g, m, lr, beta, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 128, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
