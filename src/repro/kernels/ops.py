"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in
the CPU container (Pallas interpret mode executes the kernel bodies in
Python) and compile to real Mosaic kernels on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import compress_mix as _cmix
from repro.kernels import gossip_avg as _gossip
from repro.kernels import gossip_mix as _gmix
from repro.kernels import opt_apply as _opt
from repro.kernels import ssd_scan as _ssd
from repro.kernels import zo_combine as _zo
from repro.kernels import zo_tangent as _zt
from repro.obs.trace import op_scope

BLOCK = _zo.BLOCK


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to_block(x):
    d = x.shape[0]
    pad = (-d) % BLOCK
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, d


@partial(jax.jit, static_argnames=("d", "out_dtype", "interpret"))
def zo_combine(coeffs, seed, d: int, out_dtype=jnp.float32,
               interpret: bool | None = None, n_active=None):
    interpret = _interpret_default() if interpret is None else interpret
    dp = d + ((-d) % BLOCK)
    with op_scope("zo_combine"):
        out = _zo.zo_combine(coeffs, seed, dp, n_active=n_active,
                             out_dtype=out_dtype, interpret=interpret)
    return out[:d]


@partial(jax.jit, static_argnames=("d", "dtype", "interpret"))
def zo_tangent(seed, r, d: int, dtype=jnp.float32, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    dp = d + ((-d) % BLOCK)
    with op_scope("zo_tangent"):
        return _zt.zo_tangent(seed, r, dp, dtype=dtype, interpret=interpret)[:d]


@partial(jax.jit, static_argnames=("interpret",))
def zo_perturb(x, seed, r, nu, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    xp, d = _pad_to_block(x)
    with op_scope("zo_perturb"):
        return _zo.zo_perturb(xp, seed, r, nu, interpret=interpret)[:d]


@partial(jax.jit, static_argnames=("rv", "out_dtype", "interpret"))
def zo_perturb_batch(x, seed, rv: int, nu, out_dtype=None, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    xp, d = _pad_to_block(x)
    with op_scope("zo_perturb_batch"):
        return _zo.zo_perturb_batch(xp, seed, rv, nu, out_dtype=out_dtype,
                                    interpret=interpret)[:, :d]


@partial(jax.jit, static_argnames=("d", "out_dtype", "interpret"))
def zo_combine_plane(coeffs, seed, delta, nvalid, d: int, out_dtype=jnp.float32,
                     interpret: bool | None = None, n_active=None):
    """Plane-layout combine: ``d`` is the BLOCK-aligned plane dim and
    ``delta``/``nvalid`` the ``core.plane.rng_tables`` — the buffer is
    consumed whole (no pad/slice round-trip), draws ride the compact
    counter stream, pads are written as zeros."""
    interpret = _interpret_default() if interpret is None else interpret
    with op_scope("zo_combine_plane"):
        return _zo.zo_combine_plane(coeffs, seed, delta, nvalid, d,
                                    n_active=n_active, out_dtype=out_dtype,
                                    interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def zo_perturb_plane(x, seed, r, nu, delta, nvalid, interpret: bool | None = None):
    """Plane-layout perturb: x + nu * u_r on the compact counter stream;
    pad lanes pass x through (no pad/slice round-trip)."""
    interpret = _interpret_default() if interpret is None else interpret
    with op_scope("zo_perturb_plane"):
        return _zo.zo_perturb_plane(x, seed, r, nu, delta, nvalid,
                                    interpret=interpret)


@partial(jax.jit, static_argnames=("d", "dtype", "interpret"))
def zo_tangent_plane(seed, r, delta, nvalid, d: int, dtype=jnp.float32,
                     interpret: bool | None = None):
    """Plane-layout tangent u_r (compact counter stream, zeroed pads)."""
    interpret = _interpret_default() if interpret is None else interpret
    with op_scope("zo_tangent_plane"):
        return _zt.zo_tangent_plane(seed, r, delta, nvalid, d, dtype=dtype,
                                    interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def gossip_avg(x, y, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    with op_scope("gossip_avg"):
        return _gossip.gossip_avg(x, y, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def gossip_mix(x, nbrs, w_self, w, interpret: bool | None = None):
    """x: (d,), nbrs: (k, d), w_self scalar, w: (k,) -> W-row mix of x
    with its k neighbors (one fused O(d) pass)."""
    interpret = _interpret_default() if interpret is None else interpret
    with op_scope("gossip_mix"):
        return _gmix.gossip_mix(x, nbrs, w_self, w, interpret=interpret)


@partial(jax.jit, static_argnames=("mode", "bits", "interpret"))
def compress_mix(x, u, nbrs, w, thr, seeds, mode: str, bits: int = 0,
                 interpret: bool | None = None):
    """x: (d,), u: (d,) send basis, nbrs: (k, d) neighbor send bases,
    w: (k,), thr: (k+1,) payload statistics, seeds: (k+1,) uint32 ->
    (mixed (d,), residual (d,) f32): the fused compress -> decompress ->
    difference-form combine + error-feedback write-back in one O(d)
    pass (see kernels/compress_mix.py)."""
    interpret = _interpret_default() if interpret is None else interpret
    with op_scope("compress_mix"):
        return _cmix.compress_mix(x, u, nbrs, w, thr, seeds, mode=mode,
                                  bits=bits, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def opt_apply(p, g, m, lr, beta, interpret: bool | None = None):
    """p, g, m: (d,) -> (new_p, new_m): the fused momentum-SGD apply
    ``m' = beta*m + (1-beta)*g; p' = p - lr*m'`` in one O(d) pass
    (f32 accumulate; m' stored in m.dtype before p' consumes it)."""
    interpret = _interpret_default() if interpret is None else interpret
    with op_scope("opt_apply"):
        return _opt.opt_apply(p, g, m, lr, beta, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def adamw_apply(p, g, mu, nu, lr, b1, b2, eps, wd, count,
                interpret: bool | None = None):
    """p, g, mu, nu: (d,) -> (new_p, new_mu, new_nu): the fused AdamW
    apply in one O(d) pass (f32 accumulate; the rounded ``mu`` — e.g.
    bfloat16 under ``momentum_dtype`` — drives the update).  ``count``
    is the step count AFTER this update (1-based, may be traced): the
    bias corrections 1 - b^count are computed here, outside the kernel.
    """
    interpret = _interpret_default() if interpret is None else interpret
    c = jnp.asarray(count, jnp.float32)
    b1 = jnp.asarray(b1, jnp.float32)
    b2 = jnp.asarray(b2, jnp.float32)
    sc = jnp.stack([
        b1, b2,
        jnp.asarray(lr, jnp.float32),
        jnp.asarray(eps, jnp.float32),
        jnp.asarray(wd, jnp.float32),
        1.0 - b1 ** c,
        1.0 - b2 ** c,
    ])
    with op_scope("adamw_apply"):
        return _opt.adamw_apply(p, g, mu, nu, sc, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 128, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    with op_scope("ssd_scan"):
        return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
