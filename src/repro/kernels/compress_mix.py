"""Fused compress -> decompress -> weighted k-neighbor combine kernel.

One gossip round of the communication-reduced graph mixer, for one
agent with k neighbors and error-feedback send bases u (= x + e):

    m_j  = C(u_j)                      (compress + decompress)
    out  = x + sum_s w[s] * (m_s - m_self)
    e'   = u_self - m_self             (the new error-feedback residual)

streamed in a single O(d) pass with f32 accumulation.  The difference
form preserves the population mean exactly for ANY compressor (the
doubly-stochastic row weights cancel telescopically), and the residual
write-back rides the same sweep, so compression costs no extra HBM
round-trips over the plain ``gossip_mix`` combine.

The compressor itself is elementwise given a per-payload scalar
(``quantize`` below): top-k needs the k-th largest |u| as a threshold,
qsgd the payload's inf-norm as a scale — both are O(d) reductions the
caller computes once per payload and passes as tiny array operands
(no recompilation across steps).  qsgd's stochastic rounding draws
from the counter-based RNG at the tile's global positions, so the
kernel regenerates the randomness in VMEM exactly like the ZO kernels
and stays bit-exact against the ``ref.py`` oracle.

Non-block-aligned ``d`` is tail-padded here (pad lanes compress to 0
and mix to 0), so callers never see the BLOCK constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rng import _uniform

BLOCK = 8192

# salt for the qsgd stochastic-rounding uniform stream — distinct from
# the Box-Muller salts rng.counter_normal derives from its draw index
_QSGD_SALT = 97

MODES = ("topk", "qsgd")


def quantize(u, thr, seed, idx, *, mode: str, bits: int = 0):
    """Elementwise compress+decompress of a payload (f32 -> f32).

    Identical inside the Pallas body (per tile) and in the jnp mixers
    (full width) — the property that keeps kernel and oracle bit-exact.

    ``thr`` is the payload's scalar statistic: for ``topk`` the k-th
    largest |u| of the FULL vector (kept-set threshold), for ``qsgd``
    the full vector's inf-norm (clamped > 0).  ``seed`` (uint32 scalar,
    per payload per round) and ``idx`` (uint32 global positions) drive
    qsgd's stochastic rounding on the counter stream.
    """
    if mode == "topk":
        return jnp.where(jnp.abs(u) >= thr, u, jnp.float32(0.0))
    if mode == "qsgd":
        levels = float((1 << bits) - 1)
        scaled = jnp.abs(u) / thr * jnp.float32(levels)  # in [0, levels]
        lo = jnp.floor(scaled)
        p = scaled - lo
        b = (_uniform(seed, idx, jnp.uint32(_QSGD_SALT)) < p).astype(jnp.float32)
        return jnp.sign(u) * thr * (lo + b) * jnp.float32(1.0 / levels)
    raise ValueError(f"unknown compression mode {mode!r} (one of {MODES})")


def _body(x_ref, u_ref, nbrs_ref, w_ref, thr_ref, seed_ref, o_ref, e_ref,
          *, k: int, mode: str, bits: int, block: int):
    pid = pl.program_id(0)
    idx = (pid * block + jax.lax.iota(jnp.int32, block)).astype(jnp.uint32)
    u = u_ref[...].astype(jnp.float32)
    m_self = quantize(u, thr_ref[0], seed_ref[0], idx, mode=mode, bits=bits)
    acc = x_ref[...].astype(jnp.float32)
    for s in range(k):
        m_s = quantize(nbrs_ref[s, :].astype(jnp.float32), thr_ref[s + 1],
                       seed_ref[s + 1], idx, mode=mode, bits=bits)
        acc = acc + w_ref[s] * (m_s - m_self)
    o_ref[...] = acc.astype(o_ref.dtype)
    e_ref[...] = (u - m_self).astype(e_ref.dtype)


def compress_mix(x, u, nbrs, w, thr, seeds, *, mode: str, bits: int = 0,
                 interpret: bool = False):
    """x: (d,) params row; u: (d,) f32 send basis (x + residual);
    nbrs: (k, d) f32 neighbor send bases; w: (k,) f32 edge weights;
    thr: (k+1,) f32 payload statistics [self, nbr_0..]; seeds: (k+1,)
    uint32 payload seeds -> (out (d,) x.dtype, residual (d,) f32)."""
    assert x.ndim == 1 and u.shape == x.shape, (x.shape, u.shape)
    assert nbrs.ndim == 2 and nbrs.shape[1] == x.shape[0], (x.shape, nbrs.shape)
    d = x.shape[0]
    k = nbrs.shape[0]
    w = jnp.asarray(w, jnp.float32).reshape(k)
    thr = jnp.asarray(thr, jnp.float32).reshape(k + 1)
    seeds = jnp.asarray(seeds, jnp.uint32).reshape(k + 1)
    pad = (-d) % BLOCK
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        u = jnp.concatenate([u, jnp.zeros((pad,), u.dtype)])
        nbrs = jnp.concatenate([nbrs, jnp.zeros((k, pad), nbrs.dtype)], axis=1)
    dp = d + pad
    out, resid = pl.pallas_call(
        functools.partial(_body, k=k, mode=mode, bits=bits, block=BLOCK),
        grid=(dp // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((k, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k + 1,), lambda i: (0,)),
            pl.BlockSpec((k + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), x.dtype),
            jax.ShapeDtypeStruct((dp,), jnp.float32),
        ],
        interpret=interpret,
    )(x, u.astype(jnp.float32), nbrs.astype(jnp.float32), w, thr, seeds)
    return out[:d], resid[:d]
