"""Pairwise gossip averaging kernel: out = (x + y) / 2, streamed.

Trivial arithmetic, but fusing it saves one full HBM round-trip per
interaction on multi-GB models (the gossip step is pure memory
traffic).  f32 accumulate for bf16 inputs.  Non-block-aligned ``d`` is
tail-padded here (matching the ZO kernels' contract), so callers never
see the BLOCK constraint.  The k-neighbor generalization lives in
``gossip_mix.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _body(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    o_ref[...] = ((x + y) * 0.5).astype(o_ref.dtype)


def gossip_avg(x, y, *, interpret: bool = False):
    """x, y: (d,) same dtype -> (x + y) / 2, any d."""
    assert x.shape == y.shape and x.ndim == 1
    d = x.shape[0]
    pad = (-d) % BLOCK
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
    dp = d + pad
    out = pl.pallas_call(
        _body,
        grid=(dp // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), x.dtype),
        interpret=interpret,
    )(x, y)
    return out[:d]
