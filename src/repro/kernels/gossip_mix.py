"""k-neighbor weighted gossip mixing kernel.

Generalizes ``gossip_avg``'s 2-partner average to the graph-topology
interaction step: for one agent with k neighbors,

    out = w_self * x + sum_s w[s] * nbrs[s],

streamed in a single O(d) pass with f32 accumulation (the gossip step
is pure HBM traffic on multi-GB models; fusing the weighted combine
saves k-1 full round-trips over chained binary ops).  The (k + 2) * d
traffic claim counts the kernel's own operands — it holds end-to-end
when the neighbor buffers are already resident (the ppermute lowering
in ``topology.mixer``), not when a gather first materializes them.
Non-block-aligned ``d`` is tail-padded here, so callers never see the
BLOCK constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _body(x_ref, nbrs_ref, w_ref, o_ref, *, k: int):
    acc = w_ref[0] * x_ref[...].astype(jnp.float32)
    for s in range(k):
        acc = acc + w_ref[s + 1] * nbrs_ref[s, :].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def gossip_mix(x, nbrs, w_self, w, *, interpret: bool = False):
    """x: (d,), nbrs: (k, d) same dtype, w_self scalar, w: (k,) f32
    -> (d,) in x.dtype.  Weights are array operands (no recompilation
    across steps / topologies of equal degree)."""
    assert x.ndim == 1 and nbrs.ndim == 2 and nbrs.shape[1] == x.shape[0], (
        x.shape, nbrs.shape)
    d = x.shape[0]
    k = nbrs.shape[0]
    wts = jnp.concatenate([
        jnp.asarray(w_self, jnp.float32).reshape(1),
        jnp.asarray(w, jnp.float32).reshape(k),
    ])
    pad = (-d) % BLOCK
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        nbrs = jnp.concatenate([nbrs, jnp.zeros((k, pad), nbrs.dtype)], axis=1)
    dp = d + pad
    out = pl.pallas_call(
        functools.partial(_body, k=k),
        grid=(dp // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((k, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((k + 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), x.dtype),
        interpret=interpret,
    )(x, nbrs, wts)
    return out[:d]
