"""Mamba2 SSD chunked-scan Pallas kernel.

Grid: (batch*heads, n_chunks) with the chunk dimension iterated
sequentially — the inter-chunk SSM state lives in a VMEM scratch
accumulator that persists across grid steps (reset at chunk 0).

Per (bh, chunk) step, everything is MXU-shaped matmul work:
  intra:  y1 = [(C B^T) ⊙ exp(cs_i - cs_j) ⊙ causal] @ (x * dt)
  inter:  y2 = exp(cs) ⊙ (C @ H_prev^T)
  state:  H  = exp(cs_last) * H_prev + (x * dt * decay_to_end)^T @ B

Block shapes: x (1, l, p), B/C (1, l, n), dt (1, l); l = chunk length
(128 default), p = head dim (64/32), n = ssm state (64..128) — the
(l, l) intra matrix and (p, n) state sit comfortably in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_scratch, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)  # (l, p)
    dt = dt_ref[0].astype(jnp.float32)  # (l,)
    A = a_ref[0].astype(jnp.float32)  # scalar
    Bm = b_ref[0].astype(jnp.float32)  # (l, n)
    Cm = c_ref[0].astype(jnp.float32)  # (l, n)

    dA = dt * A  # (l,) log decays (<= 0)
    cs = jnp.cumsum(dA)  # (l,)

    # ---- intra-chunk ----------------------------------------------------
    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (l, l)
    diff = cs[:, None] - cs[None, :]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    diff = jnp.where(causal, diff, -jnp.inf)
    M = CB * jnp.exp(diff)
    xbar = x * dt[:, None]  # (l, p)
    y = jnp.dot(M, xbar, preferred_element_type=jnp.float32)

    # ---- inter-chunk (contribution of carried state) ----------------------
    h_prev = h_scratch[...]  # (p, n)
    y = y + jnp.exp(cs)[:, None] * jnp.dot(
        Cm, h_prev.T, preferred_element_type=jnp.float32
    )

    # ---- state update -----------------------------------------------------
    decay_to_end = jnp.exp(cs[-1] - cs)  # (l,)
    weighted = xbar * decay_to_end[:, None]  # (l, p)
    h_new = jnp.exp(cs[-1]) * h_prev + jnp.dot(
        weighted.T, Bm, preferred_element_type=jnp.float32
    )
    h_scratch[...] = h_new
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); Bm/Cm: (b, s, n).

    Returns y: (b, s, h, p).  B/C are shared across heads (ngroups=1) —
    broadcast here so each (batch*head) grid row is independent.
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    bh = b * h

    # (bh, s, p) / (bh, s) / (bh,) / (bh, s, n)
    xf = x.transpose(0, 2, 1, 3).reshape(bh, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bh, s)
    af = jnp.broadcast_to(A[None, :], (b, h)).reshape(bh)
    bf = jnp.broadcast_to(Bm[:, None], (b, h, s, n)).reshape(bh, s, n)
    cf = jnp.broadcast_to(Cm[:, None], (b, h, s, n)).reshape(bh, s, n)

    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        functools.partial(_body, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)

    return out.reshape(b, h, s, p).transpose(0, 2, 1, 3)
