"""Kernel-side tangent generation for the fused forward-gradient path.

``fwd_grad`` (Baydin-style (u . grad F) u) needs the tangent u_r as a
*materialized* vector: ``jax.jvp`` pushes it through the loss, so unlike
the finite-difference kinds it can never stay virtual.  What the kernel
buys is the generation itself — one O(d) pass that writes u_r straight
from the counter RNG, instead of the tree path's per-leaf
``jax.random.normal`` + pytree reassembly — and, crucially, stream
compatibility: u_r here is bit-identical to the u_r that ``zo_perturb``
adds and that ``zo_combine`` regenerates in VMEM, so the estimate
g = (1/rv) sum_r jvp_r u_r can be assembled by ``zo_combine`` without
ever storing the rv tangents or an O(d) accumulator.

  zo_tangent_kernel : out = u_r = counter_normal(seed, ., r)

Same (8, 128)-aligned 1-D blocking and tiny-array-operand seeding as
``zo_combine.py`` (BLOCK is shared), so the kernel never recompiles
across draws or steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rng import counter_normal
from repro.kernels.zo_combine import BLOCK


def _zo_tangent_body(meta_ref, o_ref, *, block: int):
    pid = pl.program_id(0)
    base = (pid * block + jax.lax.iota(jnp.int32, block)).astype(jnp.uint32)
    seed = meta_ref[0].astype(jnp.uint32)
    r = meta_ref[1].astype(jnp.uint32)
    o_ref[...] = counter_normal(seed, base, r).astype(o_ref.dtype)


def zo_tangent(seed, r, d: int, *, dtype=jnp.float32, interpret: bool = False):
    """(d,) tangent u_r on the shared counter-RNG stream.

    seed/r: int32 scalars/arrays (array operands — no recompiles across
    draws).  Positions are global indices, so the f32 output is
    bit-equal to ``(zo_perturb(x, seed, r, nu) - x) / nu`` at x = 0,
    nu = 1 and to the u_r that ``zo_combine`` regenerates in VMEM
    (narrower ``dtype``\\s round that shared f32 stream on output).
    """
    assert d % BLOCK == 0, d
    meta = jnp.stack([jnp.asarray(seed, jnp.int32), jnp.asarray(r, jnp.int32)])
    return pl.pallas_call(
        functools.partial(_zo_tangent_body, block=BLOCK),
        grid=(d // BLOCK,),
        in_specs=[pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), dtype),
        interpret=interpret,
    )(meta)


def _zo_tangent_plane_body(meta_ref, delta_ref, nvalid_ref, o_ref, *, block: int):
    pid = pl.program_id(0)
    lane = jax.lax.iota(jnp.int32, block)
    base = (pid * block + lane - delta_ref[0]).astype(jnp.uint32)
    seed = meta_ref[0].astype(jnp.uint32)
    r = meta_ref[1].astype(jnp.uint32)
    u = counter_normal(seed, base, r)
    valid = lane < nvalid_ref[0]
    o_ref[...] = jnp.where(valid, u, 0.0).astype(o_ref.dtype)


def zo_tangent_plane(seed, r, delta, nvalid, d: int, *, dtype=jnp.float32,
                     interpret: bool = False):
    """Plane-layout tangent: u_r on the compact counter stream with the
    block-alignment pads zeroed (``delta`` / ``nvalid`` are the tables
    from ``core.plane.rng_tables``), bit-equal at the valid lanes to
    ``zo_tangent`` over the compact vector."""
    assert d % BLOCK == 0, d
    assert delta.shape == nvalid.shape == (d // BLOCK,), (delta.shape, d)
    meta = jnp.stack([jnp.asarray(seed, jnp.int32), jnp.asarray(r, jnp.int32)])
    return pl.pallas_call(
        functools.partial(_zo_tangent_plane_body, block=BLOCK),
        grid=(d // BLOCK,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), dtype),
        interpret=interpret,
    )(meta, jnp.asarray(delta, jnp.int32), jnp.asarray(nvalid, jnp.int32))
