"""Fused optimizer-apply kernels: the local-update phase in one pass
(momentum-SGD ``opt_apply`` and AdamW ``adamw_apply``).

The tree-path update walks the optimizer state twice per agent step —
the momentum accumulator is written by the momentum update and then
read back by the parameter update:

    m <- beta * m + (1 - beta) * g     (read m, g; write m)
    p <- p - lr * m                    (read p, m; write p)

On multi-GB models that is 6 O(d) HBM passes of pure memory traffic.
This kernel streams both lines per VMEM tile, so the intermediate
momentum never makes the extra round-trip: read p, g, m; write p, m —
5 passes, and the momentum operands shrink further with
``momentum_dtype="bfloat16"``.

Accumulation is f32; the stored momentum is rounded to the momentum
buffer's dtype *before* the parameter update consumes it (matching the
tree path's ``momentum_dtype`` write-back semantics exactly).  ``lr``
and ``beta`` arrive as a tiny array operand so the kernel never
recompiles across steps or schedules.  Non-block-aligned ``d`` is
tail-padded here (matching the ZO kernels' contract), so callers never
see the BLOCK constraint.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _body(p_ref, g_ref, m_ref, sc_ref, op_ref, om_ref):
    beta = sc_ref[0]
    lr = sc_ref[1]
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    new_m = (beta * m + (1.0 - beta) * g).astype(om_ref.dtype)
    om_ref[...] = new_m
    op_ref[...] = (p - lr * new_m.astype(jnp.float32)).astype(op_ref.dtype)


def opt_apply(p, g, m, lr, beta, *, interpret: bool = False):
    """p, g, m: (d,) -> (new_p, new_m), any d.

    ``new_m = beta*m + (1-beta)*g`` in ``m.dtype`` (bf16-capable),
    ``new_p = p - lr*new_m`` in ``p.dtype``, one streamed O(d) pass.
    """
    assert p.shape == g.shape == m.shape and p.ndim == 1, (
        p.shape, g.shape, m.shape)
    d = p.shape[0]
    sc = jnp.stack([
        jnp.asarray(beta, jnp.float32), jnp.asarray(lr, jnp.float32)
    ])
    pad = (-d) % BLOCK
    if pad:
        p = jnp.concatenate([p, jnp.zeros((pad,), p.dtype)])
        g = jnp.concatenate([g, jnp.zeros((pad,), g.dtype)])
        m = jnp.concatenate([m, jnp.zeros((pad,), m.dtype)])
    dp = d + pad
    new_p, new_m = pl.pallas_call(
        _body,
        grid=(dp // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((dp,), p.dtype),
            jax.ShapeDtypeStruct((dp,), m.dtype),
        ),
        interpret=interpret,
    )(p, g, m, sc)
    return new_p[:d], new_m[:d]


def _adamw_body(p_ref, g_ref, mu_ref, nu_ref, sc_ref, op_ref, omu_ref, onu_ref):
    b1 = sc_ref[0]
    b2 = sc_ref[1]
    lr = sc_ref[2]
    eps = sc_ref[3]
    wd = sc_ref[4]
    bc1 = sc_ref[5]
    bc2 = sc_ref[6]
    g = g_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    nu = nu_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    # the stored (possibly bf16) first moment drives the update, like
    # the sgd kernel's momentum write-back — resume from a checkpoint
    # replays the identical trajectory
    new_mu = (b1 * mu + (1.0 - b1) * g).astype(omu_ref.dtype)
    new_nu = b2 * nu + (1.0 - b2) * g * g
    upd = (new_mu.astype(jnp.float32) / bc1
           / (jnp.sqrt(new_nu / bc2) + eps) + wd * p)
    omu_ref[...] = new_mu
    onu_ref[...] = new_nu.astype(onu_ref.dtype)
    op_ref[...] = (p - lr * upd).astype(op_ref.dtype)


def adamw_apply(p, g, mu, nu, sc, *, interpret: bool = False):
    """p, g, mu, nu: (d,) -> (new_p, new_mu, new_nu), any d.

    The fused AdamW apply: both moment updates and the parameter update
    stream through one VMEM tile per block — read p, g, mu, nu; write
    p, mu, nu — instead of the tree path's separate moment-update and
    apply passes.  ``sc`` is the (7,) f32 operand
    ``[b1, b2, lr, eps, weight_decay, bias_corr1, bias_corr2]`` (the
    bias corrections depend on the traced step count, so the wrapper in
    ``kernels.ops`` computes them outside; tiny array operand — no
    recompiles across steps).  f32 accumulation; ``mu`` may be stored
    bfloat16 (``momentum_dtype``) and the *rounded* value drives the
    update; ``nu`` (second moment) should stay f32 for range.
    """
    assert p.shape == g.shape == mu.shape == nu.shape and p.ndim == 1, (
        p.shape, g.shape, mu.shape, nu.shape)
    assert sc.shape == (7,), sc.shape
    d = p.shape[0]
    pad = (-d) % BLOCK
    if pad:
        p = jnp.concatenate([p, jnp.zeros((pad,), p.dtype)])
        g = jnp.concatenate([g, jnp.zeros((pad,), g.dtype)])
        mu = jnp.concatenate([mu, jnp.zeros((pad,), mu.dtype)])
        nu = jnp.concatenate([nu, jnp.zeros((pad,), nu.dtype)])
    dp = d + pad
    new_p, new_mu, new_nu = pl.pallas_call(
        _adamw_body,
        grid=(dp // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((7,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((dp,), p.dtype),
            jax.ShapeDtypeStruct((dp,), mu.dtype),
            jax.ShapeDtypeStruct((dp,), nu.dtype),
        ),
        interpret=interpret,
    )(p, g, mu, nu, sc)
    return new_p[:d], new_mu[:d], new_nu[:d]
