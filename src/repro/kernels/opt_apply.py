"""Fused momentum-SGD apply kernel: the local-update phase in one pass.

The tree-path update walks the optimizer state twice per agent step —
the momentum accumulator is written by the momentum update and then
read back by the parameter update:

    m <- beta * m + (1 - beta) * g     (read m, g; write m)
    p <- p - lr * m                    (read p, m; write p)

On multi-GB models that is 6 O(d) HBM passes of pure memory traffic.
This kernel streams both lines per VMEM tile, so the intermediate
momentum never makes the extra round-trip: read p, g, m; write p, m —
5 passes, and the momentum operands shrink further with
``momentum_dtype="bfloat16"``.

Accumulation is f32; the stored momentum is rounded to the momentum
buffer's dtype *before* the parameter update consumes it (matching the
tree path's ``momentum_dtype`` write-back semantics exactly).  ``lr``
and ``beta`` arrive as a tiny array operand so the kernel never
recompiles across steps or schedules.  Non-block-aligned ``d`` is
tail-padded here (matching the ZO kernels' contract), so callers never
see the BLOCK constraint.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _body(p_ref, g_ref, m_ref, sc_ref, op_ref, om_ref):
    beta = sc_ref[0]
    lr = sc_ref[1]
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    new_m = (beta * m + (1.0 - beta) * g).astype(om_ref.dtype)
    om_ref[...] = new_m
    op_ref[...] = (p - lr * new_m.astype(jnp.float32)).astype(op_ref.dtype)


def opt_apply(p, g, m, lr, beta, *, interpret: bool = False):
    """p, g, m: (d,) -> (new_p, new_m), any d.

    ``new_m = beta*m + (1-beta)*g`` in ``m.dtype`` (bf16-capable),
    ``new_p = p - lr*new_m`` in ``p.dtype``, one streamed O(d) pass.
    """
    assert p.shape == g.shape == m.shape and p.ndim == 1, (
        p.shape, g.shape, m.shape)
    d = p.shape[0]
    sc = jnp.stack([
        jnp.asarray(beta, jnp.float32), jnp.asarray(lr, jnp.float32)
    ])
    pad = (-d) % BLOCK
    if pad:
        p = jnp.concatenate([p, jnp.zeros((pad,), p.dtype)])
        g = jnp.concatenate([g, jnp.zeros((pad,), g.dtype)])
        m = jnp.concatenate([m, jnp.zeros((pad,), m.dtype)])
    dp = d + pad
    new_p, new_m = pl.pallas_call(
        _body,
        grid=(dp // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((dp,), p.dtype),
            jax.ShapeDtypeStruct((dp,), m.dtype),
        ),
        interpret=interpret,
    )(p, g, m, sc)
    return new_p[:d], new_m[:d]
