"""Fused zeroth-order estimator kernels.

The ZO estimate g = (1/rv) * sum_r c_r u_r over a d ~ 1e9 parameter
vector is HBM-bandwidth-bound if the Gaussians u_r are materialized:
rv * d floats written + read.  These kernels regenerate u_r from the
counter-based RNG *inside VMEM tiles*, so HBM traffic is exactly one
read of x (perturb) / one write of g (combine) regardless of rv.

  zo_perturb_kernel : out = x + nu * u_r            (per-candidate eval)
  zo_combine_kernel : out = (1/rv) sum_r c_r u_r    (estimate assembly)

Tiles are (8, 128)-aligned 1-D blocks (BLOCK = 8192 lanes per grid step
keeps the VPU busy while fitting VMEM comfortably).  Seeds / draw
indices arrive as tiny array operands so the kernels never recompile
across steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rng import counter_normal

BLOCK = 8192


def _zo_combine_body(coeffs_ref, meta_ref, denom_ref, o_ref, *, rv: int, block: int):
    pid = pl.program_id(0)
    base = (pid * block + jax.lax.iota(jnp.int32, block)).astype(jnp.uint32)
    seed = meta_ref[0].astype(jnp.uint32)
    acc = jnp.zeros((block,), jnp.float32)
    for r in range(rv):
        u = counter_normal(seed, base, jnp.uint32(r))
        acc = acc + coeffs_ref[r] * u
    o_ref[...] = (acc / denom_ref[0]).astype(o_ref.dtype)


def zo_combine(coeffs, seed, d: int, *, n_active=None, out_dtype=jnp.float32,
               interpret: bool = False):
    """coeffs: (rv,) f32; seed: int32 scalar/array -> (d,) ``out_dtype``.

    Accumulation is always f32 in VMEM; ``out_dtype=bfloat16`` halves
    the single HBM write of the estimate (the only O(d) traffic here).

    ``n_active`` (optional f32 scalar, may be traced) replaces the
    static ``rv`` as the averaging denominator — the ragged-``rv``
    support for heterogeneous populations: a group padded to ``rv_max``
    draws zeroes the excess coefficients and passes its own draw count
    here, so the kernel stays one O(d) pass regardless of the mix.
    """
    rv = int(coeffs.shape[0])
    assert d % BLOCK == 0, d
    meta = jnp.asarray(seed, jnp.int32).reshape(1)
    denom = (jnp.float32(rv) if n_active is None
             else jnp.asarray(n_active, jnp.float32)).reshape(1)
    return pl.pallas_call(
        functools.partial(_zo_combine_body, rv=rv, block=BLOCK),
        grid=(d // BLOCK,),
        in_specs=[
            pl.BlockSpec((rv,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), out_dtype),
        interpret=interpret,
    )(coeffs.astype(jnp.float32), meta, denom)


def _zo_combine_plane_body(coeffs_ref, meta_ref, denom_ref, delta_ref,
                           nvalid_ref, o_ref, *, rv: int, block: int):
    pid = pl.program_id(0)
    lane = jax.lax.iota(jnp.int32, block)
    # compact counter stream: plane index minus the block's pad offset
    # reproduces the tree-layout ravel's counter indices bit-exactly
    base = (pid * block + lane - delta_ref[0]).astype(jnp.uint32)
    seed = meta_ref[0].astype(jnp.uint32)
    acc = jnp.zeros((block,), jnp.float32)
    for r in range(rv):
        u = counter_normal(seed, base, jnp.uint32(r))
        acc = acc + coeffs_ref[r] * u
    valid = lane < nvalid_ref[0]
    o_ref[...] = jnp.where(valid, acc / denom_ref[0], 0.0).astype(o_ref.dtype)


def zo_combine_plane(coeffs, seed, delta, nvalid, d: int, *, n_active=None,
                     out_dtype=jnp.float32, interpret: bool = False):
    """Plane-layout ``zo_combine``: compact counter stream + masked pads.

    ``delta`` / ``nvalid`` are the per-block int32 tables from
    ``core.plane.rng_tables`` — lane j of block b draws
    ``counter_normal(seed, b*BLOCK + j - delta[b], r)`` (the *compact*
    index of the underlying leaf element), and lanes >= ``nvalid[b]``
    (the block-alignment pads) are written as zeros, preserving the
    plane's zero-pad invariant.  The buffer is consumed directly — no
    concatenate-pad/slice round-trip through HBM like the generic
    ``ops`` wrappers pay on unaligned vectors.
    """
    rv = int(coeffs.shape[0])
    assert d % BLOCK == 0, d
    assert delta.shape == nvalid.shape == (d // BLOCK,), (delta.shape, d)
    meta = jnp.asarray(seed, jnp.int32).reshape(1)
    denom = (jnp.float32(rv) if n_active is None
             else jnp.asarray(n_active, jnp.float32)).reshape(1)
    return pl.pallas_call(
        functools.partial(_zo_combine_plane_body, rv=rv, block=BLOCK),
        grid=(d // BLOCK,),
        in_specs=[
            pl.BlockSpec((rv,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), out_dtype),
        interpret=interpret,
    )(coeffs.astype(jnp.float32), meta, denom,
      jnp.asarray(delta, jnp.int32), jnp.asarray(nvalid, jnp.int32))


def _zo_perturb_body(x_ref, meta_ref, nu_ref, o_ref, *, block: int):
    pid = pl.program_id(0)
    base = (pid * block + jax.lax.iota(jnp.int32, block)).astype(jnp.uint32)
    seed = meta_ref[0].astype(jnp.uint32)
    r = meta_ref[1].astype(jnp.uint32)
    u = counter_normal(seed, base, r)
    o_ref[...] = (x_ref[...].astype(jnp.float32) + nu_ref[0] * u).astype(o_ref.dtype)


def zo_perturb(x, seed, r, nu, *, interpret: bool = False):
    """x: (d,) -> x + nu * u_r with u_r regenerated in VMEM."""
    d = x.shape[0]
    assert d % BLOCK == 0, d
    meta = jnp.stack([jnp.asarray(seed, jnp.int32), jnp.asarray(r, jnp.int32)])
    nu_arr = jnp.asarray(nu, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_zo_perturb_body, block=BLOCK),
        grid=(d // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=interpret,
    )(x, meta, nu_arr)


def _zo_perturb_plane_body(x_ref, meta_ref, nu_ref, delta_ref, nvalid_ref,
                           o_ref, *, block: int):
    pid = pl.program_id(0)
    lane = jax.lax.iota(jnp.int32, block)
    base = (pid * block + lane - delta_ref[0]).astype(jnp.uint32)
    seed = meta_ref[0].astype(jnp.uint32)
    r = meta_ref[1].astype(jnp.uint32)
    u = counter_normal(seed, base, r)
    xv = x_ref[...]
    valid = lane < nvalid_ref[0]
    cand = (xv.astype(jnp.float32) + nu_ref[0] * u).astype(o_ref.dtype)
    # pad lanes pass x through untouched (zero stays zero)
    o_ref[...] = jnp.where(valid, cand, xv.astype(o_ref.dtype))


def zo_perturb_plane(x, seed, r, nu, delta, nvalid, *, interpret: bool = False):
    """Plane-layout ``zo_perturb``: x + nu * u_r on the compact counter
    stream (see ``zo_combine_plane``); pad lanes are passed through."""
    d = x.shape[0]
    assert d % BLOCK == 0, d
    assert delta.shape == nvalid.shape == (d // BLOCK,), (delta.shape, d)
    meta = jnp.stack([jnp.asarray(seed, jnp.int32), jnp.asarray(r, jnp.int32)])
    nu_arr = jnp.asarray(nu, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_zo_perturb_plane_body, block=BLOCK),
        grid=(d // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=interpret,
    )(x, meta, nu_arr, jnp.asarray(delta, jnp.int32),
      jnp.asarray(nvalid, jnp.int32))


def _zo_perturb_batch_body(x_ref, meta_ref, nu_ref, o_ref, *, rv: int, block: int):
    pid = pl.program_id(0)
    base = (pid * block + jax.lax.iota(jnp.int32, block)).astype(jnp.uint32)
    seed = meta_ref[0].astype(jnp.uint32)
    xv = x_ref[...].astype(jnp.float32)
    for r in range(rv):
        u = counter_normal(seed, base, jnp.uint32(r))
        o_ref[r, :] = (xv + nu_ref[0] * u).astype(o_ref.dtype)


def zo_perturb_batch(x, seed, rv: int, nu, *, out_dtype=None, interpret: bool = False):
    """x: (d,) -> (rv, d) candidates x + nu * u_r, one HBM read of x.

    All rv rows are produced from a single pass over x (the sequential
    ``zo_perturb`` re-reads x once per draw), so candidate generation
    reads O(d) instead of O(rv*d).
    """
    d = x.shape[0]
    assert d % BLOCK == 0, d
    out_dtype = x.dtype if out_dtype is None else out_dtype
    meta = jnp.asarray(seed, jnp.int32).reshape(1)
    nu_arr = jnp.asarray(nu, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_zo_perturb_batch_body, rv=rv, block=BLOCK),
        grid=(d // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rv, BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((rv, d), out_dtype),
        interpret=interpret,
    )(x, meta, nu_arr)
