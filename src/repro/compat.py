"""JAX version compatibility shims.

The repo pins no single JAX release: the container ships 0.4.37 while
the shard_map / AbstractMesh APIs kept moving upstream.  Policy: every
call site that touches a moved or re-signatured JAX API goes through
this module, never through ``jax.<attr>`` directly, so a version bump
is a one-file change.

Shimmed surfaces:
  * ``shard_map``     — ``jax.shard_map`` (>= 0.6) vs
                        ``jax.experimental.shard_map.shard_map`` (0.4.x),
                        reconciling ``axis_names=`` / ``check_vma=``
                        (new) with ``check_rep=`` (old).
  * ``abstract_mesh`` — ``AbstractMesh(shape_tuple)`` (0.4.37) vs
                        ``AbstractMesh(shape, names)`` (newer).
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional, Set

import jax

__all__ = ["shard_map", "abstract_mesh", "replicate_operand"]


def _resolve_shard_map() -> Callable:
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn  # JAX <= 0.5

    return fn


_SHARD_MAP = _resolve_shard_map()
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)


def shard_map(
    f: Callable,
    *,
    mesh,
    in_specs,
    out_specs,
    axis_names: Optional[Set[str]] = None,
    check_vma: bool = False,
) -> Callable:
    """Portable ``shard_map`` with the modern keyword surface.

    ``axis_names`` (partial manual sharding) and ``check_vma`` (varying
    manual-axes check) are forwarded when the installed JAX understands
    them; on 0.4.x ``check_vma`` maps onto the old ``check_rep`` flag
    and partial ``axis_names`` degrades to full-manual over the whole
    mesh — specs that omit an axis replicate over it, so the region is
    computed once per shard of the unmentioned axes (numerically
    identical; the 0.4.x ``auto=`` path aborts XLA:CPU's partitioner).
    """
    kwargs: dict[str, Any] = {
        "mesh": mesh,
        "in_specs": in_specs,
        "out_specs": out_specs,
    }
    if "check_vma" in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = check_vma
    if axis_names is not None and "axis_names" in _SHARD_MAP_PARAMS:
        kwargs["axis_names"] = set(axis_names)
    return _SHARD_MAP(f, **kwargs)


def replicate_operand(x, mesh):
    """Pin a shard_map operand to fully-replicated layout.

    On JAX 0.4.x with ``jax_threefry_partitionable=False`` (the
    default), a threefry-derived array (``jax.random.split`` /
    ``fold_in`` of a traced key) that feeds a shard_map gets its
    *producer* partitioned by XLA — and the non-partitionable threefry
    lowering is not offset-invariant, so every shard computes wrong key
    bits.  Constraining the operand replicated forces the producer to
    run whole on each device.  Apply this to RNG-derived operands only:
    it is an all-gather for anything actually sharded.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    sharding = NamedSharding(mesh, PartitionSpec())
    return jax.tree.map(lambda v: jax.lax.with_sharding_constraint(v, sharding), x)


def abstract_mesh(shape, names):
    """``jax.sharding.AbstractMesh`` across the signature change.

    0.4.37 takes a single ``shape_tuple`` of ``(name, size)`` pairs;
    newer releases take ``(axis_sizes, axis_names)``.
    """
    cls = jax.sharding.AbstractMesh
    params = list(inspect.signature(cls.__init__).parameters)
    if len(params) > 1 and params[1] == "shape_tuple":
        return cls(tuple(zip(names, shape)))
    return cls(tuple(shape), tuple(names))
