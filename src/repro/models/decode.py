"""Serving: KV/SSM cache construction and single-token decode steps.

``serve_step(params, cfg, cache, tokens, pos)`` consumes ONE new token
per sequence against a cache of ``max_seq`` (the assigned decode shapes:
decode_32k, long_500k).  Attention archs use a dynamic-slice cache
update + chunked attention over the cache; SSM archs use the O(1)
recurrent state; hybrids use both.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import mamba2
from repro.models.layers import (
    apply_rope,
    attention_qkv,
    chunked_attention,
    mlp_block,
    rms_norm,
    rope_tables,
    sinusoidal_embedding,
    softcap,
)
from repro.models.transformer import _head_weight

PyTree = Any


def use_ring(cfg, max_seq: int) -> bool:
    """Ring-buffer KV cache: O(window) storage for pure sliding-window
    serving (the long_500k optimized variant — EXPERIMENTS.md §Perf C).
    Public: the serve engine uses this to decide whether decode
    positions are bounded by the cache allocation."""
    return bool(
        cfg.decode_window_slice
        and cfg.sliding_window
        and cfg.sliding_window < max_seq
        and cfg.local_global_period == 0  # every layer must be windowed
    )


def _kv_shape(cfg, batch, max_seq):
    seq = cfg.sliding_window if use_ring(cfg, max_seq) else max_seq
    return (batch, seq, cfg.num_kv_heads, cfg.resolved_head_dim)


def cache_max_seq(cfg, cache: Dict) -> int:
    """The cache's sequence capacity, derived per family from its
    canonical leaf — NOT from ``"k" in cache`` chains, which returned 0
    for pure-SSM caches and silently depended on dict key order for
    hybrids (regression-pinned in tests/test_serve.py).  Pure SSM has
    no positional cache: the recurrent state is O(1), so 0 (nothing in
    the SSM path consumes it)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        return cache["k"].shape[2]
    if fam == "moe":
        return cache["k_moe"].shape[2]
    if fam == "hybrid":
        return cache["k"].shape[2]
    if fam == "ssm":
        return 0
    raise ValueError(fam)


def init_cache(cfg, batch: int, max_seq: int, dtype=None) -> PyTree:
    dtype = dtype or jnp.dtype(cfg.dtype)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        L = cfg.num_layers
        return {
            "k": jnp.zeros((L,) + _kv_shape(cfg, batch, max_seq), dtype),
            "v": jnp.zeros((L,) + _kv_shape(cfg, batch, max_seq), dtype),
        }
    if fam == "moe":
        n_super = cfg.num_layers // cfg.moe_every
        cache = {
            "k_moe": jnp.zeros((n_super,) + _kv_shape(cfg, batch, max_seq), dtype),
            "v_moe": jnp.zeros((n_super,) + _kv_shape(cfg, batch, max_seq), dtype),
        }
        if cfg.moe_every == 2:
            cache["k_dense"] = jnp.zeros((n_super,) + _kv_shape(cfg, batch, max_seq), dtype)
            cache["v_dense"] = jnp.zeros((n_super,) + _kv_shape(cfg, batch, max_seq), dtype)
        return cache
    if fam == "ssm":
        return {
            "mamba": jax.vmap(lambda _: mamba2.mamba_init_cache(cfg, batch, dtype))(
                jnp.arange(cfg.num_layers)
            )
        }
    if fam == "hybrid":
        n_shared = cfg.num_layers // cfg.shared_attn_every
        return {
            "mamba": jax.vmap(lambda _: mamba2.mamba_init_cache(cfg, batch, dtype))(
                jnp.arange(cfg.num_layers)
            ),
            "k": jnp.zeros((n_shared,) + _kv_shape(cfg, batch, max_seq), dtype),
            "v": jnp.zeros((n_shared,) + _kv_shape(cfg, batch, max_seq), dtype),
        }
    if fam == "audio":
        L = cfg.num_layers
        return {
            "k": jnp.zeros((L,) + _kv_shape(cfg, batch, max_seq), dtype),
            "v": jnp.zeros((L,) + _kv_shape(cfg, batch, max_seq), dtype),
            # cross-attention K/V precomputed from the (stubbed) encoder
            "ek": jnp.zeros((L,) + _kv_shape(cfg, batch, cfg.encoder_seq), dtype),
            "ev": jnp.zeros((L,) + _kv_shape(cfg, batch, cfg.encoder_seq), dtype),
        }
    raise ValueError(fam)


def _decode_attn(p, h, cfg, ck, cv, pos, *, window, max_seq):
    """h: (B, 1, d). Updates cache in-place; returns (out, ck, cv)."""
    B = h.shape[0]
    q, k, v = attention_qkv(p, h, cfg)  # (B,1,*,hd)
    qpos = pos[None] if pos.ndim == 0 else pos
    if cfg.use_rope:
        cos, sin = rope_tables(qpos, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    S_cache = ck.shape[1]
    ring = (
        isinstance(window, int)
        and cfg.decode_window_slice
        and S_cache == window
    )
    if ring:
        # O(window) ring buffer: slot s holds absolute position
        # pos - ((pos - s) mod window); unwritten slots map to pos+1 so
        # the causal mask drops them.
        slot = pos % window
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        s_idx = jnp.arange(window)
        p_s = pos - ((pos - s_idx) % window)
        kpos_ring = jnp.where(p_s >= 0, p_s, pos + 1)
        out = chunked_attention(
            q, ck, cv,
            q_positions=qpos,
            k_positions=kpos_ring,
            causal=True,
            window=window,
            logit_softcap=cfg.attn_logit_softcap,
            kv_chunk=2048,
        )
        return out.reshape(B, 1, -1) @ p["wo"], ck, cv
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, pos, 0, 0))
    if (
        cfg.decode_window_slice
        and isinstance(window, int)
        and window < max_seq
    ):
        # beyond-paper perf: read ONLY the window from the cache instead
        # of masking the full max_seq (sliding-window decode is O(window))
        start = jnp.clip(pos - window + 1, 0, max_seq - window)
        k_att = jax.lax.dynamic_slice(ck, (0, start, 0, 0),
                                      (ck.shape[0], window) + ck.shape[2:])
        v_att = jax.lax.dynamic_slice(cv, (0, start, 0, 0),
                                      (cv.shape[0], window) + cv.shape[2:])
        kpos_att = start + jnp.arange(window)
        k_valid = None  # every slice position <= pos is valid by construction
    else:
        k_att, v_att = ck, cv
        kpos_att = jnp.arange(max_seq)
        k_valid = pos + 1
    out = chunked_attention(
        q,
        k_att,
        v_att,
        q_positions=qpos,
        k_positions=kpos_att,
        causal=True,
        window=window,
        logit_softcap=cfg.attn_logit_softcap,
        kv_chunk=2048,
        k_valid=k_valid,
    )
    return out.reshape(B, 1, -1) @ p["wo"], ck, cv


def _dense_decode_block(p, x, cfg, ck, cv, pos, layer_idx, max_seq):
    if cfg.local_global_period:
        is_local = (layer_idx % cfg.local_global_period) == 0
        window = jnp.where(is_local, cfg.sliding_window, max_seq + 1)
        window = window  # traced window: mask arithmetic handles it
    elif cfg.sliding_window is not None:
        window = cfg.sliding_window
    else:
        window = None
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    a, ck, cv = _decode_attn(p["attn"], h, cfg, ck, cv, pos, window=window, max_seq=max_seq)
    if cfg.sandwich_norm:
        a = rms_norm(a, p["ln1_post"], cfg.rms_eps)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    m = mlp_block(p["mlp"], h, cfg.mlp_activation)
    if cfg.sandwich_norm:
        m = rms_norm(m, p["ln2_post"], cfg.rms_eps)
    return x + m, ck, cv


def _embed_token(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens[:, None], axis=0)  # (B,1,d)
    if cfg.sandwich_norm:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def serve_step(params, cfg, cache: Dict, tokens, pos):
    """tokens: (B,) int32; pos: scalar int32 — returns (logits (B,V), cache)."""
    fam = cfg.family
    max_seq = cache_max_seq(cfg, cache)
    if fam != "audio":
        x = _embed_token(params, cfg, tokens)

    if fam in ("dense", "vlm"):
        def body(carry, blk):
            xx = carry
            p, ck, cv, idx = blk
            xx, ck, cv = _dense_decode_block(p, xx, cfg, ck, cv, pos, idx, max_seq)
            return xx, (ck, cv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"], jnp.arange(cfg.num_layers))
        )
        cache = {"k": nk, "v": nv}
    elif fam == "moe":
        from repro.models import moe as moe_lib

        n_super = cfg.num_layers // cfg.moe_every

        def body(carry, blk):
            xx = carry
            if cfg.moe_every == 2:
                xx, dk, dv = _dense_decode_block(
                    blk["pd"], xx, cfg, blk["k_dense"], blk["v_dense"], pos, 0, max_seq
                )
            h = rms_norm(xx, blk["pm"]["ln1"], cfg.rms_eps)
            a, mk, mv = _decode_attn(
                blk["pm"]["attn"], h, cfg, blk["k_moe"], blk["v_moe"], pos,
                window=None, max_seq=max_seq,
            )
            xx = xx + a
            h = rms_norm(xx, blk["pm"]["ln2"], cfg.rms_eps)
            m, _ = moe_lib.moe_apply(blk["pm"]["moe"], h, cfg)
            xx = xx + m
            out_cache = {"k_moe": mk, "v_moe": mv}
            if cfg.moe_every == 2:
                out_cache.update({"k_dense": dk, "v_dense": dv})
            return xx, out_cache

        xs = {"pm": params["blocks_moe"], "k_moe": cache["k_moe"], "v_moe": cache["v_moe"]}
        if cfg.moe_every == 2:
            xs.update(
                pd=params["blocks_dense"], k_dense=cache["k_dense"], v_dense=cache["v_dense"]
            )
        x, cache = jax.lax.scan(body, x, xs)
    elif fam == "ssm":
        def body(carry, blk):
            xx = carry
            p, mc = blk
            y, mc = mamba2.mamba_decode_step(p, mc, xx[:, 0, :], cfg)
            return xx + y[:, None, :], mc

        x, mcache = jax.lax.scan(body, x, (params["blocks"], cache["mamba"]))
        cache = {"mamba": mcache}
    elif fam == "hybrid":
        shared = params["shared_attn"]
        k_every = cfg.shared_attn_every
        n_groups = cfg.num_layers // k_every
        grouped_p = jax.tree.map(
            lambda a: a.reshape((n_groups, k_every) + a.shape[1:]), params["blocks"]
        )
        grouped_mc = jax.tree.map(
            lambda a: a.reshape((n_groups, k_every) + a.shape[1:]), cache["mamba"]
        )

        def body(carry, blk):
            xx = carry

            def inner(c, pmc):
                p, mc = pmc
                y, mc = mamba2.mamba_decode_step(p, mc, c[:, 0, :], cfg)
                return c + y[:, None, :], mc

            xx, mc = jax.lax.scan(inner, xx, (blk["p"], blk["mc"]))
            xx, ck, cv = _dense_decode_block(
                shared, xx, cfg, blk["ck"], blk["cv"], pos, 0, max_seq
            )
            return xx, {"mc": mc, "ck": ck, "cv": cv}

        x, out = jax.lax.scan(
            body, x, {"p": grouped_p, "mc": grouped_mc, "ck": cache["k"], "cv": cache["v"]}
        )
        mcache = jax.tree.map(
            lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), out["mc"]
        )
        cache = {"mamba": mcache, "k": out["ck"], "v": out["cv"]}
    elif fam == "audio":
        x = jnp.take(params["embed"], tokens[:, None], axis=0)
        x = x + sinusoidal_embedding(max_seq, cfg.d_model, x.dtype)[pos][None, None]

        def body(carry, blk):
            xx = carry
            p, ck, cv, ek, ev = blk
            xx, ck, cv = _dense_decode_block(p, xx, cfg, ck, cv, pos, 0, max_seq)
            # cross attention against precomputed encoder K/V
            h = rms_norm(xx, p["lnx"], cfg.rms_eps)
            q, _, _ = attention_qkv(p["xattn"], h, cfg)
            a = chunked_attention(
                q, ek, ev,
                q_positions=pos[None] if pos.ndim == 0 else pos,
                k_positions=jnp.arange(ek.shape[1]),
                causal=False,
            )
            B = h.shape[0]
            xx = xx + a.reshape(B, 1, -1) @ p["xattn"]["wo"]
            return xx, (ck, cv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"], cache["ek"], cache["ev"])
        )
        cache = {"k": nk, "v": nv, "ek": cache["ek"], "ev": cache["ev"]}
    else:
        raise ValueError(fam)

    h = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = (h[:, 0, :] @ _head_weight(params, cfg)).astype(jnp.float32)
    return softcap(logits, cfg.final_logit_softcap), cache
