"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block in JAX.

Train/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear recurrence across chunks); decode is the O(1)-state recurrent
update.  ``ssd_chunked`` doubles as the numerical oracle for the Pallas
``ssd_scan`` kernel (see repro/kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    x:  (b, s, h, p)   per-head inputs
    dt: (b, s, h)      discretization steps (post-softplus)
    A:  (h,)           negative decay rates
    Bm: (b, s, n)      input projections (ngroups=1, shared across heads)
    Cm: (b, s, n)      output projections
    h0: optional initial state (b, h, p, n)
    Returns (y, h_final): y (b, s, h, p), h_final (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    dA = dtc * A[None, None, None, :]  # (b, nc, l, h) log-decays (<=0)
    cs = jnp.cumsum(dA, axis=2)  # cumulative log decay within chunk

    # ---- intra-chunk (quadratic in `chunk`) -----------------------------
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b, nc, l, l)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask the exponent BEFORE exp: the j>i entries would otherwise be
    # exp(positive) -> inf and poison the backward pass via inf*0.
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (b,nc,i,j,h)
    diff = jnp.where(causal[None, None, :, :, None], diff, -jnp.inf)
    M = CB[..., None] * jnp.exp(diff)
    xbar = xc * dtc[..., None]  # (b, nc, l, h, p)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xbar)

    # ---- chunk-final states ---------------------------------------------
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # (b, nc, l, h)
    states = jnp.einsum("bclh,bclhp,bcln->bchpn", decay_to_end * dtc, xc, Bc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (b, nc, h)

    # ---- inter-chunk recurrence (f32 carry) -------------------------------
    def scan_body(carry, inp):
        st, cd = inp  # states (b,h,p,n), chunk_decay (b,h)
        prev = carry
        new = cd[:, :, None, None].astype(jnp.float32) * prev + st.astype(jnp.float32)
        return new, prev

    init = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    h_final, h_prevs = jax.lax.scan(
        scan_body,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, h_prevs, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_final


def ssd_reference(x, dt, A, Bm, Cm, h0=None):
    """Naive sequential recurrence (oracle for tests)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    hstate = h0 if h0 is not None else jnp.zeros((b, h, p, n), jnp.float32)

    def body(hstate, t):
        dt_t = dt[:, t]  # (b, h)
        da = jnp.exp(dt_t * A[None, :])  # (b, h)
        x_t = x[:, t]  # (b, h, p)
        B_t = Bm[:, t]  # (b, n)
        C_t = Cm[:, t]
        hstate = da[:, :, None, None] * hstate + (
            (dt_t[:, :, None] * x_t)[..., None] * B_t[:, None, None, :]
        )
        y_t = jnp.einsum("bhpn,bn->bhp", hstate, C_t)
        return hstate, y_t

    hstate, ys = jax.lax.scan(body, hstate.astype(jnp.float32), jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hstate.astype(x.dtype)


def ssd_decode_step(hstate, x_t, dt_t, A, B_t, C_t, D):
    """One-token recurrent update.  hstate: (b, h, p, n)."""
    da = jnp.exp(dt_t * A[None, :])
    hstate = da[:, :, None, None] * hstate + (
        (dt_t[:, :, None] * x_t)[..., None] * B_t[:, None, None, :]
    )
    y = jnp.einsum("bhpn,bn->bhp", hstate, C_t) + D[None, :, None] * x_t
    return y, hstate


# ---------------------------------------------------------------------------
# Mamba2 block (projections + conv + SSD + gated norm)
# ---------------------------------------------------------------------------


def init_mamba_block(key, cfg, dtype):
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * ds
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * ds + nh), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[3], (di, d), dtype),
        "ln": jnp.zeros((d,), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, C); w: (k, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _split_proj(zxbcdt, cfg):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * ds]
    dt = zxbcdt[..., 2 * di + 2 * ds :]
    return z, xBC, dt


def mamba_block(p, x, cfg, *, ssd_impl=None):
    """Train/prefill forward. x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    zxbcdt = h @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :di].reshape(B, S, nh, hp)
    Bm = xBC[..., di : di + ds]
    Cm = xBC[..., di + ds :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    run = ssd_impl or (lambda *a: ssd_chunked(*a, chunk=min(cfg.ssm_chunk, S)))
    y, _ = run(xs, dt, A, Bm, Cm)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    return y @ p["out_proj"]


def mamba_init_cache(cfg, batch: int, dtype):
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di + 2 * ds), dtype),
        "ssm": jnp.zeros((batch, nh, hp, ds), jnp.float32),
    }


def mamba_decode_step(p, cache, x_t, cfg):
    """x_t: (B, d) one token -> (y, cache)."""
    B, d = x_t.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rms_norm(x_t, p["ln"], cfg.rms_eps)
    zxbcdt = h @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    # conv over (cached k-1 inputs, current)
    hist = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B,k,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    xBC_c = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:, :]
    xs = xBC_c[..., :di].reshape(B, nh, hp)
    Bm = xBC_c[..., di : di + ds]
    Cm = xBC_c[..., di + ds :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    y, new_ssm = ssd_decode_step(
        cache["ssm"], xs.astype(jnp.float32), dt, A, Bm.astype(jnp.float32),
        Cm.astype(jnp.float32), p["D"]
    )
    y = y.reshape(B, di).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    return y @ p["out_proj"], {"conv": new_conv, "ssm": new_ssm}
