"""Public model API: ``build_model(cfg)`` -> ``Model``.

``Model`` bundles pure functions:
  init(key)                      -> params
  loss(params, batch)            -> scalar (next-token CE + MoE aux)
  logits(params, batch)          -> (B, S, V)   (small models / tests)
  init_cache(batch, max_seq)     -> decode cache
  serve_step(params, cache, tokens, pos) -> (logits (B, V), cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs.base import ModelConfig
from repro.models import decode as _decode
from repro.models import transformer as _tf

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., PyTree]
    loss: Callable[..., Any]
    logits: Callable[..., Any]
    forward_hidden: Callable[..., Any]
    init_cache: Callable[..., PyTree]
    serve_step: Callable[..., Any]


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: _tf.init_params(cfg, key),
        loss=lambda params, batch: _tf.lm_loss(params, cfg, batch),
        logits=lambda params, batch: _tf.logits_full(params, cfg, batch),
        forward_hidden=lambda params, batch: _tf.forward_hidden(params, cfg, batch),
        init_cache=lambda batch, max_seq, dtype=None: _decode.init_cache(
            cfg, batch, max_seq, dtype
        ),
        serve_step=lambda params, cache, tokens, pos: _decode.serve_step(
            params, cfg, cache, tokens, pos
        ),
    )


__all__ = ["Model", "build_model"]
