"""Core transformer layers: norms, RoPE, chunked (flash-style) GQA
attention, MLPs, parameter initializers.

Everything is a pure function over parameter dicts; attention uses an
online-softmax two-level chunking so activation memory is
O(q_chunk x kv_chunk) instead of O(S^2) — required for the 32k shapes.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# initializers / norms
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_tables(positions, head_dim: int, theta: float):
    """positions: (..., S) int -> cos/sin tables (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    )
    return out.astype(dt)


def sinusoidal_embedding(seq: int, dim: int, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    emb = jnp.zeros((seq, dim), dtype=jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb.astype(dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (GQA, causal / sliding-window / cross)
# ---------------------------------------------------------------------------


def _pick_chunk(n: int, target: int) -> int:
    if n <= target:
        return n
    c = target
    while n % c:
        c //= 2
    return max(c, 1)


def chunked_attention(
    q,
    k,
    v,
    *,
    q_positions,
    k_positions,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    k_valid: Optional[int] = None,
    remat: bool = False,
):
    """Flash-style attention in pure JAX.

    q: (B, Sq, nq, hd);  k, v: (B, Sk, nkv, hd);  nq % nkv == 0.
    q_positions: (Sq,) absolute positions of queries.
    k_positions: (Sk,) absolute positions of keys.
    k_valid: scalar or None — keys with index >= k_valid are masked
       (decode caches allocated to max length).
    Returns (B, Sq, nq, hd).
    """
    B, Sq, nq, hd = q.shape
    Sk, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = 1.0 / math.sqrt(hd)

    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Sk, kv_chunk)
    n_q, n_k = Sq // qc, Sk // kc

    # (B, nkv, g, Sq, hd)
    qh = q.reshape(B, Sq, nkv, g, hd).transpose(0, 2, 3, 1, 4) * scale
    kh = k.transpose(0, 2, 1, 3)  # (B, nkv, Sk, hd)
    vh = v.transpose(0, 2, 1, 3)

    qh = qh.reshape(B, nkv, g, n_q, qc, hd)
    kh = kh.reshape(B, nkv, n_k, kc, hd)
    vh = vh.reshape(B, nkv, n_k, kc, hd)
    qpos = q_positions.reshape(n_q, qc)
    kpos = k_positions.reshape(n_k, kc)
    kidx = jnp.arange(Sk).reshape(n_k, kc)

    def q_body(_, qi):
        qblk = qh[:, :, :, qi]  # (B, nkv, g, qc, hd)
        qp = qpos[qi]  # (qc,)

        def kv_body(carry, ki):
            m, l, acc = carry
            kblk = kh[:, :, ki]  # (B, nkv, kc, hd)
            vblk = vh[:, :, ki]
            kp = kpos[ki]  # (kc,)
            s = jnp.einsum(
                "bngqh,bnkh->bngqk", qblk, kblk, preferred_element_type=jnp.float32
            )
            s = softcap(s, logit_softcap)
            mask = jnp.ones((qc, kc), dtype=bool)
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= (qp[:, None] - kp[None, :]) < window
            if k_valid is not None:
                mask &= kidx[ki][None, :] < k_valid
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bnkh->bngqh", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, nkv, g, qc), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, nkv, g, qc), dtype=jnp.float32)
        a0 = jnp.zeros((B, nkv, g, qc, hd), dtype=jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(n_k))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    if remat:
        # flash-attention-style backward: recompute score blocks instead
        # of saving every (qc, kc) p-matrix the kv-scan would stash
        q_body = jax.checkpoint(q_body, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(q_body, None, jnp.arange(n_q))
    # outs: (n_q, B, nkv, g, qc, hd) -> (B, Sq, nq, hd)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, nq, Sq, hd)
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# attention block (projections + rope + attention)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nq * hd), dtype),
        "wk": dense_init(ks[1], (d, nkv * hd), dtype),
        "wv": dense_init(ks[2], (d, nkv * hd), dtype),
        "wo": dense_init(ks[3], (nq * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def attention_qkv(p, x, cfg):
    """Project x -> q, k, v with GQA shapes."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    return q, k, v


def attention_block(
    p,
    x,
    cfg,
    *,
    positions,
    is_local=None,
    kv_override=None,
    causal: bool = True,
):
    """Full attention sublayer for train/prefill.

    is_local: traced bool (gemma2 alternation) — selects sliding window.
    kv_override: (k, v, k_positions) for cross-attention.
    """
    B, S, _ = x.shape
    q, k, v = attention_qkv(p, x, cfg)
    if kv_override is not None:
        k, v, kpos = kv_override
    else:
        kpos = positions
        if cfg.use_rope:
            cos, sin = rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)

    def run(window):
        return chunked_attention(
            q,
            k,
            v,
            q_positions=positions,
            k_positions=kpos,
            causal=causal,
            window=window,
            logit_softcap=cfg.attn_logit_softcap,
            remat=cfg.attn_remat,
        )

    if cfg.sliding_window is None:
        out = run(None)
    elif is_local is None:
        # homogeneous stacks with a window configured (e.g. zamba2 shared
        # block / gemma2 long-context serving) use the window everywhere.
        out = run(cfg.sliding_window)
    else:
        # traced gemma2 local/global alternation.
        out = jax.lax.cond(
            is_local,
            lambda: run(cfg.sliding_window),
            lambda: run(None),
        )
    B_, S_, nq, hd = out.shape
    return out.reshape(B_, S_, nq * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, ff: int, activation: str, dtype):
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "wi": dense_init(ks[0], (d, ff), dtype),
            "wg": dense_init(ks[1], (d, ff), dtype),
            "wo": dense_init(ks[2], (ff, d), dtype),
        }
    return {
        "wi": dense_init(ks[0], (d, ff), dtype),
        "wo": dense_init(ks[2], (ff, d), dtype),
    }


def mlp_block(p, x, activation: str):
    if activation == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
