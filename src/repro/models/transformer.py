"""Model stacks for all assigned architecture families.

All stacks scan over stacked per-layer parameters (compile-time O(1) in
depth) with ``jax.checkpoint`` on the layer body (activation remat).

Families:
  dense / vlm  — decoder-only GQA transformer (vlm prepends stubbed
                 patch embeddings)
  moe          — interleaved dense/MoE superblocks (moe_every in {1,2})
  ssm          — Mamba2 (SSD) stack
  hybrid       — Mamba2 stack + weight-tied shared attention block
  audio        — whisper-style encoder-decoder (frames stubbed)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import mamba2, moe as moe_lib
from repro.models.layers import (
    attention_block,
    attention_qkv,
    apply_rope,
    chunked_attention,
    dense_init,
    init_attention,
    init_mlp,
    mlp_block,
    rms_norm,
    rope_tables,
    sinusoidal_embedding,
    softcap,
)

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _stacked_init(fn: Callable, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


# ===========================================================================
# parameter init
# ===========================================================================


def _init_dense_layer(cfg, dtype):
    def init_one(key):
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(k1, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype),
        }
        if cfg.sandwich_norm:
            p["ln1_post"] = jnp.zeros((cfg.d_model,), dtype)
            p["ln2_post"] = jnp.zeros((cfg.d_model,), dtype)
        return p

    return init_one


def _init_moe_layer(cfg, dtype):
    def init_one(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(k1, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "moe": moe_lib.init_moe(k2, cfg, dtype),
        }

    return init_one


def init_params(cfg, key) -> PyTree:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, PyTree] = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size), dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = _stacked_init(_init_dense_layer(cfg, dtype), keys[2], cfg.num_layers)
    elif fam == "moe":
        assert cfg.moe_every in (1, 2), "moe_every in {1,2} supported"
        n_super = cfg.num_layers // cfg.moe_every
        if cfg.moe_every == 2:
            params["blocks_dense"] = _stacked_init(_init_dense_layer(cfg, dtype), keys[2], n_super)
        params["blocks_moe"] = _stacked_init(_init_moe_layer(cfg, dtype), keys[3], n_super)
    elif fam == "ssm":
        params["blocks"] = _stacked_init(
            lambda k: mamba2.init_mamba_block(k, cfg, dtype), keys[2], cfg.num_layers
        )
    elif fam == "hybrid":
        params["blocks"] = _stacked_init(
            lambda k: mamba2.init_mamba_block(k, cfg, dtype), keys[2], cfg.num_layers
        )
        params["shared_attn"] = _init_dense_layer(cfg, dtype)(keys[3])
    elif fam == "audio":
        params["encoder"] = _stacked_init(_init_dense_layer(cfg, dtype), keys[2], cfg.num_encoder_layers)
        params["enc_final_norm"] = jnp.zeros((cfg.d_model,), dtype)

        def init_dec(key):
            k1, k2, k3 = jax.random.split(key, 3)
            return {
                "ln1": jnp.zeros((cfg.d_model,), dtype),
                "attn": init_attention(k1, cfg, dtype),
                "lnx": jnp.zeros((cfg.d_model,), dtype),
                "xattn": init_attention(k2, cfg, dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype),
            }

        params["blocks"] = _stacked_init(init_dec, keys[3], cfg.num_layers)
    else:
        raise ValueError(fam)
    return params


# ===========================================================================
# forward (train / prefill)
# ===========================================================================


def _dense_block_apply(p, x, cfg, positions, layer_idx, kv_override=None, causal=True):
    if cfg.local_global_period:
        is_local = (layer_idx % cfg.local_global_period) == 0
    else:
        is_local = None
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    a = attention_block(
        p["attn"], h, cfg, positions=positions, is_local=is_local,
        kv_override=kv_override, causal=causal,
    )
    if cfg.sandwich_norm:
        a = rms_norm(a, p["ln1_post"], cfg.rms_eps)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    m = mlp_block(p["mlp"], h, cfg.mlp_activation)
    if cfg.sandwich_norm:
        m = rms_norm(m, p["ln2_post"], cfg.rms_eps)
    return x + m


def _moe_block_apply(p, x, cfg, positions, layer_idx):
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    a = attention_block(p["attn"], h, cfg, positions=positions)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    m, aux = moe_lib.moe_apply(p["moe"], h, cfg)
    return x + m, aux


def _embed_inputs(params, cfg, batch):
    """Returns (x, positions, label_offset)."""
    dtype = _dtype(cfg)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.sandwich_norm:  # gemma-style embedding scale
        x = x * jnp.asarray(cfg.d_model**0.5, dtype)
    offset = 0
    if cfg.family == "vlm" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
        offset = batch["patches"].shape[1]
    S = x.shape[1]
    positions = jnp.arange(S)
    return x, positions, offset


def forward_hidden(params, cfg, batch):
    """Returns (hidden (B, S, d), moe_aux_loss scalar)."""
    fam = cfg.family
    aux = jnp.float32(0.0)
    if fam == "audio":
        return _audio_forward_hidden(params, cfg, batch), aux

    x, positions, _ = _embed_inputs(params, cfg, batch)

    if fam in ("dense", "vlm"):
        def body(carry, blk):
            xx = carry
            p, idx = blk
            xx = _dense_block_apply(p, xx, cfg, positions, idx)
            return xx, None

        body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (params["blocks"], jnp.arange(cfg.num_layers)))
    elif fam == "moe":
        n_super = cfg.num_layers // cfg.moe_every

        def body(carry, blk):
            xx, aux_c = carry
            idx = blk["idx"]
            if cfg.moe_every == 2:
                xx = _dense_block_apply(blk["dense"], xx, cfg, positions, 2 * idx)
            xx, a = _moe_block_apply(blk["moe"], xx, cfg, positions, idx)
            return (xx, aux_c + a), None

        xs = {"moe": params["blocks_moe"], "idx": jnp.arange(n_super)}
        if cfg.moe_every == 2:
            xs["dense"] = params["blocks_dense"]
        body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), xs)
    elif fam == "ssm":
        def body(carry, blk):
            xx = carry
            xx = xx + mamba2.mamba_block(blk, xx, cfg)
            return xx, None

        body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    elif fam == "hybrid":
        # scan over groups of `shared_attn_every` mamba layers, each
        # followed by the weight-tied shared attention block.
        shared = params["shared_attn"]
        k_every = cfg.shared_attn_every
        assert cfg.num_layers % k_every == 0
        n_groups = cfg.num_layers // k_every
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, k_every) + a.shape[1:]), params["blocks"]
        )

        def group_body(carry, gp):
            xx = carry

            def inner(c, p):
                return c + mamba2.mamba_block(p, c, cfg), None

            xx, _ = jax.lax.scan(inner, xx, gp)
            xx = _dense_block_apply(shared, xx, cfg, positions, 0)
            return xx, None

        group_body = jax.checkpoint(group_body)
        x, _ = jax.lax.scan(group_body, x, grouped)
    else:
        raise ValueError(fam)

    return rms_norm(x, params["final_norm"], cfg.rms_eps), aux


def _audio_forward_hidden(params, cfg, batch):
    dtype = _dtype(cfg)
    frames = batch["frames"].astype(dtype)  # (B, F, d) stubbed embeddings
    B, F, d = frames.shape
    enc = frames + sinusoidal_embedding(F, d, dtype)[None]
    enc_pos = jnp.arange(F)

    def enc_body(carry, blk):
        xx = _dense_block_apply(blk, carry, cfg, enc_pos, 0, causal=False)
        return xx, None

    enc, _ = jax.lax.scan(jax.checkpoint(enc_body), enc, params["encoder"])
    enc = rms_norm(enc, params["enc_final_norm"], cfg.rms_eps)

    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoidal_embedding(S, d, dtype)[None]
    positions = jnp.arange(S)

    def dec_body(carry, blk):
        xx = carry
        xx = _dense_block_apply(blk, xx, cfg, positions, 0)
        # cross attention
        h = rms_norm(xx, blk["lnx"], cfg.rms_eps)
        _, ek, ev = attention_qkv(blk["xattn"], enc, cfg)
        a = attention_block(
            blk["xattn"], h, cfg, positions=positions,
            kv_override=(ek, ev, enc_pos), causal=False,
        )
        return xx + a, None

    x, _ = jax.lax.scan(jax.checkpoint(dec_body), x, params["blocks"])
    return rms_norm(x, params["final_norm"], cfg.rms_eps)


# ===========================================================================
# loss (chunked vocab projection)
# ===========================================================================


def _head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def lm_loss(params, cfg, batch, *, chunk: int = 512):
    """Mean next-token cross entropy (labels == -1 are masked)."""
    hidden, aux = forward_hidden(params, cfg, batch)
    labels = batch["labels"]
    if cfg.family == "vlm" and "patches" in batch:
        hidden = hidden[:, batch["patches"].shape[1] :]
    B, S, d = hidden.shape
    head = _head_weight(params, cfg)
    c = min(chunk, S)
    while S % c:
        c //= 2
    n = S // c

    def body(carry, i):
        tot, cnt = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, i * c, c, axis=1)
        l = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        logits = (h @ head).astype(jnp.float32)
        logits = softcap(logits, cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1
        )[..., 0]
        mask = (l >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), jnp.arange(n))
    loss = tot / jnp.maximum(cnt, 1.0)
    if cfg.family == "moe":
        loss = loss + cfg.router_aux_coef * aux
    return loss


def logits_full(params, cfg, batch):
    """Full (B, S, V) logits — small models / tests only."""
    hidden, _ = forward_hidden(params, cfg, batch)
    if cfg.family == "vlm" and "patches" in batch:
        hidden = hidden[:, batch["patches"].shape[1] :]
    logits = (hidden @ _head_weight(params, cfg)).astype(jnp.float32)
    return softcap(logits, cfg.final_logit_softcap)
