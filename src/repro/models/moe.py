"""Mixture-of-experts layer: top-k router, sort-based capacity dispatch,
shared experts, Switch-style load-balance auxiliary loss.

Dispatch strategy (TPU-friendly): flatten tokens, argsort by expert id,
scatter into an (E, C, d) buffer, one batched einsum per FFN matrix,
gather back.  With experts sharded over the expert axis the scatter /
gather become the all-to-all of classic expert parallelism.
"""
from __future__ import annotations

import contextvars
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import dense_init

# Beyond-paper perf knob (EXPERIMENTS.md §Perf): an explicit sharding
# for the (E, cap, d) dispatch buffer.  Without it XLA's propagation may
# replicate the buffer and all-reduce expert gradients over the expert
# axis; constraining it to the expert axis turns dispatch into the
# canonical all-to-all of expert parallelism.
_EXPERT_BUFFER_SHARDING: contextvars.ContextVar[Optional[object]] = contextvars.ContextVar(
    "expert_buffer_sharding", default=None
)
_TOKEN_SHARDING: contextvars.ContextVar[Optional[object]] = contextvars.ContextVar(
    "moe_token_sharding", default=None
)


def set_expert_buffer_sharding(sharding, token_sharding=None) -> None:
    """sharding: jax.NamedSharding for the (E, cap, d) dispatch buffer;
    token_sharding: NamedSharding for the (B, S, d) combined output.
    Constraining the combine output to stay token-sharded turns the
    naive full-buffer all-reduce into a reduce-scatter-shaped exchange.
    """
    _EXPERT_BUFFER_SHARDING.set(sharding)
    _TOKEN_SHARDING.set(token_sharding)


def _constrain(buf):
    sh = _EXPERT_BUFFER_SHARDING.get()
    if sh is not None:
        return jax.lax.with_sharding_constraint(buf, sh)
    return buf


def _constrain_tokens(y):
    sh = _TOKEN_SHARDING.get()
    if sh is not None:
        return jax.lax.with_sharding_constraint(y, sh)
    return y


# Expert-parallel dispatch via shard_map + all_to_all (EXPERIMENTS.md
# §Perf B).  When set, moe_apply routes through moe_apply_ep: tokens are
# dispatched into per-source-shard buffers and exchanged with the expert
# owners point-to-point instead of XLA's gather → mask → all-reduce.
_EP_CONTEXT: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "moe_ep_context", default=None
)


def set_ep_context(mesh=None, data_axis: str = "data") -> None:
    if mesh is None:
        _EP_CONTEXT.set(None)
    else:
        _EP_CONTEXT.set({"mesh": mesh, "axis": data_axis})


def init_moe(key, cfg, dtype):
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wi": dense_init(ks[1], (E, d, ff), dtype),
        "wg": dense_init(ks[2], (E, d, ff), dtype),
        "wo": dense_init(ks[3], (E, ff, d), dtype),
    }
    if cfg.num_shared_experts:
        sff = ff * cfg.num_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(ks2[0], (d, sff), dtype),
            "wg": dense_init(ks2[1], (d, sff), dtype),
            "wo": dense_init(ks2[2], (sff, d), dtype),
        }
    return p


def _dispatch_local(xt, idx, gates, E: int, cap: int):
    """Sort-based dispatch into an (E, cap, d) buffer (local tokens).

    Returns (buf, s_tok, eid_c, pos_c, keep, s_gate)."""
    T, d = xt.shape
    k = idx.shape[-1]
    flat_eid = idx.reshape(T * k)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    flat_gate = gates.reshape(T * k)
    order = jnp.argsort(flat_eid, stable=True)
    s_eid = flat_eid[order]
    s_tok = flat_tok[order]
    s_gate = flat_gate[order]
    counts = jnp.bincount(flat_eid, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[s_eid]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    eid_c = jnp.where(keep, s_eid, 0)
    buf = jnp.zeros((E, cap, d), xt.dtype)
    buf = buf.at[eid_c, pos_c].add(jnp.where(keep[:, None], xt[s_tok], 0).astype(xt.dtype))
    return buf, s_tok, eid_c, pos_c, keep, s_gate


def moe_apply_ep(p, x, cfg, mesh, data_axis: str = "data", *, capacity_factor: float = 1.25):
    """Expert-parallel MoE: shard_map over the expert/data axis.

    Inside each shard: route the LOCAL tokens, build an (E, cap_l, d)
    buffer, all_to_all the expert dim to the owning shards, run the
    local experts, all_to_all back, combine locally.  The only
    cross-device traffic is the two all_to_alls (+ a pmean for the aux
    loss) — no full-token-buffer all-reduce.
    """
    from jax.sharding import PartitionSpec as P

    E, k = cfg.num_experts, cfg.num_experts_per_tok
    n_sh = mesh.shape[data_axis]
    assert E % n_sh == 0

    def shard_fn(p_l, x_l):
        Bl, S, d = x_l.shape
        T = Bl * S
        xt = x_l.reshape(T, d)
        logits = xt.astype(jnp.float32) @ p_l["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        me = jax.lax.pmean(probs.mean(axis=0), data_axis)
        ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
        ce = jax.lax.pmean(ce, data_axis)
        aux = E * jnp.sum(me * ce)

        cap = int(max(1, (T * k * capacity_factor) // E))
        buf, s_tok, eid_c, pos_c, keep, s_gate = _dispatch_local(xt, idx, gates, E, cap)

        # exchange with expert owners: (E, cap, d) -> (E/n, n*cap, d)
        buf_x = jax.lax.all_to_all(buf, data_axis, split_axis=0, concat_axis=1, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf_x, p_l["wi"])
        hg = jnp.einsum("ecd,edf->ecf", buf_x, p_l["wg"])
        out_x = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * h, p_l["wo"])
        # send results back: (E/n, n*cap, d) -> (E, cap, d)
        out_buf = jax.lax.all_to_all(out_x, data_axis, split_axis=1, concat_axis=0, tiled=True)

        gate_c = jnp.where(keep, s_gate, 0.0).astype(x_l.dtype)
        y_slots = out_buf[eid_c, pos_c] * gate_c[:, None]
        y = jnp.zeros((T, d), x_l.dtype).at[s_tok].add(y_slots)
        # aux emitted per shard (identical values); avoids an
        # unproven-replicated scalar output that trips XLA:CPU's
        # AllReducePromotion pass
        return y.reshape(Bl, S, d), aux[None]

    p_specs = {
        "router": P(),
        "wi": P(data_axis, None, None),
        "wg": P(data_axis, None, None),
        "wo": P(data_axis, None, None),
    }
    p_routed = {k: v for k, v in p.items() if k in p_specs}
    y, aux = compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(p_specs, P(data_axis)),
        out_specs=(P(data_axis), P(data_axis)),
        axis_names={data_axis},
        check_vma=False,
    )(p_routed, x)
    if cfg.num_shared_experts:
        # shared experts run outside the manual region so their
        # model-axis psum stays in XLA's auto-sharded (promotable) path
        B, S, d = x.shape
        xt = x.reshape(B * S, d)
        sp = p["shared"]
        y = y + ((jax.nn.silu(xt @ sp["wg"]) * (xt @ sp["wi"])) @ sp["wo"]).reshape(B, S, d)
    return y, aux.mean()


def moe_apply(p, x, cfg, *, capacity_factor: float = 1.25):
    """x: (B, S, d) -> (y, aux_loss)."""
    ep = _EP_CONTEXT.get()
    if ep is not None and cfg.num_experts % ep["mesh"].shape[ep["axis"]] == 0:
        return moe_apply_ep(p, x, cfg, ep["mesh"], ep["axis"],
                            capacity_factor=capacity_factor)
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32)) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch load-balance aux loss: E * sum_e f_e * p_e
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch -------------------------------------------
    # small token counts (decode steps, smoke tests) get a drop-free
    # capacity; large batches use the standard capacity factor.
    if T * k <= 1024:
        cap = T * k
    else:
        cap = int(max(1, (T * k * capacity_factor) // E))
    flat_eid = idx.reshape(T * k)  # expert of each slot
    flat_tok = jnp.repeat(jnp.arange(T), k)
    flat_gate = gates.reshape(T * k)

    order = jnp.argsort(flat_eid, stable=True)
    s_eid = flat_eid[order]
    s_tok = flat_tok[order]
    s_gate = flat_gate[order]

    counts = jnp.bincount(flat_eid, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[s_eid]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    eid_c = jnp.where(keep, s_eid, 0)

    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[eid_c, pos_c].add(
        jnp.where(keep[:, None], xt[s_tok], 0).astype(x.dtype)
    )
    buf = _constrain(buf)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    hg = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    out_buf = _constrain(jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * h, p["wo"]))

    # combine in the compute dtype (a f32 gate would promote the whole
    # (T, d) combine buffer to f32 — 2x the collective bytes)
    gate_c = jnp.where(keep, s_gate, 0.0).astype(x.dtype)
    y_slots = out_buf[eid_c, pos_c] * gate_c[:, None]
    y = jnp.zeros((T, d), x.dtype).at[s_tok].add(y_slots)

    if cfg.num_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(xt @ sp["wg"]) * (xt @ sp["wi"])) @ sp["wo"]

    return _constrain_tokens(y.reshape(B, S, d)), aux
