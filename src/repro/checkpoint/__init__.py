"""Checkpointing: pytree <-> .npz with a msgpack sidecar for structure
and metadata (step, config fingerprint).  No orbax in the container.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

PyTree = Any


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(np.asarray(leaf))
    return names, leaves, treedef


def config_fingerprint(cfg) -> str:
    try:
        import dataclasses

        blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    except TypeError:
        blob = repr(cfg)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _to_native(arr: np.ndarray) -> np.ndarray:
    """npz cannot store ml_dtypes (bf16 etc.) — store as f32."""
    if arr.dtype.kind not in "biufc":
        return arr.astype(np.float32)
    return arr


def save(path: str, tree: PyTree, *, step: int = 0, meta: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    np.savez(path + ".npz", **{f"leaf_{i}": _to_native(l) for i, l in enumerate(leaves)})
    sidecar = {
        "names": names,
        "step": int(step),
        "meta": meta or {},
        "dtypes": [str(l.dtype) for l in leaves],
        "shapes": [list(l.shape) for l in leaves],
    }
    with open(path + ".msgpack", "wb") as f:
        f.write(msgpack.packb(sidecar))


def restore(path: str, like: PyTree) -> Tuple[PyTree, int, Dict]:
    """Restores into the structure of ``like`` (names must match)."""
    with open(path + ".msgpack", "rb") as f:
        sidecar = msgpack.unpackb(f.read())
    data = np.load(path + ".npz")
    names_disk = sidecar["names"]
    names_like, leaves_like, treedef = _flatten_with_names(like)
    if names_disk != names_like:
        missing = set(names_disk) ^ set(names_like)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]} ...")
    leaves = [
        np.asarray(data[f"leaf_{i}"], dtype=leaves_like[i].dtype)
        for i in range(len(names_like))
    ]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, sidecar["step"], sidecar["meta"]
