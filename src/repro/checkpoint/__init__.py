"""Checkpointing: pytree <-> .npz with a msgpack sidecar for structure
and metadata (step, config fingerprint).  No orbax in the container.

``save_state`` / ``restore_state`` round-trip a full ``HDOState``
(params + the generalized optimizer state + step counter), so a
restored run continues bit-identically to an uninterrupted one
(tests/test_localupdate.py); ``save`` / ``restore`` remain the raw
pytree primitives.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(np.asarray(leaf))
    return names, leaves, treedef


def config_fingerprint(cfg) -> str:
    try:
        import dataclasses

        blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    except TypeError:
        blob = repr(cfg)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _to_native(arr: np.ndarray) -> np.ndarray:
    """npz cannot store ml_dtypes (bf16 etc.) — store as f32."""
    if arr.dtype.kind not in "biufc":
        return arr.astype(np.float32)
    return arr


def save(path: str, tree: PyTree, *, step: int = 0, meta: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    # write-then-rename so a crash mid-save (OOM, preemption) can never
    # truncate the previous checkpoint in place, plus a shared random
    # token in both files so a crash BETWEEN the two renames (new npz,
    # stale sidecar) is detected at restore instead of silently pairing
    # round-N params with a round-M step counter
    token = os.urandom(8).hex()
    tmp = path + ".tmp.npz"  # np.savez appends .npz unless already there
    np.savez(tmp, __token__=np.frombuffer(bytes.fromhex(token), np.uint8),
             **{f"leaf_{i}": _to_native(l) for i, l in enumerate(leaves)})
    os.replace(tmp, path + ".npz")
    sidecar = {
        "names": names,
        "step": int(step),
        "meta": meta or {},
        "dtypes": [str(l.dtype) for l in leaves],
        "shapes": [list(l.shape) for l in leaves],
        "token": token,
    }
    tmp = path + ".msgpack.tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(sidecar))
    os.replace(tmp, path + ".msgpack")


def restore(path: str, like: PyTree) -> Tuple[PyTree, int, Dict]:
    """Restores into the structure of ``like`` (names must match)."""
    with open(path + ".msgpack", "rb") as f:
        sidecar = msgpack.unpackb(f.read())
    data = np.load(path + ".npz")
    if "token" in sidecar and "__token__" in data:
        disk_token = bytes(np.asarray(data["__token__"])).hex()
        if disk_token != sidecar["token"]:
            raise ValueError(
                f"torn checkpoint at {path!r}: the .npz and .msgpack sidecar "
                "come from different saves (crash between the two renames?) "
                "— params and step counter would silently disagree"
            )
    names_disk = sidecar["names"]
    names_like, leaves_like, treedef = _flatten_with_names(like)
    if names_disk != names_like:
        missing = set(names_disk) ^ set(names_like)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]} ...")
    want_dtypes = [str(l.dtype) for l in leaves_like]
    if sidecar.get("dtypes") and sidecar["dtypes"] != want_dtypes:
        bad = [f"{n}: {d} -> {w}" for n, d, w in
               zip(names_like, sidecar["dtypes"], want_dtypes) if d != w]
        raise ValueError(
            f"checkpoint dtype mismatch (silent cast would break the "
            f"resume-bit-identity contract): {bad[:5]}"
        )
    leaves = [
        np.asarray(data[f"leaf_{i}"], dtype=leaves_like[i].dtype)
        for i in range(len(names_like))
    ]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, sidecar["step"], sidecar["meta"]


def read_meta(path: str) -> Dict:
    """Read ONLY the sidecar metadata of a checkpoint (no array load).

    The pre-restore guard: ``train.py --resume`` checks the stored
    ``param_layout`` / ``manifest_hash`` against the current run BEFORE
    building templates and loading arrays, so a layout or model-shape
    drift fails with a clear message instead of a structure/shape
    mismatch deep inside ``restore``.
    """
    with open(path + ".msgpack", "rb") as f:
        sidecar = msgpack.unpackb(f.read())
    return sidecar.get("meta", {}) or {}


def check_meta_compat(meta: Dict, *, param_layout: Optional[str] = None,
                      manifest_hash: Optional[str] = None) -> None:
    """Raise ValueError when checkpoint meta disagrees with the run.

    Only keys present on BOTH sides are compared, so checkpoints written
    before these fields existed restore as before (the structure/dtype
    validation in ``restore`` still backstops them).
    """
    saved_layout = meta.get("param_layout")
    if param_layout is not None and saved_layout is not None \
            and saved_layout != param_layout:
        raise ValueError(
            f"checkpoint was written with param_layout={saved_layout!r} but "
            f"this run uses param_layout={param_layout!r} — the state "
            "layouts are incompatible (plane buffers vs stacked pytree); "
            "rerun with the matching --param-layout or start fresh"
        )
    saved_hash = meta.get("manifest_hash")
    if manifest_hash is not None and saved_hash is not None \
            and saved_hash != manifest_hash:
        raise ValueError(
            f"checkpoint leaf-manifest hash {saved_hash} does not match this "
            f"run's {manifest_hash} — the model's leaf set, shapes, or "
            "dtypes changed since the checkpoint was written (see "
            "core.plane.manifest_hash); restore would produce garbage "
            "offsets, so start fresh or restore under the original model"
        )


def save_state(path: str, state, *, meta: Optional[Dict] = None) -> None:
    """Persist a full ``core.hdo.HDOState`` (params, opt_state, step,
    and the gossip communication state — error-feedback residuals /
    stale-broadcast buffers; an empty ``comm`` contributes no leaves, so
    plain configs produce the exact pre-comm checkpoint structure)."""
    tree = {"params": state.params, "opt_state": state.opt_state,
            "comm": state.comm}
    save(path, jax.device_get(tree), step=int(state.step), meta=meta)


def restore_state(path: str, like) -> Tuple[Any, Dict]:
    """Restore an ``HDOState`` saved by ``save_state``.

    ``like`` is a template state with the target structure/dtypes —
    build it with ``core.init_state`` under the SAME ``HDOConfig``
    (optimizer / momentum / momentum_dtype decide the opt_state
    structure; compression / staleness / fault knobs decide the comm
    structure).  Returns ``(state, meta)``.
    """
    from repro.core.hdo import HDOState

    tree, step, meta = restore(
        path, {"params": like.params, "opt_state": like.opt_state,
               "comm": like.comm}
    )
    state = HDOState(
        params=tree["params"], opt_state=tree["opt_state"],
        step=jnp.int32(step), comm=tree["comm"]
    )
    return state, meta
