"""Minimal pure-JAX optimizer substrate (no optax in the container).

Transforms follow the (init, update) convention:
    opt = sgd(momentum=0.9)
    opt_state = opt.init(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    params = apply_updates(params, updates, lr)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]


def _f32(t: PyTree) -> PyTree:
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params):
        g = _f32(grads)
        if momentum == 0.0:
            return g, state
        new_state = jax.tree.map(lambda m, gi: momentum * m + (1.0 - momentum) * gi, state, g)
        return new_state, new_state

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": z, "nu": jax.tree.map(jnp.copy, z), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        g = _f32(grads)
        c = state["count"] + 1
        mu = jax.tree.map(lambda m, gi: b1 * m + (1 - b1) * gi, state["mu"], g)
        nu = jax.tree.map(lambda v, gi: b2 * v + (1 - b2) * gi * gi, state["nu"], g)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v, p: m / bc1 / (jnp.sqrt(v / bc2) + eps)
            + weight_decay * p.astype(jnp.float32),
            mu,
            nu,
            params,
        )
        return upd, {"mu": mu, "nu": nu, "count": c}

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree, lr) -> PyTree:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype), params, updates
    )


def global_norm(tree: PyTree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree)
