"""Fenced per-phase wall-clock for the HDO round.

``launch/train.py`` used to report one ``wall_s`` that (a) included
compile time and (b) said nothing about WHERE a round spends its time.
This module splits the round honestly:

  * ``build_phase_fns`` rebuilds the fused step's three phases —
    estimate, local update, mix — as separately-jittable calls **from
    the same builders** ``build_hdo_step`` composes
    (``build_estimate_phase`` / ``make_local_update`` / ``make_mixer``)
    with the identical PRNG-key and nu/lr derivations, so
    ``phase_round`` (estimate -> update -> mix, three dispatches) is
    bit-identical to one fused ``step()`` call on the same state —
    pinned by tests/test_obs.py, which is what makes the per-phase
    numbers an honest decomposition rather than a lookalike.

  * ``PhaseTimer`` measures sampled rounds with ``block_until_ready``
    fences around each phase call: ``phase_ms_{estimate,update,mix}``,
    their sum, the fused round on the same state (``step_ms_fused``),
    and the compile-vs-steady-state split (``phase_compile_ms_*`` on
    the first sample only).  Phase calls run on the *pre-round* state
    and their outputs are discarded, so sampling never perturbs the
    training trajectory.

  * ``analytic_phase_bytes`` prices the update/mix phases with the
    same analytic HBM-traffic model ``benchmarks/kernel_bench.py``
    quotes for the fused kernels, so the timer can derive achieved
    HBM GB/s (``hbm_gbps_update`` / ``hbm_gbps_mix``) next to the
    fenced times.  (The estimate phase has no clean closed form — its
    traffic depends on the model's activation footprint — so it
    deliberately gets no GB/s number rather than a made-up one.)

Restrictions: ``local_steps == 1`` only (H > 1 interleaves H
estimate+update pairs inside one ``lax.scan`` — there is no three-call
decomposition of that round; callers should skip sampling).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import host_annotation

PyTree = Any


class PhaseFns(NamedTuple):
    """The three separately-jittable phase calls of one HDO round.

    ``estimate(state, batches) -> (losses, g)``;
    ``update(state, g) -> (new_params, new_opt_state)``;
    ``mix(state, new_params) -> (mixed_params, new_comm)``.
    All three read the round index from ``state.step``, deriving the
    same folded keys / schedule values the fused step derives.
    """

    estimate: Callable[..., Tuple[jnp.ndarray, PyTree]]
    update: Callable[..., Tuple[PyTree, PyTree]]
    mix: Callable[..., Tuple[PyTree, PyTree]]
    mixer_diagnostics: Dict[str, float]


def build_phase_fns(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    cfg,
    *,
    param_dim: Optional[int] = None,
    mesh=None,
    population_axes: Tuple[str, ...] = (),
    params_template: Optional[PyTree] = None,
    jit: bool = True,
    shard: bool = False,
    model_axes: Tuple[str, ...] = (),
) -> PhaseFns:
    """The fused step's phases as standalone calls (same builders, same
    key stream — see module docstring).  ``jit=True`` returns each
    phase already jitted (the fenced-timing shape).  ``shard=True``
    routes each phase through its own ``shard_map`` over ``mesh``
    (core/shardround.py), matching ``build_hdo_step(shard=True)`` — so
    per-phase numbers time the same sharded programs the fused sharded
    round runs."""
    from repro.configs.base import HDOConfig  # noqa: F401  (type anchor)
    from repro.core import hdo, localupdate, population, schedules
    from repro.core import plane as planelib
    from repro.topology.mixer import make_mixer

    if shard:
        from repro.core import shardround

        return shardround.build_sharded_phase_fns(
            loss_fn, cfg, mesh=mesh,
            population_axes=population_axes or ("agents",),
            model_axes=model_axes or ("model",),
            param_dim=param_dim, params_template=params_template, jit=jit)

    if cfg.local_steps != 1:
        raise ValueError(
            f"per-phase decomposition needs local_steps == 1 (H="
            f"{cfg.local_steps} interleaves H estimate+update pairs in "
            f"one scan — there is no three-call split of that round)"
        )

    n = cfg.n_agents
    pop = population.resolve_population(cfg)
    manifest = None
    if cfg.param_layout == "plane":
        if params_template is None:
            raise ValueError("param_layout='plane' needs params_template")
        manifest = planelib.build_manifest(params_template)
    sched = schedules.warmup_cosine(
        pop.lr0 if pop.homogeneous else cfg.lr,
        cfg.warmup_steps, cfg.cosine_steps, cfg.use_cosine,
    )
    mixer = make_mixer(cfg, mesh=mesh, population_axes=population_axes,
                       param_dim=param_dim)
    estimate_phase = hdo.build_estimate_phase(
        loss_fn, cfg, mesh=mesh, population_axes=population_axes,
        manifest=manifest,
    )
    local_update = localupdate.make_local_update(cfg)

    if pop.homogeneous:
        lr_rel = sigma_tab = None
    else:
        lr_rel = jnp.asarray(pop.lr_array() / np.float32(cfg.lr))
        sigma_tab = jnp.asarray(pop.sigma_array())

    # the exact scalar derivations of hdo.build_hdo_step.step — one
    # helper shared by all three phases so the decomposition cannot
    # drift from the fused step's schedule / smoothing values
    def _round_scalars(t):
        lr = sched(t)
        nu = (
            lr / jnp.sqrt(jnp.float32(param_dim))
            if (cfg.nu_from_lr and param_dim)
            else jnp.float32(pop.sigma0)
        )
        lr_vec = None if pop.homogeneous else lr * lr_rel
        n0 = cfg.n_zeroth
        if pop.homogeneous:
            nu_vec = None
        elif cfg.nu_from_lr and param_dim:
            nu_vec = lr_vec[:n0] / jnp.sqrt(jnp.float32(param_dim))
        else:
            nu_vec = sigma_tab
        return lr, nu, lr_vec, nu_vec

    def estimate(state, batches):
        t = state.step
        _, nu, _, nu_vec = _round_scalars(t)
        skey = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t)
        agent_keys = jax.random.split(skey, n)
        return estimate_phase(state.params, batches, agent_keys, nu, nu_vec)

    def update(state, g):
        lr, _, lr_vec, _ = _round_scalars(state.step)
        return local_update.apply(state.params, g, state.opt_state, lr, lr_vec)

    def mix(state, new_params):
        t = state.step
        gkey = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t), 7)
        return mixer.mix(new_params, key=gkey, step=t, comm=state.comm)

    if jit:
        estimate, update, mix = jax.jit(estimate), jax.jit(update), jax.jit(mix)
    return PhaseFns(estimate, update, mix, dict(mixer.diagnostics()))


def phase_round(fns: PhaseFns, state, batches, *, annotate: bool = False):
    """One full HDO round through the three phase calls; returns
    ``(new_state, losses)``.  Bit-identical to the fused step on the
    same state (tests/test_obs.py) — the honesty contract behind the
    fenced numbers.  ``annotate=True`` wraps each dispatch in a
    ``jax.profiler.TraceAnnotation`` (the ``--trace-phases`` view)."""
    from repro.core.hdo import HDOState

    with host_annotation("hdo/estimate", annotate):
        losses, g = fns.estimate(state, batches)
    with host_annotation("hdo/update", annotate):
        new_params, new_opt = fns.update(state, g)
    with host_annotation("hdo/mix", annotate):
        mixed, new_comm = fns.mix(state, new_params)
    return HDOState(params=mixed, opt_state=new_opt, step=state.step + 1,
                    comm=new_comm), losses


def analytic_phase_bytes(cfg, param_dim: Optional[int], *,
                         n_shards: int = 1) -> Dict[str, int]:
    """Analytic HBM traffic of the update/mix phases for one round of
    the whole population — the ``benchmarks/kernel_bench.py`` model
    (``msz`` = momentum element width):

      * update, sgd+momentum: the fused apply streams
        ``(12 + 2*msz) * d`` per agent (read p, g; write p; read+write
        m); momentum=0 drops the momentum stream (``12 * d``); adamw
        reads p, g, mu, nu and writes p, mu, nu:
        ``(20 + 2*msz) * d``.
      * mix, static-graph gossip: ``gossip_mix`` reads x + k neighbor
        rows and writes x: ``(k + 2) * d * 4``; the compressed fresh
        round (``compress_mix``) additionally reads the send basis and
        writes the residual: ``(k + 4) * d * 4``.

    Phases without a clean model (dense random pairing, all_reduce,
    time-varying graphs, the estimate phase) are omitted rather than
    priced wrongly.  Empty dict when ``param_dim`` is unknown.

    ``n_shards`` divides the totals: under the sharded round the
    population's O(n * d) streams split evenly over the mesh, so the
    fenced per-phase timings (which measure ONE process hosting all
    shards on forced host devices, or one real device's shard on
    hardware) pair with per-shard bytes — ``hbm_gbps_*`` then reports
    per-device achieved bandwidth.  The default (1) is the whole-
    population accounting of the unsharded step.
    """
    if not param_dim:
        return {}
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    out: Dict[str, int] = {}
    n, d = cfg.n_agents, int(param_dim)
    msz = 2 if cfg.momentum_dtype == "bfloat16" else 4
    if cfg.optimizer == "adamw":
        out["hbm_bytes_update"] = n * (20 + 2 * msz) * d
    elif cfg.momentum > 0.0:
        out["hbm_bytes_update"] = n * (12 + 2 * msz) * d
    else:
        out["hbm_bytes_update"] = n * 12 * d
    if cfg.gossip in ("graph", "graph_ppermute") and cfg.topology in (
            "ring", "torus", "hypercube", "erdos_renyi"):
        from repro.topology.graphs import make_topology

        topo = make_topology(cfg.topology, n, p=cfg.topology_p,
                             seed=cfg.topology_seed,
                             rounds=cfg.topology_rounds)
        k = topo.k
        per_agent = ((k + 4) if cfg.compression != "none" else (k + 2)) * d * 4
        out["hbm_bytes_mix"] = n * per_agent
    if n_shards > 1:
        out = {k: v // n_shards for k, v in out.items()}
    return out


def default_sample_rounds(steps: int) -> Tuple[int, ...]:
    """The rounds a driver samples fenced timing at: one early
    steady-state round (past compile + allocator warmup) plus mid- and
    late-run samples — deterministic, a handful per run regardless of
    length."""
    if steps <= 1:
        return ()
    cand = {min(3, steps - 1), steps // 2, steps - 2}
    return tuple(sorted(t for t in cand if 0 < t < steps))


def _fence(tree) -> None:
    for leaf in jax.tree.leaves(tree):
        jax.block_until_ready(leaf)


class PhaseTimer:
    """Fenced wall-clock over the three phase calls.

    ``measure(state, batches)`` runs estimate/update/mix on the given
    (pre-round) state with a ``block_until_ready`` fence after each,
    discarding outputs — the trajectory is untouched.  The FIRST call
    also reports each phase's compile time (``phase_compile_ms_*``:
    first dispatch minus a steady re-dispatch); later calls are pure
    steady-state.  Pass ``fused_fn`` (the driver's jitted step, already
    warm) to record ``step_ms_fused`` for the same round — the number
    the per-phase sum is validated against (acceptance: within 20%).
    """

    def __init__(self, fns: PhaseFns,
                 analytic_bytes: Optional[Dict[str, int]] = None,
                 *, reps: int = 5):
        if reps < 1:
            raise ValueError(f"reps must be >= 1, got {reps}")
        self.fns = fns
        self.analytic_bytes = dict(analytic_bytes or {})
        self.reps = reps
        self._compiled = False

    def _timed(self, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        _fence(out)
        return out, (time.perf_counter() - t0) * 1e3

    def measure(self, state, batches,
                fused_fn: Optional[Callable] = None) -> Dict[str, float]:
        out: Dict[str, float] = {}
        if not self._compiled:
            # first dispatch per phase = trace + compile + run
            (_, g), c_est = self._timed(self.fns.estimate, state, batches)
            (new_p, _), c_upd = self._timed(self.fns.update, state, g)
            _, c_mix = self._timed(self.fns.mix, state, new_p)
            self._compiled = True
            firsts = {"estimate": c_est, "update": c_upd, "mix": c_mix}
        else:
            firsts = None

        # best-of-reps per phase (min = the standard robust wall-clock
        # estimator against scheduler noise; same idiom as
        # benchmarks/kernel_bench._time) — phases re-run on the SAME
        # pre-round state, so repetition changes nothing downstream
        t_est = t_upd = t_mix = float("inf")
        for _ in range(self.reps):
            (losses, g), ms = self._timed(self.fns.estimate, state, batches)
            t_est = min(t_est, ms)
            (new_p, new_o), ms = self._timed(self.fns.update, state, g)
            t_upd = min(t_upd, ms)
            _, ms = self._timed(self.fns.mix, state, new_p)
            t_mix = min(t_mix, ms)
        del losses, new_o
        out["phase_ms_estimate"] = t_est
        out["phase_ms_update"] = t_upd
        out["phase_ms_mix"] = t_mix
        out["phase_ms_total"] = t_est + t_upd + t_mix
        if firsts is not None:
            steady = {"estimate": t_est, "update": t_upd, "mix": t_mix}
            for name, ms in firsts.items():
                out[f"phase_compile_ms_{name}"] = max(0.0, ms - steady[name])
        if fused_fn is not None:
            t_fused = float("inf")
            for _ in range(self.reps):
                _, ms = self._timed(fused_fn, state, batches)
                t_fused = min(t_fused, ms)
            out["step_ms_fused"] = t_fused
        for phase, t_ms in (("update", t_upd), ("mix", t_mix)):
            b = self.analytic_bytes.get(f"hbm_bytes_{phase}")
            if b and t_ms > 0:
                out[f"hbm_bytes_{phase}"] = float(b)
                # bytes / (ms * 1e6) == GB/s
                out[f"hbm_gbps_{phase}"] = b / (t_ms * 1e6)
        return out
