"""Phase-scoped tracing for the HDO round.

Two annotation layers, one per observer:

  * **Trace-time scopes** (``phase_scope`` / ``op_scope``) wrap
    ``jax.named_scope`` around code *inside* a jitted computation —
    the scope name lands in the HLO op metadata, so an xprof / Perfetto
    trace of the compiled step resolves its ops to HDO phases
    (``hdo/estimate``, ``hdo/update``, ``hdo/mix``) and to the fused
    Pallas kernels (``zo_combine``, ``opt_apply``, ``gossip_mix``, ...)
    instead of a flat soup of fusions.  Scopes annotate metadata only:
    the lowered program's numerics are bit-identical with or without
    them (pinned by tests/test_obs.py).

  * **Run-time annotations** (``host_annotation``) wrap
    ``jax.profiler.TraceAnnotation`` around *host-side* dispatch — used
    by ``launch/train.py --trace-phases``, which runs the round as
    three separately-jitted phase calls so the host timeline shows the
    estimate/update/mix boundary too.

``profile_window`` is the capture surface for ``--profile-dir``: it
brackets N steady-state rounds with ``jax.profiler.start_trace`` /
``stop_trace`` so the artifact holds warm-step traces, not compile
noise.  This module depends on ``jax`` only — ``core`` and ``kernels``
import it without cycling through the rest of ``repro.obs``.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

import jax

__all__ = [
    "PHASES",
    "phase_scope",
    "op_scope",
    "host_annotation",
    "profile_window",
    "ProfileSchedule",
]

# the three phases of one HDO round (paper Algorithm 1 pipeline order)
PHASES = ("estimate", "update", "mix")


@contextlib.contextmanager
def phase_scope(phase: str) -> Iterator[None]:
    """Trace-time scope for one HDO phase: ops traced inside carry
    ``hdo/<phase>`` in their metadata (visible in HLO dumps and xprof).
    Valid phases are ``PHASES``; anything else is a programming error
    caught here rather than a silent mislabel in the trace."""
    if phase not in PHASES:
        raise ValueError(f"unknown HDO phase {phase!r}; expected one of {PHASES}")
    with jax.named_scope(f"hdo/{phase}"):
        yield


@contextlib.contextmanager
def op_scope(name: str) -> Iterator[None]:
    """Trace-time scope for one fused kernel call site (``zo_combine``,
    ``opt_apply``, ``gossip_mix``, ...): the Pallas custom-call and its
    operand plumbing group under ``op/<name>`` in the trace."""
    with jax.named_scope(f"op/{name}"):
        yield


@contextlib.contextmanager
def host_annotation(name: str, enabled: bool = True) -> Iterator[None]:
    """Run-time ``jax.profiler.TraceAnnotation`` around host-side
    dispatch (a no-op when ``enabled`` is False, so call sites don't
    need two code paths)."""
    if not enabled:
        yield
        return
    with jax.profiler.TraceAnnotation(name):
        yield


@contextlib.contextmanager
def profile_window(profile_dir: Optional[str]) -> Iterator[None]:
    """Bracket a block with ``jax.profiler.start_trace``/``stop_trace``
    into ``profile_dir`` (no-op when None) — the xprof capture window."""
    if not profile_dir:
        yield
        return
    jax.profiler.start_trace(profile_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()


class ProfileSchedule:
    """Round-indexed capture window for a training loop.

    ``--profile-dir`` wants *steady-state* rounds: round 0 is compile
    and the first couple of rounds still shake allocator behavior, so
    the default window opens at round ``start`` and captures ``rounds``
    rounds.  Drive it with ``maybe_start(t)`` before the round's
    dispatch and ``maybe_stop(t)`` after; ``stop()`` (idempotent) in a
    ``finally`` guarantees the trace file is finalized even when the
    loop raises mid-window.
    """

    def __init__(self, profile_dir: Optional[str], *, start: int = 3,
                 rounds: int = 3):
        if rounds <= 0:
            raise ValueError(f"profile window needs rounds >= 1, got {rounds}")
        self.profile_dir = profile_dir
        self.start = start
        self.rounds = rounds
        self._active = False
        self._done = False

    @property
    def enabled(self) -> bool:
        return bool(self.profile_dir)

    def maybe_start(self, t: int) -> None:
        if not self.enabled or self._active or self._done:
            return
        if t >= self.start:
            jax.profiler.start_trace(self.profile_dir)
            self._active = True

    def maybe_stop(self, t: int) -> None:
        if self._active and t >= self.start + self.rounds - 1:
            self.stop()

    def stop(self) -> None:
        if self._active:
            jax.profiler.stop_trace()
            self._active = False
            self._done = True
