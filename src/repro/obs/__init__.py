"""Runtime observability for the HDO pipeline.

Three surfaces, one per question:

  * ``obs.metrics`` — WHAT happened: the versioned metric schema
    registry, the ``MetricsLogger`` with pluggable sinks (JSONL / CSV /
    stdout / guarded TensorBoard), run manifests, artifact validation.
  * ``obs.trace`` — WHERE in the program: ``jax.named_scope`` phase/op
    scopes inside the jitted step and the xprof capture window
    (``--profile-dir``).
  * ``obs.timing`` — HOW LONG, honestly: fenced per-phase wall-clock
    against a decomposition pinned bit-identical to the fused step,
    with achieved-HBM-GB/s against the kernel_bench analytic model.
    (Imported lazily — it pulls in ``repro.core``; ``trace`` and
    ``metrics`` stay dependency-light so core/kernels can import them.)
"""
from repro.obs.metrics import (  # noqa: F401
    REGISTRY,
    SCHEMA_VERSION,
    JSONLSink,
    CSVSink,
    MetricsLogger,
    StdoutSink,
    TensorBoardSink,
    make_sink,
    run_manifest,
    spec_for,
    undeclared,
    validate_jsonl,
)
from repro.obs.trace import (  # noqa: F401
    PHASES,
    ProfileSchedule,
    host_annotation,
    op_scope,
    phase_scope,
    profile_window,
)

__all__ = [
    "REGISTRY",
    "SCHEMA_VERSION",
    "MetricsLogger",
    "JSONLSink",
    "CSVSink",
    "StdoutSink",
    "TensorBoardSink",
    "make_sink",
    "run_manifest",
    "spec_for",
    "undeclared",
    "validate_jsonl",
    "PHASES",
    "ProfileSchedule",
    "host_annotation",
    "op_scope",
    "phase_scope",
    "profile_window",
]
