"""Structured metrics pipeline: versioned schema registry + sinks.

Every runtime metric the repo emits — the HDO step's training metrics,
the launch drivers' wall-clock accounting, the fenced per-phase timing
records, the serve driver's per-request stats — is declared ONCE in
``REGISTRY`` below with its type, unit, and pipeline phase.  The
``MetricsLogger`` refuses undeclared keys at runtime (``strict``), the
drift test (tests/test_obs.py) walks ``build_hdo_step``'s emitted keys
across dispatch x zo_impl x param_layout x compression, and the
rendered schema table in ``docs/observability.md`` is generated from
the same registry (``--write`` / ``--check``, the ``configs.knobs``
pattern) — so code, runtime validation, and docs cannot drift apart.

A run starts with a **manifest** record (``run_manifest``): schema
version, a stable hash of the ``HDOConfig``, the parameter-plane
``manifest_hash``, jax version, backend and device kind — enough to
interpret every later record without the producing process.  JSONL
records are self-describing via ``record``:

    {"record": "manifest", "schema_version": ..., "config_hash": ...}
    {"record": "metrics", "step": 0, "loss_mean": ..., ...}
    {"record": "phase_timing", "step": 10, "phase_ms_estimate": ...}
    {"record": "serve_request", "request_id": 0, "latency_ms": ...}
    {"record": "final", ...}

Sinks are pluggable: ``JSONLSink`` (the artifact format CI uploads),
``CSVSink`` (flat metrics records for spreadsheet triage),
``StdoutSink`` (the launch drivers' log lines), and an optional
``TensorBoardSink`` that degrades with a clear error when no
tensorboard writer is importable (never a hard dependency).

``python -m repro.obs.metrics --validate run.jsonl`` checks a produced
artifact: manifest header first, schema version match, every key
declared, ``step`` monotone — the CI slow lane runs it on the 20-round
smoke artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import fnmatch
import hashlib
import json
import sys
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "SCHEMA_VERSION",
    "MetricSpec",
    "REGISTRY",
    "SINK_KINDS",
    "spec_for",
    "undeclared",
    "MetricsLogger",
    "JSONLSink",
    "CSVSink",
    "StdoutSink",
    "TensorBoardSink",
    "make_sink",
    "run_manifest",
    "config_hash",
    "validate_jsonl",
    "schema_table_markdown",
]

# bump when a key is added/removed/retyped; recorded in every manifest
SCHEMA_VERSION = 2

SINK_KINDS = ("jsonl", "csv", "stdout", "tensorboard")

# value types: "f32" scalar float, "i32" scalar integer,
# "vec_f32" per-agent float vector (length n_agents)
_TYPES = ("f32", "i32", "vec_f32")


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One declared metric key.  ``key`` may hold ``*`` wildcards for
    per-group families (``grad_var_zo_*`` matches every estimator-kind
    group); ``phase`` locates the key in the estimate -> update -> mix
    round (or ``round``/``system``/``serve`` for driver-level keys)."""

    key: str
    type: str
    unit: str
    phase: str
    desc: str

    def __post_init__(self):
        if self.type not in _TYPES:
            raise ValueError(f"{self.key}: bad type {self.type!r}")


_S = MetricSpec

REGISTRY: Tuple[MetricSpec, ...] = (
    # ---- estimate phase ---------------------------------------------------
    _S("loss_mean", "f32", "nats", "estimate", "population mean training loss"),
    _S("loss_std", "f32", "nats", "estimate", "population loss standard deviation"),
    _S("loss_fo_mean", "f32", "nats", "estimate", "mean loss over the FO cohort"),
    _S("loss_zo_mean", "f32", "nats", "estimate", "mean loss over the ZO cohort"),
    _S("loss_zo_*_mean", "f32", "nats", "estimate",
       "per-estimator-kind-group mean loss (heterogeneous cohorts)"),
    _S("grad_var_zo_*", "f32", "grad^2", "estimate",
       "per-kind-group gradient-estimate variance (heterogeneous cohorts)"),
    _S("grad_var_fo", "f32", "grad^2", "estimate",
       "FO-cohort gradient variance (heterogeneous cohorts)"),
    _S("loss_agent", "vec_f32", "nats", "estimate",
       "per-agent loss vector (extended metrics)"),
    # ---- update phase -----------------------------------------------------
    _S("lr", "f32", "1/step", "update", "the shared learning-rate schedule value"),
    # ---- mix phase --------------------------------------------------------
    _S("gossip_lambda2", "f32", "1", "mix", "graph slem (second-largest |eigenvalue|)"),
    _S("gossip_spectral_gap", "f32", "1", "mix", "1 - slem of the mixing matrix"),
    _S("gossip_gamma_contraction", "f32", "1", "mix",
       "predicted per-round Gamma contraction (effective slem^2)"),
    _S("gossip_effective_lambda2", "f32", "1", "mix",
       "compression/staleness-adjusted effective slem"),
    _S("gossip_compress_delta", "f32", "1", "mix",
       "compressor energy-fraction delta in (0, 1]"),
    _S("gossip_staleness", "f32", "rounds", "mix", "configured staleness bound tau"),
    _S("gossip_wire_bytes", "f32", "bytes", "mix",
       "payload bytes the whole population broadcasts this round "
       "(measured config: Compressor.bytes_on_wire, dense 4*d otherwise)"),
    _S("wire_mib_total", "f32", "MiB", "mix",
       "cumulative on-wire traffic since round 0 (logger-accumulated)"),
    _S("fault_drop_count", "f32", "agents", "mix",
       "agents dropped (offline) this round by the fault schedule"),
    _S("fault_straggler_count", "f32", "agents", "mix",
       "agents whose broadcast failed to land this round"),
    _S("fault_byzantine_count", "f32", "agents", "mix",
       "agents transmitting corrupted payloads this round"),
    # ---- round level ------------------------------------------------------
    _S("step", "i32", "rounds", "round", "global round index"),
    _S("consensus_gamma", "f32", "param^2", "round",
       "Gamma_t = (1/n) sum_i ||x_i - mu||^2 (in-step, extended metrics)"),
    _S("consensus_agent", "vec_f32", "param^2", "round",
       "per-agent ||x_i - mu||^2 (extended metrics)"),
    _S("gamma", "f32", "param^2", "round",
       "consensus distance (host-side, the launch drivers' log line)"),
    _S("round_ms", "f32", "ms", "round",
       "fenced steady-state wall time of one fused round"),
    _S("wall_s", "f32", "s", "round",
       "steady-state wall clock since the first post-compile round"),
    # ---- system (once per run) -------------------------------------------
    _S("compile_s", "f32", "s", "system",
       "first-call (trace+compile) time of the jitted step, reported once"),
    # ---- fenced per-phase timing records ---------------------------------
    _S("phase_ms_estimate", "f32", "ms", "estimate",
       "fenced wall time of the estimate phase (separately jitted call)"),
    _S("phase_ms_update", "f32", "ms", "update",
       "fenced wall time of the local-update phase"),
    _S("phase_ms_mix", "f32", "ms", "mix",
       "fenced wall time of the mix phase"),
    _S("phase_ms_total", "f32", "ms", "round",
       "sum of the three fenced phase times"),
    _S("step_ms_fused", "f32", "ms", "round",
       "fenced wall time of the fused (single-jit) round, same state"),
    _S("phase_compile_ms_*", "f32", "ms", "system",
       "first-call (compile) time per separately-jitted phase"),
    _S("hbm_bytes_update", "f32", "bytes", "update",
       "analytic HBM traffic of the update phase (kernel_bench model)"),
    _S("hbm_bytes_mix", "f32", "bytes", "mix",
       "analytic HBM traffic of the mix phase (kernel_bench model)"),
    _S("hbm_gbps_update", "f32", "GB/s", "update",
       "achieved HBM bandwidth: analytic bytes / fenced phase time"),
    _S("hbm_gbps_mix", "f32", "GB/s", "mix",
       "achieved HBM bandwidth: analytic bytes / fenced phase time"),
    # ---- serve: per-request records ---------------------------------------
    _S("request_id", "i32", "1", "serve", "request (sequence) index in the batch"),
    _S("agent_id", "i32", "1", "serve",
       "cohort member that served the request (-1: population-mean snapshot)"),
    _S("prompt_tokens", "i32", "tokens", "serve", "prompt length"),
    _S("gen_tokens", "i32", "tokens", "serve", "generated tokens"),
    _S("queue_ms", "f32", "ms", "serve",
       "arrival -> slot admission wait (continuous-batching queue time)"),
    _S("prefill_ms", "f32", "ms", "serve",
       "wall time attributed to the request's teacher-forced prompt steps "
       "(includes producing the first new token)"),
    _S("decode_ms", "f32", "ms", "serve",
       "wall time attributed to the request's decode steps after the first "
       "new token (excludes prefill — the timing-honesty split)"),
    _S("latency_ms", "f32", "ms", "serve", "end-to-end request latency"),
    _S("tokens_per_s", "f32", "tokens/s", "serve",
       "per-request decode-only throughput (gen tokens after the first / "
       "decode_ms)"),
    # ---- serve: engine metrics (one record per logged chunk fence) --------
    _S("queue_depth", "i32", "requests", "serve",
       "requests waiting for a free slot at the chunk fence"),
    _S("slots_active", "i32", "slots", "serve", "occupied decode slots"),
    _S("slots_free", "i32", "slots", "serve", "free decode slots"),
    _S("prefill_tokens", "i32", "tokens", "serve",
       "prompt tokens consumed this chunk across all slots"),
    _S("decode_tokens", "i32", "tokens", "serve",
       "new tokens generated this chunk across all slots"),
    _S("chunk_ms", "f32", "ms", "serve",
       "fenced wall time of one jitted decode chunk"),
)

_EXACT = {s.key: s for s in REGISTRY if "*" not in s.key}
_PATTERNS = [s for s in REGISTRY if "*" in s.key]


def spec_for(key: str) -> Optional[MetricSpec]:
    """The declared spec for ``key`` (exact match first, then the ``*``
    families), or None for an undeclared key."""
    spec = _EXACT.get(key)
    if spec is not None:
        return spec
    for s in _PATTERNS:
        if fnmatch.fnmatchcase(key, s.key):
            return s
    return None


def undeclared(keys: Iterable[str]) -> List[str]:
    """The subset of ``keys`` absent from the registry (sorted)."""
    return sorted(k for k in set(keys) if spec_for(k) is None)


# ---------------------------------------------------------------------------
# run manifest
# ---------------------------------------------------------------------------


def config_hash(cfg) -> str:
    """Stable short hash of an ``HDOConfig`` (or any dataclass/dict):
    sha256 over the sorted-key JSON of its fields — the run identity the
    manifest records (msgpack/json round-trips normalize tuples to
    lists, matching the checkpoint meta comparison)."""
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        cfg = dataclasses.asdict(cfg)
    blob = json.dumps(cfg, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def run_manifest(cfg=None, *, manifest_hash: Optional[str] = None,
                 **extra: Any) -> Dict[str, Any]:
    """The run-header record: schema version + config hash + plane
    ``manifest_hash`` + jax/device identity (+ caller extras, e.g. the
    dryrun HLO cost summary or the CLI arch name)."""
    import jax

    devs = jax.devices()
    out: Dict[str, Any] = {
        "record": "manifest",
        "schema_version": SCHEMA_VERSION,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "unknown",
        "n_devices": len(devs),
    }
    if cfg is not None:
        out["config_hash"] = config_hash(cfg)
        if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
            out["hdo"] = dataclasses.asdict(cfg)
    if manifest_hash is not None:
        out["manifest_hash"] = manifest_hash
    out.update(extra)
    return out


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------


class JSONLSink:
    """One JSON object per line, flushed per record (the smoke-scale
    artifact format; CI uploads and validates it)."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")

    def write(self, record: Dict[str, Any]) -> None:
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class CSVSink:
    """Flat CSV of the ``metrics`` records only (header from the first
    record; later records fill missing columns blank and DROP novel
    keys — CSV cannot grow columns mid-file; use JSONL for full
    fidelity).  Vector values are JSON-encoded into their cell."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")
        self._header: Optional[List[str]] = None

    def write(self, record: Dict[str, Any]) -> None:
        if record.get("record") != "metrics":
            return
        row = {k: v for k, v in record.items() if k != "record"}
        if self._header is None:
            self._header = list(row)
            self._f.write(",".join(self._header) + "\n")
        cells = []
        for k in self._header:
            v = row.get(k, "")
            if isinstance(v, (list, tuple)):
                v = '"' + json.dumps(list(v)).replace('"', '""') + '"'
            cells.append(str(v))
        self._f.write(",".join(cells) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class StdoutSink:
    """The launch drivers' log line: every record printed as one JSON
    line (manifests prefixed ``# `` so step streams stay grep-able)."""

    def write(self, record: Dict[str, Any]) -> None:
        if record.get("record") in ("manifest", "final"):
            print("# " + json.dumps(record))
        else:
            print(json.dumps({k: v for k, v in record.items()
                              if k != "record"}))

    def close(self) -> None:
        pass


class TensorBoardSink:
    """Optional scalar sink; imports a SummaryWriter lazily so the repo
    never hard-depends on tensorboard (guarded per the no-new-deps
    rule: a clear error at construction, not an import-time crash)."""

    def __init__(self, logdir: str):
        writer_cls = None
        try:
            from tensorboardX import SummaryWriter as writer_cls  # type: ignore
        except ImportError:
            try:
                from torch.utils.tensorboard import SummaryWriter as writer_cls  # type: ignore
            except ImportError:
                pass
        if writer_cls is None:
            raise RuntimeError(
                "TensorBoardSink needs tensorboardX or torch.utils."
                "tensorboard; neither is importable — use the jsonl/csv "
                "sinks instead"
            )
        self._w = writer_cls(logdir)

    def write(self, record: Dict[str, Any]) -> None:
        if record.get("record") not in ("metrics", "phase_timing"):
            return
        step = int(record.get("step", 0))
        for k, v in record.items():
            if k in ("record", "step"):
                continue
            if isinstance(v, (int, float)):
                self._w.add_scalar(k, float(v), step)

    def close(self) -> None:
        self._w.close()


def make_sink(spec: str):
    """Sink from a ``--metrics-out`` value: ``*.csv`` -> CSVSink,
    ``tb:<logdir>`` -> TensorBoardSink, ``-`` -> StdoutSink, anything
    else -> JSONLSink."""
    if spec == "-":
        return StdoutSink()
    if spec.startswith("tb:"):
        return TensorBoardSink(spec[3:])
    if spec.endswith(".csv"):
        return CSVSink(spec)
    return JSONLSink(spec)


# ---------------------------------------------------------------------------
# the logger
# ---------------------------------------------------------------------------


def _coerce(key: str, value: Any) -> Any:
    """JSON-able python value for one metric (jax/np arrays -> float /
    int / list of floats), type-checked against the declared spec."""
    spec = spec_for(key)
    if hasattr(value, "tolist"):  # jax / numpy array or scalar
        value = value.tolist()
    if spec is not None and spec.type == "vec_f32":
        if not isinstance(value, (list, tuple)):
            raise TypeError(f"{key}: declared vec_f32 but got scalar {value!r}")
        return [float(v) for v in value]
    if isinstance(value, (list, tuple)):
        raise TypeError(f"{key}: declared scalar but got a vector of "
                        f"length {len(value)}")
    if spec is not None and spec.type == "i32":
        return int(value)
    return round(float(value), 6)


class MetricsLogger:
    """The runtime metrics pipeline: schema-checked records fanned out
    to the configured sinks.

    A logger with no sinks is inert: ``enabled`` is False and every
    ``log_*`` call returns immediately, so default runs pay nothing
    (and, by construction, cannot perturb the jitted step — the logger
    only ever *reads* metric values; tests pin the stronger claim that
    the step's arrays are bit-identical with metrics plumbing on/off).

    ``strict=True`` (default) raises on undeclared keys — the runtime
    half of the schema-drift gate.  The logger also owns the cumulative
    accounting that needs cross-round state, e.g. ``wire_mib_total``
    accumulated from per-round ``gossip_wire_bytes``.
    """

    def __init__(self, sinks: Sequence[Any] = (), *, strict: bool = True):
        self.sinks = list(sinks)
        self.strict = strict
        self._wire_bytes = 0.0
        self._wrote_manifest = False

    @property
    def enabled(self) -> bool:
        return bool(self.sinks)

    # -- record writers ----------------------------------------------------
    def _emit(self, record: Dict[str, Any]) -> None:
        for s in self.sinks:
            s.write(record)

    def _check(self, metrics: Dict[str, Any]) -> None:
        bad = undeclared(metrics.keys())
        if bad and self.strict:
            raise KeyError(
                f"undeclared metric keys {bad}: declare them in "
                f"repro.obs.metrics.REGISTRY (and bump SCHEMA_VERSION) "
                f"before emitting"
            )

    def start_run(self, manifest: Dict[str, Any]) -> None:
        """Write the run-header record (must be the first record; see
        ``run_manifest``)."""
        if not self.enabled:
            return
        rec = dict(manifest)
        rec.setdefault("record", "manifest")
        rec.setdefault("schema_version", SCHEMA_VERSION)
        self._emit(rec)
        self._wrote_manifest = True

    def log_round(self, step: int, metrics: Dict[str, Any]) -> None:
        """One ``metrics`` record for round ``step``.  Accumulates
        ``wire_mib_total`` whenever ``gossip_wire_bytes`` is present."""
        if not self.enabled:
            return
        self._check(metrics)
        rec: Dict[str, Any] = {"record": "metrics", "step": int(step)}
        for k, v in metrics.items():
            rec[k] = _coerce(k, v)
        if "gossip_wire_bytes" in rec:
            self._wire_bytes += rec["gossip_wire_bytes"]
            rec["wire_mib_total"] = round(self._wire_bytes / (1 << 20), 6)
        self._emit(rec)

    def log_timing(self, step: int, timing: Dict[str, Any]) -> None:
        """One fenced ``phase_timing`` record (see ``repro.obs.timing``)."""
        if not self.enabled:
            return
        self._check(timing)
        rec = {"record": "phase_timing", "step": int(step)}
        rec.update({k: _coerce(k, v) for k, v in timing.items()})
        self._emit(rec)

    def log_request(self, payload: Dict[str, Any]) -> None:
        """One ``serve_request`` record (the serve driver's per-request
        latency / token accounting)."""
        if not self.enabled:
            return
        self._check(payload)
        rec = {"record": "serve_request"}
        rec.update({k: _coerce(k, v) for k, v in payload.items()})
        self._emit(rec)

    def finish(self, summary: Optional[Dict[str, Any]] = None) -> None:
        """Write the ``final`` record (freeform summary) and close all
        sinks.  Idempotent enough for ``finally`` blocks."""
        if self.enabled and summary is not None:
            rec = {"record": "final"}
            rec.update(summary)
            self._emit(rec)
        for s in self.sinks:
            s.close()
        self.sinks = []


# ---------------------------------------------------------------------------
# artifact validation (CI slow lane) + generated docs table
# ---------------------------------------------------------------------------


def validate_jsonl(path: str) -> List[str]:
    """Validate a metrics JSONL artifact; returns a list of problems
    (empty = valid).  Checks: manifest header first with a matching
    schema version and a config hash, every metric/timing key declared,
    and the ``metrics`` records' ``step`` strictly monotone."""
    problems: List[str] = []
    with open(path) as f:
        lines = [ln for ln in (l.strip() for l in f) if ln]
    if not lines:
        return [f"{path}: empty file"]
    try:
        records = [json.loads(ln) for ln in lines]
    except json.JSONDecodeError as e:
        return [f"{path}: invalid JSON: {e}"]
    head = records[0]
    if head.get("record") != "manifest":
        problems.append("first record is not the run manifest")
    else:
        if head.get("schema_version") != SCHEMA_VERSION:
            problems.append(
                f"manifest schema_version {head.get('schema_version')} != "
                f"registry version {SCHEMA_VERSION}")
        for field in ("config_hash", "jax_version", "backend"):
            if field not in head:
                problems.append(f"manifest missing {field!r}")
    last_step = None
    for i, rec in enumerate(records[1:], start=2):
        kind = rec.get("record")
        if kind in ("metrics", "phase_timing"):
            bad = undeclared(k for k in rec if k != "record")
            if bad:
                problems.append(f"line {i}: undeclared keys {bad}")
        if kind == "metrics":
            step = rec.get("step")
            if not isinstance(step, int):
                problems.append(f"line {i}: metrics record without int step")
            elif last_step is not None and step <= last_step:
                problems.append(
                    f"line {i}: step {step} not monotone (prev {last_step})")
            else:
                last_step = step
    return problems


BEGIN = ("<!-- metric-schema:begin (generated by `python -m repro.obs.metrics "
         "--write docs/observability.md` — do not edit by hand) -->")
END = "<!-- metric-schema:end -->"


def schema_table_markdown() -> str:
    lines = [
        f"Schema version **{SCHEMA_VERSION}** "
        f"(`repro.obs.metrics.SCHEMA_VERSION`).",
        "",
        "| key | type | unit | phase | meaning |",
        "|---|---|---|---|---|",
    ]
    for s in REGISTRY:
        key = s.key.replace("*", "\\*")
        desc = s.desc.replace("|", "\\|")
        lines.append(f"| `{key}` | {s.type} | {s.unit} | {s.phase} | {desc} |")
    return "\n".join(lines)


def rendered_section() -> str:
    return f"{BEGIN}\n{schema_table_markdown()}\n{END}"


def inject(text: str) -> str:
    start, end = text.find(BEGIN), text.find(END)
    if start < 0 or end < 0 or end < start:
        raise SystemExit(
            f"metric-schema markers missing or out of order "
            f"(need {BEGIN!r} before {END!r})"
        )
    return text[:start] + rendered_section() + text[end + len(END):]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="docs/observability.md")
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--write", action="store_true",
                      help="rewrite the marked schema table in place")
    mode.add_argument("--check", action="store_true",
                      help="exit 1 if the marked schema table is stale")
    mode.add_argument("--validate", action="store_true",
                      help="validate PATH as a metrics JSONL artifact")
    args = ap.parse_args(argv)

    if args.validate:
        problems = validate_jsonl(args.path)
        for p in problems:
            print(f"{args.path}: {p}", file=sys.stderr)
        if not problems:
            print(f"{args.path}: valid (schema v{SCHEMA_VERSION})")
        return 1 if problems else 0

    with open(args.path) as f:
        text = f.read()
    new = inject(text)
    if args.write:
        if new != text:
            with open(args.path, "w") as f:
                f.write(new)
            print(f"{args.path}: metric schema table rewritten")
        else:
            print(f"{args.path}: metric schema table already current")
        return 0
    if new != text:
        print(f"{args.path}: metric schema table is stale — run "
              f"`PYTHONPATH=src python -m repro.obs.metrics --write {args.path}`",
              file=sys.stderr)
        return 1
    print(f"{args.path}: metric schema table is current")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
