"""Flat-parameter fused zeroth-order engine (``HDOConfig.zo_impl="fused"``).

The tree-pytree estimators in ``core/estimators.py`` materialize a full
Gaussian pytree u_r per draw (``tree_normal``), so one ZO estimate moves
O(rv * d) floats through HBM.  This engine ravels the agent's params
once (``jax.flatten_util.ravel_pytree``), then

  1. builds each perturbed candidate with the ``zo_perturb`` Pallas
     kernel — the Gaussian u_r is regenerated from the counter RNG
     inside VMEM tiles and never stored,
  2. evaluates the loss on the unraveled candidate,
  3. assembles g = (1/rv) sum_r c_r u_r with the ``zo_combine`` kernel
     (written directly in the params' dtype), again regenerating every
     u_r on the fly.

This removes the O(rv * d) Gaussian materialization entirely: the only
HBM traffic left is the candidate evals themselves (one x read + one
candidate write per function evaluation, which any multi-point scheme
pays) plus a single O(d) write of g — the noise term the tree path
adds on top drops to zero.

The counter RNG draws differ from ``jax.random.normal``, so the fused
path is distribution-equivalent (same estimator, same statistics) but
not bit-equal to the tree path; parity is asserted on converged
solutions (see tests/test_perf_variants.py).

``fwd_grad`` needs a materialized tangent for ``jax.jvp`` and is not
fused; callers fall back to the tree implementation for it.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.kernels import ops

PyTree = Any
LossFn = Callable[[PyTree], jnp.ndarray]  # params -> scalar loss

# estimator kinds the fused engine implements (fwd_grad excluded)
FUSED_KINDS = ("biased_1pt", "biased_2pt", "multi_rv")


def seed_from_key(key) -> jnp.ndarray:
    """Non-negative int32 kernel seed derived from a PRNG key (vmap-safe)."""
    return (jax.random.bits(key, dtype=jnp.uint32) >> 1).astype(jnp.int32)


def flat_zo_estimate(
    loss_fn: LossFn,
    params: PyTree,
    key,
    *,
    kind: str = "multi_rv",
    rv: int = 4,
    nu: float = 1e-4,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, PyTree]:
    """Fused zeroth-order estimate: (loss_at_x, grad_estimate).

    Drop-in for ``estimators.zo_estimate`` on the finite-difference
    kinds; ``key`` seeds the counter RNG instead of ``jax.random``.
    """
    if kind not in FUSED_KINDS:
        raise ValueError(f"fused ZO engine supports {FUSED_KINDS}, got {kind!r}")
    flat, unravel = ravel_pytree(params)
    d = flat.shape[0]
    seed = seed_from_key(key)
    nu = jnp.asarray(nu, jnp.float32)
    two_point = kind in ("biased_2pt", "multi_rv")
    n_draws = rv if kind == "multi_rv" else 1

    loss0 = loss_fn(params)
    flat_loss = lambda v: loss_fn(unravel(v))

    def coeff(_, r):
        lp = flat_loss(ops.zo_perturb(flat, seed, r, nu, interpret=interpret))
        if two_point:
            lm = flat_loss(ops.zo_perturb(flat, seed, r, -nu, interpret=interpret))
            c = (lp - lm) / (2.0 * nu)
        else:
            c = (lp - loss0) / nu
        return None, c.astype(jnp.float32)

    _, coeffs = jax.lax.scan(coeff, None, jnp.arange(n_draws))
    g_flat = ops.zo_combine(coeffs, seed, d, out_dtype=flat.dtype, interpret=interpret)
    return loss0, unravel(g_flat)
