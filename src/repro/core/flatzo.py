"""Flat-parameter fused zeroth-order engine (``HDOConfig.zo_impl="fused"``).

The tree-pytree estimators in ``core/estimators.py`` materialize a full
Gaussian pytree u_r per draw (``tree_normal``), so one ZO estimate moves
O(rv * d) floats through HBM.  This engine ravels the agent's params
once (``jax.flatten_util.ravel_pytree``), then

  1. builds each perturbed candidate with the ``zo_perturb`` Pallas
     kernel — the Gaussian u_r is regenerated from the counter RNG
     inside VMEM tiles and never stored,
  2. evaluates the loss on the unraveled candidate,
  3. assembles g = (1/rv) sum_r c_r u_r with the ``zo_combine`` kernel
     (written directly in the params' dtype), again regenerating every
     u_r on the fly.

This removes the O(rv * d) Gaussian materialization entirely: the only
HBM traffic left is the candidate evals themselves (one x read + one
candidate write per function evaluation, which any multi-point scheme
pays) plus a single O(d) write of g — the noise term the tree path
adds on top drops to zero.

The counter RNG draws differ from ``jax.random.normal``, so the fused
path is distribution-equivalent (same estimator, same statistics) but
not bit-equal to the tree path; parity is asserted on converged
solutions (see tests/test_perf_variants.py) and on the estimator mean
(tests/test_properties.py).

``fwd_grad`` (unbiased forward-mode (u . grad F) u) is fused through
``flat_fwd_grad``: the ``zo_tangent`` kernel materializes each tangent
u_r in a single O(d) pass on the same counter stream, ``jax.jvp``
pushes it through the loss, and ``zo_combine`` assembles
g = (1/rv) sum_r jvp_r u_r by regenerating every u_r in VMEM — the
tangent itself must exist for the JVP, but the rv-deep accumulator and
the per-leaf Gaussian generation of the tree path drop to zero.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import plane as planelib
from repro.core.estimators import ZO_KINDS
from repro.kernels import ops

PyTree = Any
LossFn = Callable[[PyTree], jnp.ndarray]  # params -> scalar loss

# the fused engine implements every estimator kind
FUSED_KINDS = ZO_KINDS


def seed_from_key(key) -> jnp.ndarray:
    """Non-negative int32 kernel seed derived from a PRNG key (vmap-safe)."""
    return (jax.random.bits(key, dtype=jnp.uint32) >> 1).astype(jnp.int32)


def _mask_coeffs(coeffs, rv_actual):
    """Zero the padded draws of a ragged-rv agent; returns (coeffs,
    n_active) ready for ``zo_combine``'s denominator operand."""
    if rv_actual is None:
        return coeffs, None
    n_draws = coeffs.shape[0]
    live = jnp.arange(n_draws) < rv_actual
    return jnp.where(live, coeffs, 0.0), jnp.asarray(rv_actual, jnp.float32)


def flat_zo_estimate(
    loss_fn: LossFn,
    params: PyTree,
    key,
    *,
    kind: str = "multi_rv",
    rv: int = 4,
    nu: float = 1e-4,
    rv_actual=None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, PyTree]:
    """Fused zeroth-order estimate: (loss_at_x, grad_estimate).

    Drop-in for ``estimators.zo_estimate`` on the finite-difference
    kinds; ``key`` seeds the counter RNG instead of ``jax.random``.

    ``rv_actual`` (optional, may be traced) is the ragged-rv support
    for heterogeneous cohorts: the scan runs the static ``rv`` draws
    (one uniform program per vmapped kind group, padded to the group's
    ``rv_max``), excess coefficients are zeroed, and ``zo_combine``
    averages over ``rv_actual`` via its denominator operand — the
    kernels stay one O(d) pass.  Ignored by the single-draw kinds.
    """
    if kind not in FUSED_KINDS:
        raise ValueError(f"fused ZO engine supports {FUSED_KINDS}, got {kind!r}")
    if kind == "fwd_grad":
        return flat_fwd_grad(loss_fn, params, key, rv=rv, rv_actual=rv_actual,
                             interpret=interpret)
    flat, unravel = ravel_pytree(params)
    d = flat.shape[0]
    seed = seed_from_key(key)
    nu = jnp.asarray(nu, jnp.float32)
    two_point = kind in ("biased_2pt", "multi_rv")
    n_draws = rv if kind == "multi_rv" else 1
    if kind != "multi_rv":
        rv_actual = None  # single-draw kinds have nothing to mask

    loss0 = loss_fn(params)
    flat_loss = lambda v: loss_fn(unravel(v))

    def coeff(_, r):
        lp = flat_loss(ops.zo_perturb(flat, seed, r, nu, interpret=interpret))
        if two_point:
            lm = flat_loss(ops.zo_perturb(flat, seed, r, -nu, interpret=interpret))
            c = (lp - lm) / (2.0 * nu)
        else:
            c = (lp - loss0) / nu
        return None, c.astype(jnp.float32)

    _, coeffs = jax.lax.scan(coeff, None, jnp.arange(n_draws))
    coeffs, n_active = _mask_coeffs(coeffs, rv_actual)
    g_flat = ops.zo_combine(coeffs, seed, d, n_active=n_active,
                            out_dtype=flat.dtype, interpret=interpret)
    return loss0, unravel(g_flat)


def flat_fwd_grad(
    loss_fn: LossFn,
    params: PyTree,
    key,
    *,
    rv: int = 4,
    rv_actual=None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, PyTree]:
    """Fused unbiased forward-gradient estimate: (loss_at_x, grad_estimate).

    Per draw r the ``zo_tangent`` kernel writes u_r in one O(d) pass,
    ``jax.jvp`` yields jvp_r = u_r . grad F (one forward pass, no
    backprop), and ``zo_combine`` rebuilds g = (1/rv) sum_r jvp_r u_r
    from the same counter stream — no tangent is kept past its JVP and
    no O(d) accumulator exists outside the combine kernel's VMEM tiles.
    """
    flat, unravel = ravel_pytree(params)
    d = flat.shape[0]
    seed = seed_from_key(key)

    def draw(_, r):
        # f32 tangent: bit-identical to the u_r zo_combine regenerates;
        # unravel casts to each leaf's dtype at the jvp boundary (the
        # same per-leaf rounding the tree path applies to its tangents)
        u_flat = ops.zo_tangent(seed, r, d, interpret=interpret)
        primal, jvp = jax.jvp(loss_fn, (params,), (unravel(u_flat),))
        return None, (primal, jvp.astype(jnp.float32))

    _, (primals, coeffs) = jax.lax.scan(draw, None, jnp.arange(rv))
    coeffs, n_active = _mask_coeffs(coeffs, rv_actual)
    g_flat = ops.zo_combine(coeffs, seed, d, n_active=n_active,
                            out_dtype=flat.dtype, interpret=interpret)
    return primals[0], unravel(g_flat)


def plane_zo_estimate(
    loss_fn: LossFn,
    x: jnp.ndarray,
    key,
    *,
    manifest: planelib.PlaneManifest,
    kind: str = "multi_rv",
    rv: int = 4,
    nu: float = 1e-4,
    rv_actual=None,
    interpret: Optional[bool] = None,
    tables=None,
    assemble=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``flat_zo_estimate`` over the persistent plane: (loss_at_x, g).

    ``x`` is the agent's ``(manifest.dim,)`` plane row and the returned
    gradient estimate is a plane row too — no ``ravel_pytree`` and no
    pad/slice HBM round-trip per kernel call; the pytree is rebuilt
    (``plane.unpack``) only at the loss boundary.  The plane kernels
    draw on the *compact* counter stream (``plane.rng_tables``), so
    every u_r is bit-identical to the tree-layout fused engine's over
    ``ravel_pytree`` of the same model; pad lanes stay zero.

    Under FSDP sharding of the dim axis, ``x`` is the shard-local slice:
    pass this shard's ``(delta, nvalid)`` via ``tables`` (see
    ``plane.rng_tables_sharded``) and a gather-to-full-row callable via
    ``assemble`` (e.g. a tiled ``all_gather`` over the model axis) so
    perturb/combine run on local lanes while the loss sees full rows.
    """
    if kind not in FUSED_KINDS:
        raise ValueError(f"fused ZO engine supports {FUSED_KINDS}, got {kind!r}")
    if kind == "fwd_grad":
        return plane_fwd_grad(loss_fn, x, key, manifest=manifest, rv=rv,
                              rv_actual=rv_actual, interpret=interpret,
                              tables=tables, assemble=assemble)
    delta, nvalid = tables if tables is not None else planelib.rng_tables(manifest)
    full = assemble if assemble is not None else (lambda v: v)
    d_local = x.shape[0]
    seed = seed_from_key(key)
    nu = jnp.asarray(nu, jnp.float32)
    two_point = kind in ("biased_2pt", "multi_rv")
    n_draws = rv if kind == "multi_rv" else 1
    if kind != "multi_rv":
        rv_actual = None  # single-draw kinds have nothing to mask

    loss0 = loss_fn(planelib.unpack(manifest, full(x)))
    plane_loss = lambda v: loss_fn(planelib.unpack(manifest, full(v)))

    def coeff(_, r):
        lp = plane_loss(ops.zo_perturb_plane(x, seed, r, nu, delta, nvalid,
                                             interpret=interpret))
        if two_point:
            lm = plane_loss(ops.zo_perturb_plane(x, seed, r, -nu, delta, nvalid,
                                                 interpret=interpret))
            c = (lp - lm) / (2.0 * nu)
        else:
            c = (lp - loss0) / nu
        return None, c.astype(jnp.float32)

    _, coeffs = jax.lax.scan(coeff, None, jnp.arange(n_draws))
    coeffs, n_active = _mask_coeffs(coeffs, rv_actual)
    g = ops.zo_combine_plane(coeffs, seed, delta, nvalid, d_local,
                             n_active=n_active, out_dtype=x.dtype,
                             interpret=interpret)
    return loss0, g


def plane_fwd_grad(
    loss_fn: LossFn,
    x: jnp.ndarray,
    key,
    *,
    manifest: planelib.PlaneManifest,
    rv: int = 4,
    rv_actual=None,
    interpret: Optional[bool] = None,
    tables=None,
    assemble=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``flat_fwd_grad`` over the persistent plane (see
    ``plane_zo_estimate`` for the layout/stream contract, including the
    sharded ``tables``/``assemble`` hooks).  The f32 tangent is unpacked
    at the jvp boundary — the same per-leaf rounding the tree-layout
    path applies via ``unravel``."""
    delta, nvalid = tables if tables is not None else planelib.rng_tables(manifest)
    full = assemble if assemble is not None else (lambda v: v)
    d_local = x.shape[0]
    seed = seed_from_key(key)
    unpacked = planelib.unpack(manifest, full(x))

    def draw(_, r):
        u = ops.zo_tangent_plane(seed, r, delta, nvalid, d_local,
                                 interpret=interpret)
        primal, jvp = jax.jvp(loss_fn, (unpacked,),
                              (planelib.unpack(manifest, full(u)),))
        return None, (primal, jvp.astype(jnp.float32))

    _, (primals, coeffs) = jax.lax.scan(draw, None, jnp.arange(rv))
    coeffs, n_active = _mask_coeffs(coeffs, rv_actual)
    g = ops.zo_combine_plane(coeffs, seed, delta, nvalid, d_local,
                             n_active=n_active, out_dtype=x.dtype,
                             interpret=interpret)
    return primals[0], g
