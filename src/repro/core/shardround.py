"""The sharded HDO round: one ``shard_map`` over an ``agents x model``
mesh covering the full estimate -> update -> mix pipeline.

Placement (see docs/sharding.md):

  * the population axis (``"agents"``) splits the cohort into
    contiguous blocks of ``n_local = n_agents / A`` agents — every
    per-agent stream (params, opt state, EF residuals, batches) shards
    its leading axis;
  * under ``param_layout="plane"`` the model axis (``"model"``)
    FSDP-shards the flat ``(n_agents, dim)`` buffer's dim axis into
    BLOCK-aligned chunks: the O(d) phases (perturb, combine, update,
    mix) run on local ``dim_local`` slices, and only the loss/backprop
    boundary reconstructs full rows via a tiled ``all_gather``;
  * cross-device traffic in the mix phase is the round-decomposed
    ppermute exchange of ``topology.shardmix`` — O(neighbor degree)
    blocks per shard, never an O(n_agents) all-gather.

Bit-identity contract: every in-shard expression mirrors the unsharded
builders term for term (the estimate dispatch masks, ``LocalUpdate`` on
local rows, ``GraphMixer``'s combine via ``shardmix.combine_local``,
``CompressedGraphMixer``'s fresh difference-form round), all scalar/
metric math runs OUTSIDE the shard_map on globally-assembled values
with the unsharded step's literal expressions, and threefry-derived
operands are pinned replicated (``compat.replicate_operand``).  The
8-device subprocess tests in tests/test_shard.py pin sharded ==
unsharded bitwise across dispatch x zo_impl x layout; ``all_reduce``
is the one allclose-only mode (a psum reduces in a different order
than ``mean(axis=0)``).

v1 scope (clear ValueErrors otherwise): homogeneous cohorts,
``local_steps == 1``, ``dispatch in {"select", "shard_cond"}``,
``gossip in SHARD_GOSSIP_MODES``, static topologies, no staleness /
faults; compression (fresh + EF) needs ``model_parallel == 1``;
``model_parallel > 1`` needs the plane layout with
``manifest.n_blocks % M == 0`` and no gradient clipping (the
per-agent global norm would need a cross-shard reduction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import HDOConfig, SHARD_GOSSIP_MODES
from repro.core import estimators, flatzo, localupdate, population, schedules
from repro.core import plane as planelib
from repro.core.hdo import HDOState, _select_tree, consensus_per_agent
from repro.obs.trace import phase_scope

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardGeometry:
    """Resolved mesh geometry of one sharded round build."""
    pop_axes: Tuple[str, ...]
    mdl_axes: Tuple[str, ...]
    agent_shards: int   # A
    n_local: int
    model_shards: int   # M
    dim_local: Optional[int]  # plane only; manifest.dim for M == 1


def _axes_entry(axes: Tuple[str, ...]):
    """PartitionSpec entry for an axis tuple (None when empty)."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _resolve_geometry(cfg: HDOConfig, mesh, population_axes, model_axes,
                      manifest) -> ShardGeometry:
    pop_axes = tuple(a for a in population_axes if a in mesh.shape)
    mdl_axes = tuple(a for a in model_axes if a in mesh.shape)
    if not pop_axes and not mdl_axes:
        raise ValueError(
            f"mesh {dict(mesh.shape)} has none of the requested population "
            f"axes {population_axes} or model axes {model_axes}")
    A = int(np.prod([mesh.shape[a] for a in pop_axes])) if pop_axes else 1
    M = int(np.prod([mesh.shape[a] for a in mdl_axes])) if mdl_axes else 1
    n = cfg.n_agents
    if n % A != 0:
        raise ValueError(
            f"population axes {pop_axes} have {A} shards, which must "
            f"divide n_agents={n}")
    dim_local = None
    if manifest is not None:
        if manifest.n_blocks % M != 0:
            raise ValueError(
                f"model axes {mdl_axes} have {M} shards; the plane's "
                f"{manifest.n_blocks} BLOCKs must split evenly "
                f"(n_blocks % M == 0)")
        dim_local = manifest.dim // M
    if M > 1:
        mdl_use = mdl_axes
    else:
        mdl_use = ()  # size-1 model axes add nothing; keep specs minimal
    return ShardGeometry(pop_axes=pop_axes, mdl_axes=mdl_use,
                         agent_shards=A, n_local=n // A,
                         model_shards=M, dim_local=dim_local)


def _check_supported(cfg: HDOConfig, pop, geom: ShardGeometry) -> None:
    def bail(msg):
        raise ValueError(f"sharded HDO round (shard=True): {msg}")

    if not pop.homogeneous:
        bail("heterogeneous cohorts are not supported yet — use the "
             "unsharded step (mesh-aware dispatch='shard_cond' covers "
             "the heterogeneous case there)")
    if cfg.local_steps != 1:
        bail(f"local_steps must be 1, got {cfg.local_steps}")
    if cfg.dispatch not in ("select", "shard_cond"):
        bail(f"dispatch={cfg.dispatch!r}: static 'split' slicing cannot "
             "cross shard boundaries — use 'select' or 'shard_cond'")
    if cfg.gossip not in SHARD_GOSSIP_MODES:
        bail(f"gossip={cfg.gossip!r} is not shardable; supported: "
             f"{SHARD_GOSSIP_MODES}")
    if cfg.topology.startswith("tv_") and cfg.gossip in ("graph",
                                                         "graph_ppermute"):
        bail(f"time-varying topology {cfg.topology!r}: the ppermute plan "
             "needs a static neighbor table")
    if cfg.staleness > 0 or cfg.fault_drop_rate > 0 \
            or cfg.fault_straggler_rate > 0 or cfg.fault_byzantine_rate > 0:
        bail("staleness/fault injection need the buffered gather path — "
             "run them unsharded")
    M = geom.model_shards
    if M > 1:
        if cfg.param_layout != "plane":
            bail("model-axis sharding needs param_layout='plane' (the "
                 "tree layout has no per-leaf FSDP rule in the round)")
        if cfg.compression != "none":
            bail("compression + model-axis sharding is not supported "
                 "(thresholds are row-global); use model_parallel=1")
        if cfg.clip_norm > 0.0:
            bail("clip_norm > 0 with model-axis sharding would need a "
                 "cross-shard norm reduction; use model_parallel=1")
    if cfg.dispatch == "shard_cond" and 0 < cfg.n_zeroth < cfg.n_agents:
        if cfg.n_zeroth % geom.n_local != 0:
            bail(f"dispatch='shard_cond' needs the ZO/FO boundary aligned "
                 f"with shards: n_zeroth={cfg.n_zeroth} % n_local="
                 f"{geom.n_local} != 0")


def _diag_mixer(cfg: HDOConfig, param_dim):
    """A gather-path mixer object used ONLY for diagnostics() and
    wire_bytes_per_agent() — never called on arrays.  graph_ppermute
    maps onto 'graph' (same topology, same spectral numbers)."""
    from repro.topology.mixer import make_mixer

    diag_cfg = cfg
    if cfg.gossip == "graph_ppermute":
        diag_cfg = dataclasses.replace(cfg, gossip="graph")
    return make_mixer(diag_cfg, mesh=None, param_dim=param_dim)


def _build_round(loss_fn, cfg: HDOConfig, *, mesh, population_axes,
                 model_axes, param_dim, params_template):
    """Everything the fused sharded step and the sharded phase fns
    share: geometry, pspec trees, and the three in-shard phase bodies."""
    from jax.sharding import PartitionSpec as P

    from repro.topology import compress as compresslib
    from repro.topology import shardmix
    from repro.topology.graphs import make_topology
    from repro.topology.mixer import shard_agent_index

    n = cfg.n_agents
    pop = population.resolve_population(cfg)
    manifest = None
    if cfg.param_layout == "plane":
        if params_template is None:
            raise ValueError(
                "param_layout='plane' needs params_template (the "
                "single-agent model pytree, or its jax.eval_shape structs)")
        manifest = planelib.build_manifest(params_template)
    geom = _resolve_geometry(cfg, mesh, population_axes, model_axes, manifest)
    _check_supported(cfg, pop, geom)
    A, M, n_local = geom.agent_shards, geom.model_shards, geom.n_local
    pop_axes, mdl_axes = geom.pop_axes, geom.mdl_axes
    pop_s = _axes_entry(pop_axes)
    mdl_s = _axes_entry(mdl_axes)
    axis_names = set(pop_axes) | set(mdl_axes)
    use_plane = manifest is not None

    # --- pspec trees -----------------------------------------------------
    if use_plane:
        pspec_params_leaf = P(pop_s, mdl_s) if (pop_s or mdl_s) else P()
        params_pspecs = pspec_params_leaf
    else:
        pspec_params_leaf = P(pop_s) if pop_s else P()
        params_pspecs = None  # built per-state (tree structure unknown here)

    def tree_pspecs(tree):
        return jax.tree.map(lambda _: pspec_params_leaf, tree)

    def state_pspecs(state):
        p_psp = (params_pspecs if use_plane else tree_pspecs(state.params))
        return dict(
            params=p_psp,
            opt_state=localupdate.opt_state_pspecs(cfg, p_psp),
            comm=compresslib.comm_pspecs(cfg, p_psp),
        )

    batch_leaf_pspec = P(pop_s) if pop_s else P()

    # --- scalars (identical to hdo.build_hdo_step.step) ------------------
    sched = schedules.warmup_cosine(
        pop.lr0, cfg.warmup_steps, cfg.cosine_steps, cfg.use_cosine)

    def round_scalars(t):
        lr = sched(t)
        nu = (lr / jnp.sqrt(jnp.float32(param_dim))
              if (cfg.nu_from_lr and param_dim)
              else jnp.float32(pop.sigma0))
        return lr, nu

    # --- per-shard agent/model indices -----------------------------------
    def indices():
        gidx = shard_agent_index(mesh, pop_axes, n_local)
        midx = (shard_agent_index(mesh, mdl_axes, 1) if M > 1
                else jnp.int32(0))
        return gidx, midx

    # --- estimate bodies -------------------------------------------------
    dim_local = geom.dim_local
    if use_plane and M > 1:
        tables_s = planelib.rng_tables_sharded(manifest, M)
        mdl_name = mdl_s

        def assemble(v):
            # (dim_local,) local chunk -> (dim,) full row; identical
            # bits on every model shard (deterministic concat)
            return jax.lax.all_gather(v, mdl_name, axis=0, tiled=True)

        def local_tables(midx):
            b_local = manifest.n_blocks // M
            dl = jax.lax.dynamic_slice(
                jnp.asarray(tables_s[0]), (midx, 0), (1, b_local))[0]
            nv = jax.lax.dynamic_slice(
                jnp.asarray(tables_s[1]), (midx, 0), (1, b_local))[0]
            return dl, nv
    else:
        assemble = local_tables = None

    unpack = (lambda v: planelib.unpack(manifest, v)) if use_plane else None

    def make_per_agent(midx):
        """(per_agent_fo, per_agent_zo) closures for this shard — the
        unsharded ``build_estimate_phase`` bodies, plus the local-slice
        boundary when the plane's dim axis is sharded."""
        if use_plane and M > 1:
            def slice_local(g_plane):
                return jax.lax.dynamic_slice(
                    g_plane, (midx * dim_local,), (dim_local,))

            def per_agent_fo(x_i, batch_i):
                l_i, g_tree = estimators.fo_estimate(
                    lambda p: loss_fn(p, batch_i), unpack(assemble(x_i)))
                return l_i, slice_local(planelib.pack(manifest, g_tree))

            dl_nv = local_tables(midx)
            if cfg.zo_impl == "fused":
                def zo_engine(loss, x_i, key_i, **kw):
                    return flatzo.plane_zo_estimate(
                        loss, x_i, key_i, manifest=manifest,
                        tables=dl_nv, assemble=assemble, **kw)
            else:
                def zo_engine(loss, x_i, key_i, **kw):
                    l_i, g_tree = estimators.zo_estimate(
                        loss, unpack(assemble(x_i)), key_i, **kw)
                    return l_i, slice_local(planelib.pack(manifest, g_tree))
        elif use_plane:
            def per_agent_fo(x_i, batch_i):
                l_i, g_tree = estimators.fo_estimate(
                    lambda p: loss_fn(p, batch_i), unpack(x_i))
                return l_i, planelib.pack(manifest, g_tree)

            if cfg.zo_impl == "fused":
                def zo_engine(loss, x_i, key_i, **kw):
                    return flatzo.plane_zo_estimate(
                        loss, x_i, key_i, manifest=manifest, **kw)
            else:
                def zo_engine(loss, x_i, key_i, **kw):
                    l_i, g_tree = estimators.zo_estimate(
                        loss, unpack(x_i), key_i, **kw)
                    return l_i, planelib.pack(manifest, g_tree)
        else:
            def per_agent_fo(params_i, batch_i):
                return estimators.fo_estimate(
                    lambda p: loss_fn(p, batch_i), params_i)

            zo_engine = (flatzo.flat_zo_estimate if cfg.zo_impl == "fused"
                         else estimators.zo_estimate)

        def per_agent_zo(params_i, batch_i, key_i, nu):
            return zo_engine(lambda p: loss_fn(p, batch_i), params_i, key_i,
                             kind=pop.kind0, rv=pop.rv0, nu=nu)

        return per_agent_fo, per_agent_zo

    n0 = cfg.n_zeroth
    use_cond = (cfg.dispatch == "shard_cond" and 0 < n0 < n)

    def estimate_local(p_l, b_l, k_l, nu, gidx, midx):
        """(losses_l, g_l) for this shard's ``n_local`` agents —
        mirrors the unsharded select / shard_cond paths per row."""
        per_agent_fo, per_agent_zo = make_per_agent(midx)
        if use_cond:
            def zo_branch(_):
                return jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu))(
                    p_l, b_l, k_l)

            def fo_branch(_):
                return jax.vmap(per_agent_fo)(p_l, b_l)

            return jax.lax.cond(gidx < n0, zo_branch, fo_branch, None)
        # select: the SPMD-uniform masked baseline on local rows
        if cfg.n_first > 0:
            loss_fo, g_fo = jax.vmap(per_agent_fo)(p_l, b_l)
        else:
            loss_fo = jnp.zeros((n_local,), jnp.float32)
            g_fo = jax.tree.map(jnp.zeros_like, p_l)
        if cfg.n_zeroth > 0:
            loss_zo, g_zo = jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu))(
                p_l, b_l, k_l)
        else:
            loss_zo = jnp.zeros((n_local,), jnp.float32)
            g_zo = jax.tree.map(jnp.zeros_like, p_l)
        is_zo_l = (gidx + jnp.arange(n_local, dtype=jnp.int32)) < n0
        g = _select_tree(is_zo_l, g_zo, g_fo)
        losses = jnp.where(is_zo_l, loss_zo, loss_fo)
        return losses, g

    # --- update body -----------------------------------------------------
    # the LocalUpdate rule is row-wise, so rebuilding it at the local
    # cohort size applies the identical per-row arithmetic
    cfg_local = dataclasses.replace(
        cfg, n_agents=n_local, n_zeroth=min(cfg.n_zeroth, n_local))
    local_update = localupdate.make_local_update(cfg_local)

    def update_local(p_l, g_l, o_l, lr):
        return local_update.apply(p_l, g_l, o_l, lr, None)

    # --- mix body --------------------------------------------------------
    compressor = compresslib.make_compressor(cfg)
    graph_gossip = cfg.gossip in ("graph", "graph_ppermute") and n > 1
    if graph_gossip:
        topo = make_topology(cfg.topology, n, p=cfg.topology_p,
                             seed=cfg.topology_seed,
                             rounds=cfg.topology_rounds)
        plan = shardmix.plan_shard_mix(topo, A)
    else:
        topo = plan = None
    has_residual, _ = compresslib.comm_stream_flags(cfg)

    def mix_local_fn(p_l, c_l, seeds_l, gidx):
        """(new_params_l, new_comm_l); mirrors the gather-path mixers."""
        if cfg.gossip == "none" or n == 1:
            return p_l, c_l
        if cfg.gossip == "all_reduce":
            def ar(x):
                s = x.astype(jnp.float32).sum(axis=0)
                if pop_axes:
                    s = jax.lax.psum(s, pop_s)
                m = s / jnp.float32(n)
                return jnp.broadcast_to(m[None], x.shape).astype(x.dtype)

            return jax.tree.map(ar, p_l), c_l
        # static-graph gossip over the ppermute plan; the plan tables
        # are indexed by SHARD (gidx is the shard's first global agent)
        sidx = gidx // n_local
        sb, sr, w, w_self = shardmix.gather_tables(plan, topo, sidx)
        if compressor is None:
            def mix_leaf(x):
                bufs = shardmix.exchange_blocks(plan, x, pop_s)
                return shardmix.combine_local(x, bufs, sb, sr, w, w_self)

            return jax.tree.map(mix_leaf, p_l), c_l
        # compressed fresh round (M == 1): difference-form combine with
        # locally-computed payloads, exchanging only the decompressed
        # send payload m — CompressedGraphMixer's jnp path per row
        resid = c_l.get("residual") if isinstance(c_l, dict) else None
        p_leaves, tdef = jax.tree.flatten(p_l)
        r_leaves = (jax.tree.leaves(resid) if resid is not None
                    else [None] * len(p_leaves))
        outs = []
        for x, e in zip(p_leaves, r_leaves):
            shape = x.shape
            x2 = x.reshape(n_local, -1)
            d = x2.shape[1]
            xf = x2.astype(jnp.float32)
            u = xf + e.reshape(n_local, d) if e is not None else xf
            thr = compressor.thresholds(u)
            m = compressor.apply(u, thr, seeds_l)
            bufs = shardmix.exchange_blocks(plan, m, pop_s)
            m_nbr = bufs[sb, sr]  # (n_local, k, d)
            acc = (w[:, :, None] * (m_nbr - m[:, None, :])).sum(axis=1)
            out = (xf + acc).astype(x.dtype)
            new_e = (u - m).reshape(shape) if has_residual else None
            outs.append((out.reshape(shape), new_e))
        new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
        if resid is not None:
            new_c = dict(c_l)
            new_c["residual"] = jax.tree.unflatten(
                jax.tree.structure(resid), [o[1] for o in outs])
            return new_p, new_c
        return new_p, c_l

    wire_dim = manifest.size if manifest is not None else param_dim
    diag = _diag_mixer(cfg, wire_dim)

    def payload_seeds(t):
        if compressor is None:
            return jnp.zeros((n,), jnp.uint32)  # unused placeholder
        return compresslib.payload_seeds(cfg.seed, t, n)

    return dict(
        geom=geom, manifest=manifest, pop=pop, n=n,
        pop_s=pop_s, axis_names=axis_names,
        pspec_params_leaf=pspec_params_leaf, tree_pspecs=tree_pspecs,
        state_pspecs=state_pspecs, batch_leaf_pspec=batch_leaf_pspec,
        round_scalars=round_scalars, indices=indices,
        estimate_local=estimate_local, update_local=update_local,
        mix_local_fn=mix_local_fn, payload_seeds=payload_seeds,
        diag_mixer=diag, wire_dim=wire_dim, P=P,
    )


def build_sharded_step(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    cfg: HDOConfig,
    *,
    mesh,
    population_axes: Tuple[str, ...] = ("agents",),
    model_axes: Tuple[str, ...] = ("model",),
    param_dim: Optional[int] = None,
    params_template: Optional[PyTree] = None,
    extended_metrics: bool = False,
) -> Callable[[HDOState, Any], Tuple[HDOState, Dict[str, jnp.ndarray]]]:
    """``step(state, batches) -> (state, metrics)`` with the whole
    round under one shard_map (see module docstring).  The metric set
    matches ``build_hdo_step`` exactly (homogeneous subset) — metric
    math runs outside the shard_map on the assembled global values."""
    parts = _build_round(loss_fn, cfg, mesh=mesh,
                         population_axes=population_axes,
                         model_axes=model_axes, param_dim=param_dim,
                         params_template=params_template)
    P = parts["P"]
    n = parts["n"]
    n0 = cfg.n_zeroth
    mixer_metrics = {
        k: jnp.float32(v) for k, v in parts["diag_mixer"].diagnostics().items()
    }
    payload_bytes = (parts["diag_mixer"].wire_bytes_per_agent(parts["wire_dim"])
                     if extended_metrics and parts["wire_dim"] else None)
    pop_s = parts["pop_s"]
    losses_spec = P(pop_s) if pop_s else P()

    def step(state: HDOState, batches):
        t = state.step
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t)
        lr, nu = parts["round_scalars"](t)
        skey = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t)
        agent_keys = jax.random.split(skey, n)
        # threefry producers must stay replicated under the 0.4.x SPMD
        # partitioner (see compat) — then shard_map slices them
        agent_keys = compat.replicate_operand(agent_keys, mesh)
        seeds_pay = parts["payload_seeds"](t)
        st_psp = parts["state_pspecs"](state)
        b_psp = jax.tree.map(lambda _: parts["batch_leaf_pspec"], batches)

        def shard_fn(p_l, o_l, c_l, b_l, keys_l, seeds_full, lr_s, nu_s):
            gidx, midx = parts["indices"]()
            with phase_scope("estimate"):
                losses_l, g_l = parts["estimate_local"](
                    p_l, b_l, keys_l, nu_s, gidx, midx)
            with phase_scope("update"):
                new_p, new_o = parts["update_local"](p_l, g_l, o_l, lr_s)
            seeds_l = jax.lax.dynamic_slice(
                seeds_full, (gidx,), (parts["geom"].n_local,))
            with phase_scope("mix"):
                new_p, new_c = parts["mix_local_fn"](new_p, c_l, seeds_l, gidx)
            return new_p, new_o, new_c, losses_l

        new_params, new_opt, new_comm, losses = compat.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(st_psp["params"], st_psp["opt_state"], st_psp["comm"],
                      b_psp, P(pop_s) if pop_s else P(), P(), P(), P()),
            out_specs=(st_psp["params"], st_psp["opt_state"], st_psp["comm"],
                       losses_spec),
            axis_names=parts["axis_names"],
            check_vma=False,
        )(state.params, state.opt_state, state.comm, batches, agent_keys,
          seeds_pay, lr, nu)

        # ---- metrics: the unsharded step's literal expressions -------
        mets = {
            "loss_mean": losses.mean(),
            "loss_std": losses.std(),
        }
        if extended_metrics:
            mets["loss_agent"] = losses
        if cfg.n_first:
            mets["loss_fo_mean"] = losses[n0:].mean()
        if cfg.n_zeroth:
            mets["loss_zo_mean"] = losses[:n0].mean()
        metrics = {**mets, "lr": lr, **mixer_metrics}
        if extended_metrics:
            per_agent = consensus_per_agent(new_params)
            metrics["consensus_agent"] = per_agent
            metrics["consensus_gamma"] = per_agent.mean()
            if payload_bytes is not None:
                metrics["gossip_wire_bytes"] = jnp.float32(n) * jnp.float32(
                    payload_bytes)
        return HDOState(params=new_params, opt_state=new_opt, step=t + 1,
                        comm=new_comm), metrics

    return step


def build_sharded_phase_fns(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    cfg: HDOConfig,
    *,
    mesh,
    population_axes: Tuple[str, ...] = ("agents",),
    model_axes: Tuple[str, ...] = ("model",),
    param_dim: Optional[int] = None,
    params_template: Optional[PyTree] = None,
    jit: bool = True,
):
    """The sharded round's three phases as standalone calls with the
    ``obs.timing.PhaseFns`` contract — each phase is its own shard_map,
    composing bit-identically with ``build_sharded_step`` (same bodies,
    same key/schedule derivations)."""
    from repro.obs.timing import PhaseFns

    parts = _build_round(loss_fn, cfg, mesh=mesh,
                         population_axes=population_axes,
                         model_axes=model_axes, param_dim=param_dim,
                         params_template=params_template)
    P = parts["P"]
    n = parts["n"]
    pop_s = parts["pop_s"]
    losses_spec = P(pop_s) if pop_s else P()

    def estimate(state, batches):
        t = state.step
        _, nu = parts["round_scalars"](t)
        skey = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t)
        agent_keys = compat.replicate_operand(
            jax.random.split(skey, n), mesh)
        p_psp = parts["state_pspecs"](state)["params"]
        b_psp = jax.tree.map(lambda _: parts["batch_leaf_pspec"], batches)

        def shard_fn(p_l, b_l, keys_l, nu_s):
            gidx, midx = parts["indices"]()
            return parts["estimate_local"](p_l, b_l, keys_l, nu_s, gidx, midx)

        return compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(p_psp, b_psp, P(pop_s) if pop_s else P(), P()),
            out_specs=(losses_spec, p_psp),
            axis_names=parts["axis_names"], check_vma=False,
        )(state.params, batches, agent_keys, nu)

    def update(state, g):
        lr, _ = parts["round_scalars"](state.step)
        st_psp = parts["state_pspecs"](state)

        def shard_fn(p_l, g_l, o_l, lr_s):
            return parts["update_local"](p_l, g_l, o_l, lr_s)

        return compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(st_psp["params"], st_psp["params"],
                      st_psp["opt_state"], P()),
            out_specs=(st_psp["params"], st_psp["opt_state"]),
            axis_names=parts["axis_names"], check_vma=False,
        )(state.params, g, state.opt_state, lr)

    # mix() receives (state, new_params) and mixes new_params against
    # state.comm — the PhaseFns contract
    def mix_fn(state, new_params):
        t = state.step
        seeds_pay = parts["payload_seeds"](t)
        st_psp = parts["state_pspecs"](state)

        def shard_fn(p_l, c_l, seeds_full):
            gidx, _ = parts["indices"]()
            seeds_l = jax.lax.dynamic_slice(
                seeds_full, (gidx,), (parts["geom"].n_local,))
            return parts["mix_local_fn"](p_l, c_l, seeds_l, gidx)

        return compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(st_psp["params"], st_psp["comm"], P()),
            out_specs=(st_psp["params"], st_psp["comm"]),
            axis_names=parts["axis_names"], check_vma=False,
        )(new_params, state.comm, seeds_pay)

    if jit:
        estimate, update, mix_fn = (jax.jit(estimate), jax.jit(update),
                                    jax.jit(mix_fn))
    return PhaseFns(estimate, update, mix_fn,
                    dict(parts["diag_mixer"].diagnostics()))
