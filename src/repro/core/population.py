"""Per-agent resolution of heterogeneous HDO populations.

The paper's analysis is about *heterogeneous* cohorts — noisy,
possibly-biased ZO agents with different oracles coexisting with FO
agents — but the scalar ``HDOConfig`` knobs (``estimator_zo`` /
``sigma``-as-``nu`` / ``rv`` / ``lr``) describe one uniform ZO cohort.
This module turns the optional per-agent overrides (``cfg.sigmas``,
``cfg.rvs``, ``cfg.lrs``, ``cfg.estimators_zo``) into the static
per-agent tables ``build_hdo_step`` consumes:

  * every per-agent knob is defaulted from its scalar counterpart when
    the override is ``None``;
  * ZO agents are grouped by estimator kind (``KindGroup``), each group
    carrying the *static* padded draw count ``rv_max`` — agents with a
    smaller ``rv`` run the same program and mask their excess draws
    (``rv_actual`` threading through the estimators down to the
    ``zo_combine`` kernel's denominator operand);
  * a fully uniform population is collapsed back onto the homogeneous
    scalar path (``homogeneous=True`` + the ``kind0``/``sigma0``/
    ``rv0``/``lr0`` effective scalars), which pins the contract that
    all-equal per-agent values are *bit-identical* to not setting them.

Everything here is trace-time-static (plain Python / numpy): the
resolved tables become constants of the jitted step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple, TypeVar

import numpy as np

from repro.configs.base import HDOConfig

T = TypeVar("T")


@dataclasses.dataclass(frozen=True)
class KindGroup:
    """One estimator-kind cohort inside the ZO population."""

    kind: str
    indices: Tuple[int, ...]  # global agent indices (subset of 0..n0-1)
    rv_max: int  # static draw count the whole group is padded to


@dataclasses.dataclass(frozen=True)
class Population:
    """Static per-agent tables resolved from an ``HDOConfig``.

    ``kinds`` / ``sigmas`` / ``rvs`` have length ``n_zeroth`` (the ZO
    cohort, agents 0..n0-1); ``lrs`` has length ``n_agents``.
    """

    n_agents: int
    n_zeroth: int
    kinds: Tuple[str, ...]
    sigmas: Tuple[float, ...]
    rvs: Tuple[int, ...]
    lrs: Tuple[float, ...]
    homogeneous: bool
    groups: Tuple[KindGroup, ...]
    # effective scalars for the homogeneous (collapsed) path — fall back
    # to the config scalars when the ZO cohort is empty
    kind0: str
    sigma0: float
    rv0: int
    lr0: float

    # -- per-agent tables as arrays ------------------------------------
    def sigma_array(self) -> np.ndarray:
        return np.asarray(self.sigmas, np.float32)

    def rv_array(self) -> np.ndarray:
        return np.asarray(self.rvs, np.float32)

    def lr_array(self) -> np.ndarray:
        return np.asarray(self.lrs, np.float32)


def resolve_population(cfg: HDOConfig) -> Population:
    """Fill per-agent defaults from the scalar knobs and group by kind."""
    n, n0 = cfg.n_agents, cfg.n_zeroth
    kinds = cfg.estimators_zo if cfg.estimators_zo is not None else (cfg.estimator_zo,) * n0
    sigmas = cfg.sigmas if cfg.sigmas is not None else (cfg.nu,) * n0
    rvs = cfg.rvs if cfg.rvs is not None else (cfg.rv,) * n0
    lrs = cfg.lrs if cfg.lrs is not None else (cfg.lr,) * n

    homogeneous = (
        len(set(kinds)) <= 1
        and len(set(sigmas)) <= 1
        and len(set(rvs)) <= 1
        and len(set(lrs)) <= 1
    )

    groups = []
    for kind in dict.fromkeys(kinds):  # first-seen order, unique
        idx = tuple(i for i in range(n0) if kinds[i] == kind)
        groups.append(KindGroup(kind=kind, indices=idx,
                                rv_max=max(rvs[i] for i in idx)))

    return Population(
        n_agents=n, n_zeroth=n0, kinds=tuple(kinds), sigmas=tuple(sigmas),
        rvs=tuple(rvs), lrs=tuple(lrs), homogeneous=homogeneous,
        groups=tuple(groups),
        kind0=kinds[0] if n0 else cfg.estimator_zo,
        sigma0=sigmas[0] if n0 else cfg.nu,
        rv0=rvs[0] if n0 else cfg.rv,
        lr0=lrs[0],
    )


# ---------------------------------------------------------------------------
# CLI helpers (shared by launch/train.py and launch/dryrun.py so the two
# drivers parse the per-agent CSV flags identically)
# ---------------------------------------------------------------------------


def parse_csv(spec: Optional[str], cast: Callable[[str], T]) -> Optional[Tuple[T, ...]]:
    """``"a,b,c"`` -> ``(cast(a), cast(b), cast(c))``; None passes through.

    An empty segment (``"1e-3,,0.1"``) is an error, not silently
    dropped — ``tile`` would otherwise cycle a shorter pattern than the
    user wrote.
    """
    if spec is None:
        return None
    parts = [v.strip() for v in spec.split(",")]
    if not parts or any(not v for v in parts):
        raise ValueError(f"empty value in per-agent CSV spec {spec!r}")
    return tuple(cast(v) for v in parts)


def tile(vals: Optional[Sequence[T]], n: int) -> Optional[Tuple[T, ...]]:
    """Cycle ``vals`` to length ``n`` (CLI ergonomics: ``--sigmas
    1e-3,1e-1`` alternates over the cohort; a single value broadcasts)."""
    if vals is None:
        return None
    return tuple(vals[i % len(vals)] for i in range(n))
