"""Learning-rate schedules used by the paper's experiments:
linear warmup followed by cosine annealing (Loshchilov & Hutter 2017).
"""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(lr: float, warmup_steps: int, cosine_steps: int, use_cosine: bool = True):
    """Returns lr(step) -> f32 scalar."""

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(1.0, (step + 1.0) / jnp.maximum(warmup_steps, 1))
        if not use_cosine:
            return warm
        t = jnp.clip((step - warmup_steps) / jnp.maximum(cosine_steps - warmup_steps, 1), 0.0, 1.0)
        cos = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule


def constant(lr: float):
    def schedule(step):
        return jnp.asarray(lr, jnp.float32)

    return schedule
