"""The local-update phase of the HDO step (``HDOConfig.optimizer``).

``build_hdo_step`` used to hand-roll the paper's momentum-SGD rule
inline while the ``repro.optim`` ``(init, update)`` substrate sat
unused.  This module is the update-side sibling of the PR-3 ``Mixer``
refactor: one ``LocalUpdate`` object per optimizer, built at
trace-build time and called once per local substep between the
estimate and mix phases,

    new_params, new_opt_state = lu.apply(params, grads, opt_state,
                                         lr, lr_vec)

where every tree has the stacked leading ``n_agents`` axis.  The
``"sgd"`` instance reproduces the pre-refactor inline math *bit for
bit* (f32 accumulate, ``momentum_dtype`` write-back consumed by the
parameter update, per-agent ``lr_vec`` as a broadcast scale) — pinned
by tests/test_localupdate.py — and ``"adamw"`` plugs the
``optim.adamw`` transform into the same slot.  ``cfg.clip_norm > 0``
clips each agent's gradient by its own global norm
(``optim.clip_by_global_norm`` vmapped over the population) before the
optimizer update.

The perf half: ``use_kernel=True`` (default: on TPU only, like the
graph mixers) routes the momentum-SGD apply through the fused
``opt_apply`` Pallas kernel — each large leaf is raveled per agent and
the momentum update + parameter update stream in a single O(d) pass
instead of writing the momentum and reading it back; leaves smaller
than a kernel BLOCK (biases, norms — negligible traffic) keep the jnp
math rather than paying a tail-padded launch each (see
``kernels/opt_apply.py``; benched in ``BENCH_optim.json``).

Under ``HDOConfig.param_layout="plane"`` the stacked params are a
single BLOCK-aligned ``(n_agents, dim)`` leaf (``core/plane.py``): the
sgd machinery consumes it unchanged — one fused ``opt_apply`` launch
per agent, zero sub-BLOCK fallback leaves — and adamw switches to a
plane-shaped state with the fused ``adamw_apply`` kernel
(``_make_plane_adamw``), which is also where ``momentum_dtype``
extends from sgd momentum to the adamw first moment.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import OPTIMIZERS as OPTIMIZERS  # canonical tuple
from repro.configs.base import HDOConfig
from repro.kernels import ops
from repro.obs.trace import op_scope

PyTree = Any


def _scoped_apply(name: str, apply):
    """Trace-scope the optimizer apply (``op/<name>`` in HLO metadata /
    xprof) — annotation only, numerics untouched."""
    def wrapped(params, grads, opt_state, lr, lr_vec):
        with op_scope(name):
            return apply(params, grads, opt_state, lr, lr_vec)

    return wrapped

# per-agent flat size below which the kernel route is not worth a
# (tail-padded) pallas launch — small leaves use the jnp math instead.
# One kernel BLOCK: below this the pad would dominate the stream.
_KERNEL_MIN_SIZE = 8192


class LocalUpdate(NamedTuple):
    """One local optimizer: ``init`` builds the (stacked) opt state,
    ``apply`` runs clip -> optimizer update -> parameter update."""

    name: str
    init: Callable[[PyTree], PyTree]
    # (params, grads, opt_state, lr, lr_vec) -> (new_params, new_opt_state)
    apply: Callable[..., Tuple[PyTree, PyTree]]


def _apply_lr(params: PyTree, upd: PyTree, lr, lr_vec, n: int) -> PyTree:
    """x <- x - lr * u with f32 accumulate and params-dtype write-back;
    ``lr_vec`` (per-agent heterogeneity) broadcasts over the leading
    agent axis.  Bit-identical to the pre-refactor inline expressions
    (the homogeneous branch IS ``optim.apply_updates``)."""
    if lr_vec is None:
        return optim.apply_updates(params, upd, lr)

    def leaf(p, u):
        lrb = lr_vec.reshape((n,) + (1,) * (p.ndim - 1))
        return (p.astype(jnp.float32) - lrb * u).astype(p.dtype)

    return jax.tree.map(leaf, params, upd)


def make_local_update(cfg: HDOConfig, *,
                      use_kernel: Optional[bool] = None) -> LocalUpdate:
    """Builds the LocalUpdate for ``cfg.optimizer``.

    ``use_kernel`` routes the momentum-SGD apply through the fused
    ``opt_apply`` Pallas kernel; default off-TPU is the jnp/optim tree
    path (the interpret-friendly oracle, and the bit-identity surface
    the default config pins).
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    n = cfg.n_agents
    clip = float(cfg.clip_norm)

    def maybe_clip(grads):
        if clip <= 0.0:
            return grads
        # each agent clips by its OWN global norm — the population is n
        # independent local optimizers, not one big tree
        return jax.vmap(lambda t: optim.clip_by_global_norm(t, clip))(grads)

    if cfg.optimizer == "adamw":
        # cfg.momentum is the first-moment decay (b1) — the same knob it
        # is for sgd, so CLI sweeps over --momentum act on both rules —
        # and cfg.weight_decay is the decoupled decay (0 = plain Adam).
        if cfg.param_layout == "plane":
            return _make_plane_adamw(cfg, n, use_kernel, maybe_clip)
        # Tree-layout state stays f32 regardless of momentum_dtype: the
        # variance accumulator needs f32 range, and a bf16 mu would
        # break the resume-bit-identity contract unless the rounded
        # value also drove the update.  The plane layout ships exactly
        # that write-back discipline through the fused adamw kernel
        # (``_make_plane_adamw``), which is where momentum_dtype covers
        # the adamw first moment too.
        opt = optim.adamw(b1=cfg.momentum, weight_decay=cfg.weight_decay)

        def apply(params, grads, opt_state, lr, lr_vec):
            upd, new_state = opt.update(maybe_clip(grads), opt_state, params)
            return _apply_lr(params, upd, lr, lr_vec, n), new_state

        return LocalUpdate("adamw", opt.init, _scoped_apply("adamw_update", apply))

    # ---- "sgd": the paper's momentum-SGD rule ------------------------
    opt = optim.sgd(cfg.momentum)
    mdt = jnp.dtype(cfg.momentum_dtype)

    def init(stacked):
        if cfg.momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), stacked)

    def tree_sgd_leaf(p, g, m, lrb):
        """The exact tree-path math for one stacked leaf: momentum in
        f32, stored in m.dtype, the stored value consumed by the
        parameter update."""
        nm = (cfg.momentum * m.astype(jnp.float32)
              + (1.0 - cfg.momentum) * g.astype(jnp.float32)).astype(m.dtype)
        return (p.astype(jnp.float32) - lrb * nm).astype(p.dtype), nm

    def fused_apply(params, grads, opt_state, lr, lr_vec):
        """Per-leaf routing: leaves whose per-agent flat size reaches
        the kernel BLOCK stream through ``opt_apply`` (one fused O(d)
        pass per agent — the momentum never re-reads from HBM; on real
        models these leaves carry essentially all the traffic); small
        leaves (biases, norms) use the jnp math directly rather than
        each paying a tail-padded kernel launch.  Both routes compute
        the identical rounding chain."""
        lrs = (jnp.broadcast_to(jnp.asarray(lr, jnp.float32), (n,))
               if lr_vec is None else lr_vec)
        beta = jnp.float32(cfg.momentum)

        def leaf(p, g, m):
            if p.size // n >= _KERNEL_MIN_SIZE:
                po, mo = jax.vmap(
                    lambda pf, gf, mf, lrf: ops.opt_apply(pf, gf, mf, lrf, beta)
                )(p.reshape(n, -1), g.reshape(n, -1), m.reshape(n, -1), lrs)
                return po.reshape(p.shape), mo.reshape(m.shape)
            lrb = lrs.reshape((n,) + (1,) * (p.ndim - 1))
            return tree_sgd_leaf(p, g, m, lrb)

        pairs = jax.tree.map(leaf, params, grads, opt_state)
        return jax.tree_util.tree_transpose(
            jax.tree.structure(params), jax.tree.structure((0, 0)), pairs
        )

    def apply(params, grads, opt_state, lr, lr_vec):
        g = maybe_clip(grads)
        if cfg.momentum == 0.0:
            upd, _ = opt.update(g, opt_state, params)  # = f32(g)
            return _apply_lr(params, upd, lr, lr_vec, n), opt_state
        if use_kernel:
            return fused_apply(params, g, opt_state, lr, lr_vec)
        # pre-refactor bit-parity path: momentum accumulated in f32,
        # stored in momentum_dtype, and the *stored* (rounded) momentum
        # is what the parameter update consumes
        st = jax.tree.map(lambda m: m.astype(jnp.float32), opt_state)
        upd_f32, _ = opt.update(g, st, params)
        new_m = jax.tree.map(lambda u, m: u.astype(m.dtype), upd_f32, opt_state)
        return _apply_lr(params, new_m, lr, lr_vec, n), new_m

    return LocalUpdate("sgd", init, _scoped_apply("sgd_update", apply))


def _make_plane_adamw(cfg: HDOConfig, n: int, use_kernel: bool,
                      maybe_clip) -> LocalUpdate:
    """AdamW over the plane layout: params are one (n, dim) buffer, so
    the moments are matching plane streams — ``mu`` in
    ``cfg.momentum_dtype`` (the *stored*, possibly-bf16 value drives
    the update, the sgd kernel's write-back discipline, so resume
    replays the identical trajectory), ``nu`` f32 (range), ``count``
    a shared scalar.  ``use_kernel=True`` streams the whole update
    through the fused ``adamw_apply`` kernel — one O(d) pass per agent,
    no per-leaf dispatch and no sub-BLOCK fallback (the plane is
    BLOCK-aligned by construction); the jnp route computes the
    identical chain (the interpret-friendly oracle)."""
    b1 = float(cfg.momentum)
    b2 = 0.999
    eps = 1e-8
    wd = float(cfg.weight_decay)
    mdt = jnp.dtype(cfg.momentum_dtype)

    def init(stacked):
        return {
            "mu": jnp.zeros(stacked.shape, mdt),
            "nu": jnp.zeros(stacked.shape, jnp.float32),
            "count": jnp.zeros((), jnp.int32),
        }

    def apply(params, grads, opt_state, lr, lr_vec):
        g = maybe_clip(grads)
        c = opt_state["count"] + 1
        lrs = (jnp.broadcast_to(jnp.asarray(lr, jnp.float32), (n,))
               if lr_vec is None else lr_vec)
        if use_kernel:
            po, mu, nuv = jax.vmap(
                lambda pf, gf, mf, vf, lrf: ops.adamw_apply(
                    pf, gf, mf, vf, lrf, b1, b2, eps, wd, c)
            )(params, g, opt_state["mu"], opt_state["nu"], lrs)
        else:
            gf = g.astype(jnp.float32)
            pf = params.astype(jnp.float32)
            mu = (b1 * opt_state["mu"].astype(jnp.float32)
                  + (1.0 - b1) * gf).astype(mdt)
            nuv = b2 * opt_state["nu"] + (1.0 - b2) * gf * gf
            cf = c.astype(jnp.float32)
            bc1 = 1.0 - jnp.float32(b1) ** cf
            bc2 = 1.0 - jnp.float32(b2) ** cf
            upd = (mu.astype(jnp.float32) / bc1
                   / (jnp.sqrt(nuv / bc2) + eps) + wd * pf)
            po = (pf - lrs[:, None] * upd).astype(params.dtype)
        return po, {"mu": mu, "nu": nuv, "count": c}

    return LocalUpdate("adamw", init, _scoped_apply("adamw_plane_update", apply))


def opt_state_pspecs(cfg: HDOConfig, params_pspecs: PyTree) -> PyTree:
    """PartitionSpec tree for ``HDOState.opt_state`` given the params'
    spec tree (the opt state shards exactly like the params it tracks;
    scalar counters replicate).  Used by launch/dryrun.py."""
    from jax.sharding import PartitionSpec as P

    if cfg.optimizer == "sgd":
        return params_pspecs if cfg.momentum > 0.0 else ()
    return {"mu": params_pspecs, "nu": params_pspecs, "count": P()}
