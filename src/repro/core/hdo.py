"""The HDO training step (paper Algorithm 1, parallel simulation form).

One parallel step =
  1. every agent computes its local gradient estimate (FO agents:
     backprop; ZO agents: function-evaluation estimators),
  2. every agent takes a local (momentum-)SGD step,
  3. the population communicates through a ``Mixer`` (paper: O(n)
     random disjoint pairs average; beyond-paper: any doubly-stochastic
     scheme from ``repro.topology`` — round-robin tournaments,
     weighted graph topologies, all-reduce).

The population is carried as a stacked pytree with a leading
``n_agents`` axis (shardable over a mesh axis -> each agent's replica
lives on its own sub-mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro import compat
from repro.configs.base import HDOConfig
from repro.core import estimators, flatzo, population, schedules

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HDOState:
    params: PyTree  # leading axis n_agents
    momentum: PyTree
    step: jnp.ndarray  # scalar int32


def tree_stack_broadcast(params: PyTree, n: int) -> PyTree:
    """Replicate one model into a stacked population (paper: all agents
    start from the same random point)."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)


def init_state(params: PyTree, cfg: HDOConfig) -> HDOState:
    stacked = tree_stack_broadcast(params, cfg.n_agents)
    mdt = jnp.dtype(cfg.momentum_dtype)
    mom = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=mdt), stacked)
    return HDOState(params=stacked, momentum=mom, step=jnp.int32(0))


def zo_mask(cfg: HDOConfig) -> jnp.ndarray:
    """True for zeroth-order agents (paper: agents 1..n0 are ZO)."""
    return jnp.arange(cfg.n_agents) < cfg.n_zeroth


def _select_tree(mask_agents, a: PyTree, b: PyTree) -> PyTree:
    """where(mask) over leading agent axis: a if mask else b."""
    def sel(x, y):
        m = mask_agents.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)

    return jax.tree.map(sel, a, b)


def build_hdo_step(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    cfg: HDOConfig,
    *,
    param_dim: Optional[int] = None,
    donate: bool = False,
    mesh=None,
    population_axes: Tuple[str, ...] = (),
) -> Callable[[HDOState, Any], Tuple[HDOState, Dict[str, jnp.ndarray]]]:
    """Returns step(state, batches) -> (state, metrics).

    ``loss_fn(params, batch)`` is a single-agent loss; ``batches`` is a
    pytree whose leaves have leading axis ``n_agents`` (each agent's
    local shard of the data — the paper's split-data setup).

    ``donate=True`` returns the step already jitted with the incoming
    state's buffers donated (in-place update of params/momentum — the
    caller must rebind ``state = step(state, ...)`` and never reuse the
    old state).  The default returns the raw traceable function so
    callers can apply their own ``jax.jit`` (e.g. with shardings, as
    ``launch/dryrun.py`` does).

    ``dispatch="shard_cond"`` additionally needs ``mesh`` +
    ``population_axes``: the estimation phase runs under a partial
    ``shard_map`` over the population axes with a *runtime* branch on
    the shard's agent type, so ZO devices never build the backward pass
    (HLO conditionals are dynamic).  The shard_map gossip lowerings
    (``gossip="rr_ppermute"`` / ``"graph_ppermute"``) need the same two
    arguments plus one agent per population shard.

    Heterogeneous populations (``cfg.sigmas`` / ``rvs`` / ``lrs`` /
    ``estimators_zo``, see ``core/population.py``) run a grouped
    variant of the select/split machinery: ZO agents are grouped by
    estimator kind, each group padded to its ``rv_max`` draw count with
    masked excess draws, and per-group gradient-estimate variance is
    logged as ``grad_var_zo_<kind>`` / ``grad_var_fo`` metrics.
    ``dispatch="shard_cond"`` requires a homogeneous cohort; an
    all-equal per-agent override collapses onto the homogeneous path
    bit-identically (tests/test_population.py).
    """
    # deferred: topology depends on core.gossip's primitives, so a
    # module-level import here would cycle through repro.core.__init__
    from repro.topology.mixer import make_mixer, shard_agent_index

    n = cfg.n_agents
    # per-agent sigma/rv/lr tables + estimator-kind groups; a fully
    # uniform population collapses onto the scalar path below, which is
    # what pins "all-equal per-agent values == homogeneous" bit-exactly
    pop = population.resolve_population(cfg)
    if not pop.homogeneous and cfg.dispatch == "shard_cond":
        raise ValueError(
            "dispatch='shard_cond' needs a homogeneous ZO cohort (one "
            "estimator kind, uniform sigma/rv/lr); use 'select' or 'split' "
            "for heterogeneous populations"
        )
    sched = schedules.warmup_cosine(
        pop.lr0 if pop.homogeneous else cfg.lr,
        cfg.warmup_steps, cfg.cosine_steps, cfg.use_cosine,
    )
    is_zo = zo_mask(cfg)
    mixer = make_mixer(cfg, mesh=mesh, population_axes=population_axes)
    mixer_metrics = {
        k: jnp.float32(v) for k, v in mixer.diagnostics().items()
    }

    def per_agent_fo(params_i, batch_i):
        return estimators.fo_estimate(lambda p: loss_fn(p, batch_i), params_i)

    # every estimator kind has a fused form (fwd_grad since the
    # zo_tangent kernel landed) — "fused" never falls back to the tree
    use_fused = cfg.zo_impl == "fused"
    zo_engine = flatzo.flat_zo_estimate if use_fused else estimators.zo_estimate

    def per_agent_zo(params_i, batch_i, key_i, nu):
        return zo_engine(
            lambda p: loss_fn(p, batch_i),
            params_i,
            key_i,
            kind=pop.kind0,
            rv=pop.rv0,
            nu=nu,
        )

    # -- heterogeneous cohort machinery (trace-time constants; only
    #    built when the population is genuinely heterogeneous) ----------
    if pop.homogeneous:
        lr_rel = sigma_tab = rv_tab = None
    else:
        if cfg.lr <= 0:
            raise ValueError(
                "heterogeneous lrs scale the shared schedule, which is "
                f"anchored at cfg.lr — cfg.lr must be > 0, got {cfg.lr}"
            )
        # per-agent lr enters as a scale on the shared schedule shape:
        # lr_i(t) = sched(t) * lrs[i] / cfg.lr
        lr_rel = jnp.asarray(pop.lr_array() / np.float32(cfg.lr))
        sigma_tab = jnp.asarray(pop.sigma_array())
        rv_tab = jnp.asarray(pop.rv_array())

    def zo_for_kind(kind, rv_max):
        """Uniform program for one kind group, padded to rv_max draws;
        agents with rv_i < rv_max mask the excess (rv_actual)."""
        def f(params_i, batch_i, key_i, nu_i, rv_i):
            return zo_engine(
                lambda p: loss_fn(p, batch_i), params_i, key_i,
                kind=kind, rv=rv_max, nu=nu_i, rv_actual=rv_i,
            )
        return f

    def het_split(params, batches, agent_keys, nu_vec):
        """Grouped "split" dispatch: each kind group computes ONLY its
        own estimator on a static gather of its agents, then the parts
        are reassembled through the static inverse permutation."""
        n0 = cfg.n_zeroth
        order, loss_parts, g_parts = [], [], []
        for grp in pop.groups:
            idx = np.asarray(grp.indices)
            take = lambda t, _i=idx: jax.tree.map(lambda x: x[_i], t)
            l_k, g_k = jax.vmap(zo_for_kind(grp.kind, grp.rv_max))(
                take(params), take(batches), agent_keys[idx],
                nu_vec[idx], rv_tab[idx],
            )
            order += list(grp.indices)
            loss_parts.append(l_k)
            g_parts.append(g_k)
        if cfg.n_first:
            tail = lambda t: jax.tree.map(lambda x: x[n0:], t)
            l_fo, g_fo = jax.vmap(per_agent_fo)(tail(params), tail(batches))
            order += list(range(n0, n))
            loss_parts.append(l_fo)
            g_parts.append(g_fo)
        inv = np.argsort(np.asarray(order))
        g = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0)[inv], *g_parts)
        losses = jnp.concatenate(loss_parts)[inv]
        return losses, g

    def het_select(params, batches, agent_keys, nu_vec):
        """Grouped "select" dispatch (paper-faithful uniform program):
        every kind group runs over the WHOLE anonymous population and
        its agents are masked in via ``_select_tree`` — 1 + n_groups
        full passes, the price of SPMD uniformity."""
        n0 = cfg.n_zeroth
        if cfg.n_first > 0:
            losses, g = jax.vmap(per_agent_fo)(params, batches)
        else:
            losses = jnp.zeros((n,), jnp.float32)
            g = jax.tree.map(jnp.zeros_like, params)
        # pad the ZO tables over the FO rows (masked out; the pad values
        # only need to keep the arithmetic finite)
        pad = jnp.ones((n - n0,), jnp.float32)
        nu_full = jnp.concatenate([nu_vec, pad])
        rv_full = jnp.concatenate([rv_tab, pad])
        for grp in pop.groups:
            l_k, g_k = jax.vmap(zo_for_kind(grp.kind, grp.rv_max))(
                params, batches, agent_keys, nu_full, rv_full
            )
            mask = np.zeros((n,), bool)
            mask[list(grp.indices)] = True
            mask = jnp.asarray(mask)
            g = _select_tree(mask, g_k, g)
            losses = jnp.where(mask, l_k, losses)
        return losses, g

    def subset_var(tree, idx):
        """Per-group gradient-estimate variance: (1/|G|) sum_{i in G}
        ||g_i - mean_G||^2 over the flattened estimates."""
        idx = np.asarray(list(idx))

        def v(x):
            xs = x[idx].astype(jnp.float32)
            mu = xs.mean(0, keepdims=True)
            return jnp.sum((xs - mu) ** 2) / idx.size

        return sum(jax.tree.leaves(jax.tree.map(v, tree)))

    def step(state: HDOState, batches) -> Tuple[HDOState, Dict[str, jnp.ndarray]]:
        t = state.step
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t)
        lr = sched(t)
        nu = (
            lr / jnp.sqrt(jnp.float32(param_dim))
            if (cfg.nu_from_lr and param_dim)
            else jnp.float32(pop.sigma0)
        )
        lr_vec = None if pop.homogeneous else lr * lr_rel  # (n,)

        agent_keys = jax.random.split(key, n)

        # ---- local estimates -------------------------------------------
        n0 = cfg.n_zeroth
        if not pop.homogeneous:
            # heterogeneous cohort: per-agent (sigma, rv, lr), possibly
            # mixed estimator kinds — grouped select/split dispatch
            if cfg.nu_from_lr and param_dim:
                nu_vec = lr_vec[:n0] / jnp.sqrt(jnp.float32(param_dim))
            else:
                nu_vec = sigma_tab
            if cfg.dispatch == "split":
                losses, g = het_split(state.params, batches, agent_keys, nu_vec)
            else:
                losses, g = het_select(state.params, batches, agent_keys, nu_vec)
        elif n == 1:
            # single-agent population (e.g. llama4 pod-population on the
            # single-pod mesh): skip vmap so inner shard_map layers (the
            # expert-parallel MoE path) remain top-level collectives.
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            if n0 == 1:
                l1, g1 = per_agent_zo(sq(state.params), sq(batches), agent_keys[0], nu)
            else:
                l1, g1 = per_agent_fo(sq(state.params), sq(batches))
            losses = l1[None]
            g = jax.tree.map(lambda x: x[None], g1)
        elif cfg.dispatch == "shard_cond" and 0 < n0 < n and mesh is not None:
            from jax.sharding import PartitionSpec as P

            pop_axes = tuple(a for a in population_axes if a in mesh.shape)
            pop_size = 1
            for a in pop_axes:
                pop_size *= mesh.shape[a]
            n_local = n // pop_size
            assert n0 % n_local == 0, "ZO/FO boundary must align with shards"

            def shard_fn(p_l, b_l, k_l, nu_s):
                idx = shard_agent_index(mesh, pop_axes, n_local)
                is_zo_shard = idx < n0

                def zo_branch(_):
                    return jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu_s))(
                        p_l, b_l, k_l
                    )

                def fo_branch(_):
                    return jax.vmap(per_agent_fo)(p_l, b_l)

                return jax.lax.cond(is_zo_shard, zo_branch, fo_branch, None)

            pspec = P(pop_axes if len(pop_axes) > 1 else pop_axes[0])
            # keys are threefry-derived from the traced step counter;
            # without this pin XLA partitions the key computation and
            # the 0.4.x lowering produces wrong bits (see compat)
            agent_keys = compat.replicate_operand(agent_keys, mesh)
            losses, g = compat.shard_map(
                shard_fn,
                mesh=mesh,
                in_specs=(pspec, pspec, pspec, P()),
                out_specs=(pspec, pspec),
                axis_names=set(pop_axes),
                check_vma=False,
            )(state.params, batches, agent_keys, nu)
        elif cfg.dispatch == "split" and 0 < n0 < n:
            # beyond-paper: agents are sorted (ZO first), so slicing the
            # stacked population lets every device compute ONLY its own
            # estimator kind (no masked double work).
            take = lambda t, sl: jax.tree.map(lambda x: x[sl], t)
            loss_zo, g_zo = jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu))(
                take(state.params, slice(0, n0)), take(batches, slice(0, n0)),
                agent_keys[:n0],
            )
            loss_fo, g_fo = jax.vmap(per_agent_fo)(
                take(state.params, slice(n0, n)), take(batches, slice(n0, n))
            )
            g = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), g_zo, g_fo)
            losses = jnp.concatenate([loss_zo, loss_fo])
        else:
            # paper-faithful SPMD-uniform baseline: both estimators are
            # computed for every (anonymous) agent, then masked.
            if cfg.n_first > 0:
                loss_fo, g_fo = jax.vmap(per_agent_fo)(state.params, batches)
            else:
                loss_fo = jnp.zeros((n,), jnp.float32)
                g_fo = jax.tree.map(jnp.zeros_like, state.params)
            if cfg.n_zeroth > 0:
                loss_zo, g_zo = jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu))(
                    state.params, batches, agent_keys
                )
            else:
                loss_zo = jnp.zeros((n,), jnp.float32)
                g_zo = jax.tree.map(jnp.zeros_like, state.params)

            g = _select_tree(is_zo, g_zo, g_fo)
            losses = jnp.where(is_zo, loss_zo, loss_fo)

        # ---- local momentum-SGD step (paper: g <- m g + (1-m) grad) ---
        if cfg.momentum > 0.0:
            new_mom = jax.tree.map(
                lambda m, gi: (
                    cfg.momentum * m.astype(jnp.float32)
                    + (1.0 - cfg.momentum) * gi.astype(jnp.float32)
                ).astype(m.dtype),
                state.momentum,
                g,
            )
            upd = new_mom
        else:
            new_mom = state.momentum
            upd = jax.tree.map(lambda gi: gi.astype(jnp.float32), g)

        if pop.homogeneous:
            new_params = jax.tree.map(
                lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
                state.params,
                upd,
            )
        else:
            def upd_leaf(p, u):
                lrb = lr_vec.reshape((n,) + (1,) * (p.ndim - 1))
                return (p.astype(jnp.float32) - lrb * u).astype(p.dtype)

            new_params = jax.tree.map(upd_leaf, state.params, upd)

        # ---- gossip (the Mixer interaction step) ----------------------
        gkey = jax.random.fold_in(key, 7)
        new_params = mixer(new_params, key=gkey, step=t)

        metrics = {
            "loss_mean": losses.mean(),
            "loss_std": losses.std(),
            "lr": lr,
            **mixer_metrics,
        }
        if cfg.n_first:
            metrics["loss_fo_mean"] = losses[cfg.n_zeroth :].mean()
        if cfg.n_zeroth:
            metrics["loss_zo_mean"] = losses[: cfg.n_zeroth].mean()
        if not pop.homogeneous:
            # per-group gradient-estimate variance — the heterogeneity
            # diagnostics next to consensus_distance (high-sigma /
            # low-rv groups show up as high-variance estimators)
            for grp in pop.groups:
                metrics[f"grad_var_zo_{grp.kind}"] = subset_var(g, grp.indices)
            if cfg.n_first:
                metrics["grad_var_fo"] = subset_var(g, range(n0, n))
        return HDOState(params=new_params, momentum=new_mom, step=t + 1), metrics

    if donate:
        return jax.jit(step, donate_argnums=(0,))
    return step


def consensus_distance(params: PyTree) -> jnp.ndarray:
    """Gamma_t = (1/n) sum_i ||X_i - mu||^2 (the paper's potential)."""
    def gamma(x):
        mu = x.mean(axis=0, keepdims=True)
        return jnp.sum((x.astype(jnp.float32) - mu.astype(jnp.float32)) ** 2) / x.shape[0]

    return sum(jax.tree.leaves(jax.tree.map(gamma, params)))
