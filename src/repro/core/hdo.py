"""The HDO training step (paper Algorithm 1, parallel simulation form).

One parallel step =
  1. every agent computes its local gradient estimate (FO agents:
     backprop; ZO agents: function-evaluation estimators),
  2. every agent takes a local (momentum-)SGD step,
  3. the population communicates through a ``Mixer`` (paper: O(n)
     random disjoint pairs average; beyond-paper: any doubly-stochastic
     scheme from ``repro.topology`` — round-robin tournaments,
     weighted graph topologies, all-reduce).

The population is carried as a stacked pytree with a leading
``n_agents`` axis (shardable over a mesh axis -> each agent's replica
lives on its own sub-mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import HDOConfig
from repro.core import estimators, flatzo, schedules

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HDOState:
    params: PyTree  # leading axis n_agents
    momentum: PyTree
    step: jnp.ndarray  # scalar int32


def tree_stack_broadcast(params: PyTree, n: int) -> PyTree:
    """Replicate one model into a stacked population (paper: all agents
    start from the same random point)."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)


def init_state(params: PyTree, cfg: HDOConfig) -> HDOState:
    stacked = tree_stack_broadcast(params, cfg.n_agents)
    mdt = jnp.dtype(cfg.momentum_dtype)
    mom = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=mdt), stacked)
    return HDOState(params=stacked, momentum=mom, step=jnp.int32(0))


def zo_mask(cfg: HDOConfig) -> jnp.ndarray:
    """True for zeroth-order agents (paper: agents 1..n0 are ZO)."""
    return jnp.arange(cfg.n_agents) < cfg.n_zeroth


def _select_tree(mask_agents, a: PyTree, b: PyTree) -> PyTree:
    """where(mask) over leading agent axis: a if mask else b."""
    def sel(x, y):
        m = mask_agents.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)

    return jax.tree.map(sel, a, b)


def build_hdo_step(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    cfg: HDOConfig,
    *,
    param_dim: Optional[int] = None,
    donate: bool = False,
    mesh=None,
    population_axes: Tuple[str, ...] = (),
) -> Callable[[HDOState, Any], Tuple[HDOState, Dict[str, jnp.ndarray]]]:
    """Returns step(state, batches) -> (state, metrics).

    ``loss_fn(params, batch)`` is a single-agent loss; ``batches`` is a
    pytree whose leaves have leading axis ``n_agents`` (each agent's
    local shard of the data — the paper's split-data setup).

    ``donate=True`` returns the step already jitted with the incoming
    state's buffers donated (in-place update of params/momentum — the
    caller must rebind ``state = step(state, ...)`` and never reuse the
    old state).  The default returns the raw traceable function so
    callers can apply their own ``jax.jit`` (e.g. with shardings, as
    ``launch/dryrun.py`` does).

    ``dispatch="shard_cond"`` additionally needs ``mesh`` +
    ``population_axes``: the estimation phase runs under a partial
    ``shard_map`` over the population axes with a *runtime* branch on
    the shard's agent type, so ZO devices never build the backward pass
    (HLO conditionals are dynamic).  The shard_map gossip lowerings
    (``gossip="rr_ppermute"`` / ``"graph_ppermute"``) need the same two
    arguments plus one agent per population shard.
    """
    # deferred: topology depends on core.gossip's primitives, so a
    # module-level import here would cycle through repro.core.__init__
    from repro.topology.mixer import make_mixer, shard_agent_index

    n = cfg.n_agents
    sched = schedules.warmup_cosine(cfg.lr, cfg.warmup_steps, cfg.cosine_steps, cfg.use_cosine)
    is_zo = zo_mask(cfg)
    mixer = make_mixer(cfg, mesh=mesh, population_axes=population_axes)
    mixer_metrics = {
        k: jnp.float32(v) for k, v in mixer.diagnostics().items()
    }

    def per_agent_fo(params_i, batch_i):
        return estimators.fo_estimate(lambda p: loss_fn(p, batch_i), params_i)

    # every estimator kind has a fused form (fwd_grad since the
    # zo_tangent kernel landed) — "fused" never falls back to the tree
    use_fused = cfg.zo_impl == "fused"

    def per_agent_zo(params_i, batch_i, key_i, nu):
        if use_fused:
            return flatzo.flat_zo_estimate(
                lambda p: loss_fn(p, batch_i),
                params_i,
                key_i,
                kind=cfg.estimator_zo,
                rv=cfg.rv,
                nu=nu,
            )
        return estimators.zo_estimate(
            lambda p: loss_fn(p, batch_i),
            params_i,
            key_i,
            kind=cfg.estimator_zo,
            rv=cfg.rv,
            nu=nu,
        )

    def step(state: HDOState, batches) -> Tuple[HDOState, Dict[str, jnp.ndarray]]:
        t = state.step
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t)
        lr = sched(t)
        nu = (
            lr / jnp.sqrt(jnp.float32(param_dim))
            if (cfg.nu_from_lr and param_dim)
            else jnp.float32(cfg.nu)
        )

        agent_keys = jax.random.split(key, n)

        # ---- local estimates -------------------------------------------
        n0 = cfg.n_zeroth
        if n == 1:
            # single-agent population (e.g. llama4 pod-population on the
            # single-pod mesh): skip vmap so inner shard_map layers (the
            # expert-parallel MoE path) remain top-level collectives.
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            if n0 == 1:
                l1, g1 = per_agent_zo(sq(state.params), sq(batches), agent_keys[0], nu)
            else:
                l1, g1 = per_agent_fo(sq(state.params), sq(batches))
            losses = l1[None]
            g = jax.tree.map(lambda x: x[None], g1)
        elif cfg.dispatch == "shard_cond" and 0 < n0 < n and mesh is not None:
            from jax.sharding import PartitionSpec as P

            pop_axes = tuple(a for a in population_axes if a in mesh.shape)
            pop_size = 1
            for a in pop_axes:
                pop_size *= mesh.shape[a]
            n_local = n // pop_size
            assert n0 % n_local == 0, "ZO/FO boundary must align with shards"

            def shard_fn(p_l, b_l, k_l, nu_s):
                idx = shard_agent_index(mesh, pop_axes, n_local)
                is_zo_shard = idx < n0

                def zo_branch(_):
                    return jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu_s))(
                        p_l, b_l, k_l
                    )

                def fo_branch(_):
                    return jax.vmap(per_agent_fo)(p_l, b_l)

                return jax.lax.cond(is_zo_shard, zo_branch, fo_branch, None)

            pspec = P(pop_axes if len(pop_axes) > 1 else pop_axes[0])
            # keys are threefry-derived from the traced step counter;
            # without this pin XLA partitions the key computation and
            # the 0.4.x lowering produces wrong bits (see compat)
            agent_keys = compat.replicate_operand(agent_keys, mesh)
            losses, g = compat.shard_map(
                shard_fn,
                mesh=mesh,
                in_specs=(pspec, pspec, pspec, P()),
                out_specs=(pspec, pspec),
                axis_names=set(pop_axes),
                check_vma=False,
            )(state.params, batches, agent_keys, nu)
        elif cfg.dispatch == "split" and 0 < n0 < n:
            # beyond-paper: agents are sorted (ZO first), so slicing the
            # stacked population lets every device compute ONLY its own
            # estimator kind (no masked double work).
            take = lambda t, sl: jax.tree.map(lambda x: x[sl], t)
            loss_zo, g_zo = jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu))(
                take(state.params, slice(0, n0)), take(batches, slice(0, n0)),
                agent_keys[:n0],
            )
            loss_fo, g_fo = jax.vmap(per_agent_fo)(
                take(state.params, slice(n0, n)), take(batches, slice(n0, n))
            )
            g = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), g_zo, g_fo)
            losses = jnp.concatenate([loss_zo, loss_fo])
        else:
            # paper-faithful SPMD-uniform baseline: both estimators are
            # computed for every (anonymous) agent, then masked.
            if cfg.n_first > 0:
                loss_fo, g_fo = jax.vmap(per_agent_fo)(state.params, batches)
            else:
                loss_fo = jnp.zeros((n,), jnp.float32)
                g_fo = jax.tree.map(jnp.zeros_like, state.params)
            if cfg.n_zeroth > 0:
                loss_zo, g_zo = jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu))(
                    state.params, batches, agent_keys
                )
            else:
                loss_zo = jnp.zeros((n,), jnp.float32)
                g_zo = jax.tree.map(jnp.zeros_like, state.params)

            g = _select_tree(is_zo, g_zo, g_fo)
            losses = jnp.where(is_zo, loss_zo, loss_fo)

        # ---- local momentum-SGD step (paper: g <- m g + (1-m) grad) ---
        if cfg.momentum > 0.0:
            new_mom = jax.tree.map(
                lambda m, gi: (
                    cfg.momentum * m.astype(jnp.float32)
                    + (1.0 - cfg.momentum) * gi.astype(jnp.float32)
                ).astype(m.dtype),
                state.momentum,
                g,
            )
            upd = new_mom
        else:
            new_mom = state.momentum
            upd = jax.tree.map(lambda gi: gi.astype(jnp.float32), g)

        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
            state.params,
            upd,
        )

        # ---- gossip (the Mixer interaction step) ----------------------
        gkey = jax.random.fold_in(key, 7)
        new_params = mixer(new_params, key=gkey, step=t)

        metrics = {
            "loss_mean": losses.mean(),
            "loss_std": losses.std(),
            "lr": lr,
            **mixer_metrics,
        }
        if cfg.n_first:
            metrics["loss_fo_mean"] = losses[cfg.n_zeroth :].mean()
        if cfg.n_zeroth:
            metrics["loss_zo_mean"] = losses[: cfg.n_zeroth].mean()
        return HDOState(params=new_params, momentum=new_mom, step=t + 1), metrics

    if donate:
        return jax.jit(step, donate_argnums=(0,))
    return step


def consensus_distance(params: PyTree) -> jnp.ndarray:
    """Gamma_t = (1/n) sum_i ||X_i - mu||^2 (the paper's potential)."""
    def gamma(x):
        mu = x.mean(axis=0, keepdims=True)
        return jnp.sum((x.astype(jnp.float32) - mu.astype(jnp.float32)) ** 2) / x.shape[0]

    return sum(jax.tree.leaves(jax.tree.map(gamma, params)))
