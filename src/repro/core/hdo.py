"""The HDO training step (paper Algorithm 1, parallel simulation form).

One parallel round is an **estimate -> update -> mix** pipeline:

  1. estimate — every agent computes its local gradient estimate (FO
     agents: backprop; ZO agents: function-evaluation estimators),
     through the select / split / shard_cond dispatch machinery
     (``build_estimate_phase``),
  2. local update — every agent takes a local optimizer step through a
     ``LocalUpdate`` (``core.localupdate``, backed by ``repro.optim``:
     the paper's momentum-SGD, or AdamW),
  3. mix — the population communicates through a ``Mixer`` (paper:
     O(n) random disjoint pairs average; beyond-paper: any
     doubly-stochastic scheme from ``repro.topology``).

``HDOConfig.local_steps = H > 1`` runs H estimate+update iterations
per round (``lax.scan`` over per-substep folded keys AND per-substep
batch slices — every batches leaf carries a leading H axis) before the
single mix — the periodic-averaging communication/computation
trade-off of Omidvar et al. / Sahu et al.; the Mixer still runs
exactly once per round, so ``consensus_distance`` / spectral
diagnostics keep lining up per *round*.

Communication-reduced / fault-tolerant gossip (``cfg.compression``,
``cfg.staleness``, ``cfg.fault_*``) threads a communication state —
error-feedback residuals, stale-broadcast buffers — through the round
as ``HDOState.comm`` (``()`` for plain configs, so existing states and
checkpoints are structurally unchanged).

The population is carried as a stacked pytree with a leading
``n_agents`` axis (shardable over a mesh axis -> each agent's replica
lives on its own sub-mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro import compat
from repro.configs.base import HDOConfig
from repro.core import estimators, flatzo, localupdate, population, schedules
from repro.core import plane as planelib
from repro.obs.trace import phase_scope

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HDOState:
    params: PyTree  # leading axis n_agents
    # optimizer state of the LocalUpdate: the stacked momentum pytree
    # for "sgd" (momentum > 0; () otherwise), {"mu","nu","count"} for
    # "adamw" — generalizes the old ``momentum`` field
    opt_state: PyTree
    step: jnp.ndarray  # scalar int32
    # communication state of the Mixer (topology.compress.init_comm):
    # error-feedback residuals / stale-broadcast buffers, mirroring the
    # params layout; () for plain configs
    comm: PyTree = ()


def tree_stack_broadcast(params: PyTree, n: int) -> PyTree:
    """Replicate one model into a stacked population (paper: all agents
    start from the same random point)."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)


def init_state(params: PyTree, cfg: HDOConfig) -> HDOState:
    """Stacked population state from one model pytree.

    ``cfg.param_layout="plane"`` packs the pytree into the persistent
    BLOCK-aligned flat buffer (``core/plane.py``): ``state.params`` is
    a single ``(n_agents, dim)`` array and the opt state holds matching
    plane streams; ``"tree"`` keeps the stacked-pytree layout.
    """
    if cfg.param_layout == "plane":
        man = planelib.build_manifest(params)
        flat = planelib.pack(man, params)
        stacked = jnp.broadcast_to(flat[None], (cfg.n_agents,) + flat.shape)
    else:
        stacked = tree_stack_broadcast(params, cfg.n_agents)
    lu = localupdate.make_local_update(cfg)
    # deferred for the same core<->topology cycle as build_hdo_step
    from repro.topology import compress as compresslib

    return HDOState(params=stacked, opt_state=lu.init(stacked),
                    step=jnp.int32(0),
                    comm=compresslib.init_comm(cfg, stacked))


def zo_mask(cfg: HDOConfig) -> jnp.ndarray:
    """True for zeroth-order agents (paper: agents 1..n0 are ZO)."""
    return jnp.arange(cfg.n_agents) < cfg.n_zeroth


def _select_tree(mask_agents, a: PyTree, b: PyTree) -> PyTree:
    """where(mask) over leading agent axis: a if mask else b."""
    def sel(x, y):
        m = mask_agents.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)

    return jax.tree.map(sel, a, b)


def build_estimate_phase(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    cfg: HDOConfig,
    *,
    mesh=None,
    population_axes: Tuple[str, ...] = (),
    manifest: Optional[planelib.PlaneManifest] = None,
) -> Callable[..., Tuple[jnp.ndarray, PyTree]]:
    """Phase 1 of the step: the per-agent gradient-estimate dispatch.

    Returns ``estimate(params, batches, agent_keys, nu, nu_vec)`` ->
    ``(losses, g)``, both with leading axis ``n_agents``.  ``nu`` is the
    homogeneous smoothing radius (scalar); ``nu_vec`` the per-ZO-agent
    radii of a heterogeneous cohort (ignored when homogeneous).  All
    dispatch variants (select / split / shard_cond, grouped
    heterogeneous select / split / shard_cond, the single-agent fast
    path) live here; the estimator contracts are untouched.

    ``cfg.param_layout="plane"`` needs ``manifest`` (from
    ``plane.build_manifest`` of the single-agent model): per-agent
    params arrive as plane rows, the fused engine runs the plane
    kernels directly, and the tree estimators / FO backprop see the
    pytree only at the loss boundary (``plane.unpack``).

    A heterogeneous ``dispatch="shard_cond"`` cohort runs a runtime
    ``lax.switch`` per population shard over the kind groups' uniform
    programs — every shard must hold agents of a single kind group
    (ValueError at build time otherwise); without a mesh it falls back
    to the grouped select path, like the homogeneous fallthrough.
    """
    from repro.topology.mixer import shard_agent_index

    n = cfg.n_agents
    pop = population.resolve_population(cfg)
    rv_tab = None if pop.homogeneous else jnp.asarray(pop.rv_array())

    use_plane = cfg.param_layout == "plane"
    if use_plane and manifest is None:
        raise ValueError(
            "param_layout='plane' needs the leaf manifest — pass "
            "manifest=plane.build_manifest(params) (build_hdo_step does "
            "this from its params_template argument)"
        )
    unpack = (lambda v: planelib.unpack(manifest, v)) if use_plane else None

    if use_plane:
        def per_agent_fo(x_i, batch_i):
            # backprop at the model-apply boundary: grads are taken on
            # the unpacked pytree (the exact tree-layout graph at the
            # same bits) and packed back into a plane row
            l_i, g_tree = estimators.fo_estimate(
                lambda p: loss_fn(p, batch_i), unpack(x_i)
            )
            return l_i, planelib.pack(manifest, g_tree)
    else:
        def per_agent_fo(params_i, batch_i):
            return estimators.fo_estimate(lambda p: loss_fn(p, batch_i), params_i)

    # every estimator kind has a fused form (fwd_grad since the
    # zo_tangent kernel landed) — "fused" never falls back to the tree
    use_fused = cfg.zo_impl == "fused"
    if use_plane and use_fused:
        def zo_engine(loss, x_i, key_i, **kw):
            return flatzo.plane_zo_estimate(loss, x_i, key_i,
                                            manifest=manifest, **kw)
    elif use_plane:
        def zo_engine(loss, x_i, key_i, **kw):
            l_i, g_tree = estimators.zo_estimate(loss, unpack(x_i), key_i, **kw)
            return l_i, planelib.pack(manifest, g_tree)
    else:
        zo_engine = flatzo.flat_zo_estimate if use_fused else estimators.zo_estimate

    def per_agent_zo(params_i, batch_i, key_i, nu):
        return zo_engine(
            lambda p: loss_fn(p, batch_i),
            params_i,
            key_i,
            kind=pop.kind0,
            rv=pop.rv0,
            nu=nu,
        )

    def zo_for_kind(kind, rv_max):
        """Uniform program for one kind group, padded to rv_max draws;
        agents with rv_i < rv_max mask the excess (rv_actual)."""
        def f(params_i, batch_i, key_i, nu_i, rv_i):
            return zo_engine(
                lambda p: loss_fn(p, batch_i), params_i, key_i,
                kind=kind, rv=rv_max, nu=nu_i, rv_actual=rv_i,
            )
        return f

    def het_split(params, batches, agent_keys, nu_vec):
        """Grouped "split" dispatch: each kind group computes ONLY its
        own estimator on a static gather of its agents, then the parts
        are reassembled through the static inverse permutation."""
        n0 = cfg.n_zeroth
        order, loss_parts, g_parts = [], [], []
        for grp in pop.groups:
            idx = np.asarray(grp.indices)
            take = lambda t, _i=idx: jax.tree.map(lambda x: x[_i], t)
            l_k, g_k = jax.vmap(zo_for_kind(grp.kind, grp.rv_max))(
                take(params), take(batches), agent_keys[idx],
                nu_vec[idx], rv_tab[idx],
            )
            order += list(grp.indices)
            loss_parts.append(l_k)
            g_parts.append(g_k)
        if cfg.n_first:
            tail = lambda t: jax.tree.map(lambda x: x[n0:], t)
            l_fo, g_fo = jax.vmap(per_agent_fo)(tail(params), tail(batches))
            order += list(range(n0, n))
            loss_parts.append(l_fo)
            g_parts.append(g_fo)
        inv = np.argsort(np.asarray(order))
        g = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0)[inv], *g_parts)
        losses = jnp.concatenate(loss_parts)[inv]
        return losses, g

    def het_select(params, batches, agent_keys, nu_vec):
        """Grouped "select" dispatch (paper-faithful uniform program):
        every kind group runs over the WHOLE anonymous population and
        its agents are masked in via ``_select_tree`` — 1 + n_groups
        full passes, the price of SPMD uniformity."""
        n0 = cfg.n_zeroth
        if cfg.n_first > 0:
            losses, g = jax.vmap(per_agent_fo)(params, batches)
        else:
            losses = jnp.zeros((n,), jnp.float32)
            g = jax.tree.map(jnp.zeros_like, params)
        # pad the ZO tables over the FO rows (masked out; the pad values
        # only need to keep the arithmetic finite)
        pad = jnp.ones((n - n0,), jnp.float32)
        nu_full = jnp.concatenate([nu_vec, pad])
        rv_full = jnp.concatenate([rv_tab, pad])
        for grp in pop.groups:
            l_k, g_k = jax.vmap(zo_for_kind(grp.kind, grp.rv_max))(
                params, batches, agent_keys, nu_full, rv_full
            )
            mask = np.zeros((n,), bool)
            mask[list(grp.indices)] = True
            mask = jnp.asarray(mask)
            g = _select_tree(mask, g_k, g)
            losses = jnp.where(mask, l_k, losses)
        return losses, g

    # -- heterogeneous shard_cond: runtime branch per kind group -------
    # Build-time: a static shard -> branch table over the kind groups'
    # uniform programs (groups first, FO last).  Runtime: one
    # ``lax.switch`` per population shard — each shard runs ONLY its
    # own group's program, like homogeneous shard_cond's ZO/FO cond,
    # with the per-agent nu/rv sliced from replicated full tables.
    het_shard_cond = None
    if not pop.homogeneous and cfg.dispatch == "shard_cond" and mesh is not None:
        from jax.sharding import PartitionSpec as P

        sc_axes = tuple(a for a in population_axes if a in mesh.shape)
        sc_size = 1
        for a in sc_axes:
            sc_size *= mesh.shape[a]
        sc_local = n // sc_size
        branch_of = {}
        for gi, grp in enumerate(pop.groups):
            for a_idx in grp.indices:
                branch_of[a_idx] = gi
        for a_idx in range(cfg.n_zeroth, n):
            branch_of[a_idx] = len(pop.groups)
        shard_branch = []
        for s in range(sc_size):
            members = range(s * sc_local, (s + 1) * sc_local)
            kinds_s = {branch_of[a_idx] for a_idx in members}
            if len(kinds_s) != 1:
                raise ValueError(
                    "dispatch='shard_cond' over a heterogeneous cohort needs "
                    "every population shard to hold agents of a single "
                    f"estimator kind group (shard {s} holds agents "
                    f"{list(members)} spanning {len(kinds_s)} groups); "
                    "reorder/resize the cohort so group boundaries align "
                    "with shards, or use dispatch='select'/'split'"
                )
            shard_branch.append(kinds_s.pop())
        branch_tab = jnp.asarray(np.asarray(shard_branch, np.int32))

        def het_shard_cond(params, batches, agent_keys, nu_vec):
            n0 = cfg.n_zeroth
            pad = jnp.ones((n - n0,), jnp.float32)
            nu_full = jnp.concatenate([nu_vec, pad])
            rv_full = jnp.concatenate([rv_tab.astype(jnp.float32), pad])

            def shard_fn(p_l, b_l, k_l, nu_f, rv_f, btab):
                idx = shard_agent_index(mesh, sc_axes, sc_local)
                nu_loc = jax.lax.dynamic_slice(nu_f, (idx,), (sc_local,))
                rv_loc = jax.lax.dynamic_slice(rv_f, (idx,), (sc_local,))

                def group_branch(grp):
                    f = zo_for_kind(grp.kind, grp.rv_max)
                    return lambda _: jax.vmap(f)(p_l, b_l, k_l, nu_loc, rv_loc)

                branches = [group_branch(grp) for grp in pop.groups]
                branches.append(lambda _: jax.vmap(per_agent_fo)(p_l, b_l))
                return jax.lax.switch(btab[idx // sc_local], branches, None)

            pspec = P(sc_axes if len(sc_axes) > 1 else sc_axes[0])
            keys = compat.replicate_operand(agent_keys, mesh)
            return compat.shard_map(
                shard_fn,
                mesh=mesh,
                in_specs=(pspec, pspec, pspec, P(), P(), P()),
                out_specs=(pspec, pspec),
                axis_names=set(sc_axes),
                check_vma=False,
            )(params, batches, keys, nu_full, rv_full, branch_tab)

    is_zo = zo_mask(cfg)

    def estimate(params, batches, agent_keys, nu, nu_vec=None):
        n0 = cfg.n_zeroth
        if not pop.homogeneous:
            # heterogeneous cohort: per-agent (sigma, rv, lr), possibly
            # mixed estimator kinds — grouped select/split/shard_cond
            if nu_vec is None:
                raise ValueError(
                    "heterogeneous cohort: estimate() needs the per-ZO-agent "
                    "nu_vec (length n_zeroth), e.g. the resolved sigma table"
                )
            if cfg.dispatch == "split":
                return het_split(params, batches, agent_keys, nu_vec)
            if het_shard_cond is not None:
                return het_shard_cond(params, batches, agent_keys, nu_vec)
            return het_select(params, batches, agent_keys, nu_vec)
        if n == 1:
            # single-agent population (e.g. llama4 pod-population on the
            # single-pod mesh): skip vmap so inner shard_map layers (the
            # expert-parallel MoE path) remain top-level collectives.
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            if n0 == 1:
                l1, g1 = per_agent_zo(sq(params), sq(batches), agent_keys[0], nu)
            else:
                l1, g1 = per_agent_fo(sq(params), sq(batches))
            losses = l1[None]
            g = jax.tree.map(lambda x: x[None], g1)
            return losses, g
        if cfg.dispatch == "shard_cond" and 0 < n0 < n and mesh is not None:
            from jax.sharding import PartitionSpec as P

            pop_axes = tuple(a for a in population_axes if a in mesh.shape)
            pop_size = 1
            for a in pop_axes:
                pop_size *= mesh.shape[a]
            n_local = n // pop_size
            assert n0 % n_local == 0, "ZO/FO boundary must align with shards"

            def shard_fn(p_l, b_l, k_l, nu_s):
                idx = shard_agent_index(mesh, pop_axes, n_local)
                is_zo_shard = idx < n0

                def zo_branch(_):
                    return jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu_s))(
                        p_l, b_l, k_l
                    )

                def fo_branch(_):
                    return jax.vmap(per_agent_fo)(p_l, b_l)

                return jax.lax.cond(is_zo_shard, zo_branch, fo_branch, None)

            pspec = P(pop_axes if len(pop_axes) > 1 else pop_axes[0])
            # keys are threefry-derived from the traced step counter;
            # without this pin XLA partitions the key computation and
            # the 0.4.x lowering produces wrong bits (see compat)
            agent_keys = compat.replicate_operand(agent_keys, mesh)
            return compat.shard_map(
                shard_fn,
                mesh=mesh,
                in_specs=(pspec, pspec, pspec, P()),
                out_specs=(pspec, pspec),
                axis_names=set(pop_axes),
                check_vma=False,
            )(params, batches, agent_keys, nu)
        if cfg.dispatch == "split" and 0 < n0 < n:
            # beyond-paper: agents are sorted (ZO first), so slicing the
            # stacked population lets every device compute ONLY its own
            # estimator kind (no masked double work).
            take = lambda t, sl: jax.tree.map(lambda x: x[sl], t)
            loss_zo, g_zo = jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu))(
                take(params, slice(0, n0)), take(batches, slice(0, n0)),
                agent_keys[:n0],
            )
            loss_fo, g_fo = jax.vmap(per_agent_fo)(
                take(params, slice(n0, n)), take(batches, slice(n0, n))
            )
            g = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), g_zo, g_fo)
            losses = jnp.concatenate([loss_zo, loss_fo])
            return losses, g
        # paper-faithful SPMD-uniform baseline: both estimators are
        # computed for every (anonymous) agent, then masked.
        if cfg.n_first > 0:
            loss_fo, g_fo = jax.vmap(per_agent_fo)(params, batches)
        else:
            loss_fo = jnp.zeros((n,), jnp.float32)
            g_fo = jax.tree.map(jnp.zeros_like, params)
        if cfg.n_zeroth > 0:
            loss_zo, g_zo = jax.vmap(lambda p, b, k: per_agent_zo(p, b, k, nu))(
                params, batches, agent_keys
            )
        else:
            loss_zo = jnp.zeros((n,), jnp.float32)
            g_zo = jax.tree.map(jnp.zeros_like, params)

        g = _select_tree(is_zo, g_zo, g_fo)
        losses = jnp.where(is_zo, loss_zo, loss_fo)
        return losses, g

    return estimate


def build_hdo_step(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    cfg: HDOConfig,
    *,
    param_dim: Optional[int] = None,
    donate: bool = False,
    mesh=None,
    population_axes: Tuple[str, ...] = (),
    params_template: Optional[PyTree] = None,
    extended_metrics: bool = False,
    shard: bool = False,
    model_axes: Tuple[str, ...] = (),
) -> Callable[[HDOState, Any], Tuple[HDOState, Dict[str, jnp.ndarray]]]:
    """Returns step(state, batches) -> (state, metrics).

    ``loss_fn(params, batch)`` is a single-agent loss; ``batches`` is a
    pytree whose leaves have leading axis ``n_agents`` (each agent's
    local shard of the data — the paper's split-data setup).

    The step composes three phases built at trace-build time:
    ``build_estimate_phase`` (gradient-estimate dispatch),
    ``localupdate.make_local_update`` (the ``cfg.optimizer`` rule,
    with ``cfg.clip_norm`` per-agent gradient clipping), and
    ``topology.mixer.make_mixer`` (the interaction step).  With
    ``cfg.local_steps = H > 1`` the estimate+update pair runs H times
    per round under ``lax.scan`` — each substep folds its own PRNG key
    from the global substep counter ``t*H + h`` (H=1 reduces to the
    pre-refactor key stream exactly) and consumes its own batch slice:
    every ``batches`` leaf must carry a leading H axis (then
    ``n_agents``), so H local steps see H fresh batches instead of
    re-descending one — and the Mixer still runs exactly once, after
    the scan.  Scalar metrics are averaged over the H substeps.

    ``donate=True`` returns the step already jitted with the incoming
    state's buffers donated (in-place update of params/opt_state — the
    caller must rebind ``state = step(state, ...)`` and never reuse the
    old state).  The default returns the raw traceable function so
    callers can apply their own ``jax.jit`` (e.g. with shardings, as
    ``launch/dryrun.py`` does).

    ``dispatch="shard_cond"`` additionally needs ``mesh`` +
    ``population_axes``: the estimation phase runs under a partial
    ``shard_map`` over the population axes with a *runtime* branch on
    the shard's agent type, so ZO devices never build the backward pass
    (HLO conditionals are dynamic).  The shard_map gossip lowerings
    (``gossip="rr_ppermute"`` / ``"graph_ppermute"``) need the same two
    arguments plus one agent per population shard.

    Heterogeneous populations (``cfg.sigmas`` / ``rvs`` / ``lrs`` /
    ``estimators_zo``, see ``core/population.py``) run a grouped
    variant of the select/split machinery, with per-group
    gradient-estimate variance (``grad_var_zo_<kind>`` /
    ``grad_var_fo``) and per-group loss trajectories
    (``loss_zo_<kind>_mean``) logged as metrics.
    ``dispatch="shard_cond"`` over a heterogeneous cohort runs a
    runtime ``lax.switch`` per population shard over the kind groups'
    uniform programs — each shard must hold agents of a single kind
    group (build-time ValueError otherwise; without a mesh it falls
    back to the grouped select path).  An all-equal per-agent override
    collapses onto the homogeneous path bit-identically
    (tests/test_population.py).

    ``extended_metrics=True`` additionally surfaces the per-agent
    health diagnostics the default step keeps dark: the per-agent loss
    vector (``loss_agent``), in-step consensus distance
    (``consensus_gamma`` and the per-agent ``consensus_agent``
    vector, post-mix), this round's fault-injection counters
    (``fault_drop_count`` / ``fault_straggler_count`` /
    ``fault_byzantine_count``, recomputed from the replayable fault
    schedule — a pure function of (fault_seed, step, agent)), and the
    measured on-wire traffic ``gossip_wire_bytes`` (broadcasting-agent
    count x ``Mixer.wire_bytes_per_agent`` — staleness schedules,
    drops, and stragglers reduce it, so compression sweeps quote
    measured rather than analytic bytes).  Every extra key is
    observe-only: the returned state is bit-identical with the flag on
    or off (tests/test_obs.py), and every key is declared in the
    ``repro.obs.metrics`` schema registry.  The default (False) emits
    exactly the pre-existing metric set.

    ``cfg.param_layout="plane"`` additionally needs
    ``params_template`` — the single-agent model pytree (real arrays or
    ``jax.eval_shape`` structs) from which the static leaf manifest is
    derived (``core/plane.py``).  The state then carries one
    BLOCK-aligned flat buffer per agent; estimate/update/mix all
    consume it whole (O(#agents) kernel dispatches per phase) and the
    pytree is rebuilt only at the loss/jvp boundary.  Single-step
    output is pinned bit-identical to the tree layout for sgd and
    allclose for adamw (tests/test_plane.py).

    ``shard=True`` routes the WHOLE round (estimate -> update -> mix)
    through one ``shard_map`` over ``mesh``: ``population_axes`` shard
    the agent axis and ``model_axes`` FSDP-shard the plane's dim axis
    (``core/shardround.py``; metrics and the returned state are pinned
    against this unsharded path in tests/test_shard.py).  ``mesh=None``
    with ``shard=False`` (the default) is byte-for-byte this function's
    pre-existing single-host path.
    """
    if shard:
        if mesh is None:
            raise ValueError("shard=True needs a mesh (see launch/mesh."
                             "make_hdo_mesh)")
        # deferred: shardround imports this module for HDOState and the
        # select-mask helper
        from repro.core import shardround

        step = shardround.build_sharded_step(
            loss_fn, cfg,
            mesh=mesh,
            population_axes=population_axes or ("agents",),
            model_axes=model_axes or ("model",),
            param_dim=param_dim,
            params_template=params_template,
            extended_metrics=extended_metrics,
        )
        if donate:
            return jax.jit(step, donate_argnums=(0,))
        return step

    # deferred: topology depends on core.gossip's primitives, so a
    # module-level import here would cycle through repro.core.__init__
    from repro.topology import faults as faultlib
    from repro.topology.mixer import make_mixer

    n = cfg.n_agents
    H = cfg.local_steps
    # per-agent sigma/rv/lr tables + estimator-kind groups; a fully
    # uniform population collapses onto the scalar path below, which is
    # what pins "all-equal per-agent values == homogeneous" bit-exactly
    pop = population.resolve_population(cfg)
    manifest = None
    if cfg.param_layout == "plane":
        if params_template is None:
            raise ValueError(
                "param_layout='plane' needs params_template (the "
                "single-agent model pytree, or its jax.eval_shape structs) "
                "to derive the static leaf manifest — see core/plane.py"
            )
        manifest = planelib.build_manifest(params_template)
    sched = schedules.warmup_cosine(
        pop.lr0 if pop.homogeneous else cfg.lr,
        cfg.warmup_steps, cfg.cosine_steps, cfg.use_cosine,
    )
    mixer = make_mixer(cfg, mesh=mesh, population_axes=population_axes,
                       param_dim=param_dim)
    mixer_metrics = {
        k: jnp.float32(v) for k, v in mixer.diagnostics().items()
    }
    estimate = build_estimate_phase(
        loss_fn, cfg, mesh=mesh, population_axes=population_axes,
        manifest=manifest,
    )
    local_update = localupdate.make_local_update(cfg)

    # -- extended-metrics constants (trace-time) -----------------------
    # wire accounting: the plane layout knows its dim from the manifest,
    # otherwise the caller-provided param_dim prices the payloads
    fault_spec = faultlib.FaultSpec.from_config(cfg) if extended_metrics else None
    wire_dim = manifest.size if manifest is not None else param_dim
    payload_bytes = (mixer.wire_bytes_per_agent(wire_dim)
                     if extended_metrics and wire_dim else None)

    # -- heterogeneous cohort tables (trace-time constants) ------------
    if pop.homogeneous:
        lr_rel = sigma_tab = None
    else:
        if cfg.lr <= 0:
            raise ValueError(
                "heterogeneous lrs scale the shared schedule, which is "
                f"anchored at cfg.lr — cfg.lr must be > 0, got {cfg.lr}"
            )
        # per-agent lr enters as a scale on the shared schedule shape:
        # lr_i(t) = sched(t) * lrs[i] / cfg.lr
        lr_rel = jnp.asarray(pop.lr_array() / np.float32(cfg.lr))
        sigma_tab = jnp.asarray(pop.sigma_array())

    def subset_var(tree, idx):
        """Per-group gradient-estimate variance: (1/|G|) sum_{i in G}
        ||g_i - mean_G||^2 over the flattened estimates."""
        idx = np.asarray(list(idx))

        def v(x):
            xs = x[idx].astype(jnp.float32)
            mu = xs.mean(0, keepdims=True)
            return jnp.sum((xs - mu) ** 2) / idx.size

        return sum(jax.tree.leaves(jax.tree.map(v, tree)))

    def step(state: HDOState, batches) -> Tuple[HDOState, Dict[str, jnp.ndarray]]:
        t = state.step
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), t)
        lr = sched(t)
        nu = (
            lr / jnp.sqrt(jnp.float32(param_dim))
            if (cfg.nu_from_lr and param_dim)
            else jnp.float32(pop.sigma0)
        )
        lr_vec = None if pop.homogeneous else lr * lr_rel  # (n,)
        n0 = cfg.n_zeroth
        if pop.homogeneous:
            nu_vec = None
        elif cfg.nu_from_lr and param_dim:
            nu_vec = lr_vec[:n0] / jnp.sqrt(jnp.float32(param_dim))
        else:
            nu_vec = sigma_tab

        def substep(params, opt_state, ctr, b):
            """One estimate+update iteration at substep counter ``ctr``
            on batch slice ``b`` (H=1: ctr == t and b == batches, the
            pre-refactor key stream and data)."""
            skey = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), ctr)
            agent_keys = jax.random.split(skey, n)
            with phase_scope("estimate"):
                losses, g = estimate(params, b, agent_keys, nu, nu_vec)
            with phase_scope("update"):
                new_params, new_opt = local_update.apply(
                    params, g, opt_state, lr, lr_vec
                )
            mets = {
                "loss_mean": losses.mean(),
                "loss_std": losses.std(),
            }
            if extended_metrics:
                mets["loss_agent"] = losses
            if cfg.n_first:
                mets["loss_fo_mean"] = losses[n0:].mean()
            if cfg.n_zeroth:
                mets["loss_zo_mean"] = losses[:n0].mean()
            if not pop.homogeneous:
                # per-group diagnostics — the heterogeneity view next to
                # consensus_distance (high-sigma / low-rv groups show up
                # as high-variance estimators; per-group loss
                # trajectories expose who is actually descending)
                for grp in pop.groups:
                    idx = np.asarray(grp.indices)
                    mets[f"grad_var_zo_{grp.kind}"] = subset_var(g, grp.indices)
                    mets[f"loss_zo_{grp.kind}_mean"] = losses[idx].mean()
                if cfg.n_first:
                    mets["grad_var_fo"] = subset_var(g, range(n0, n))
            return new_params, new_opt, mets

        # ---- local update phase: H estimate+update substeps ----------
        if H == 1:
            new_params, new_opt, mets = substep(
                state.params, state.opt_state, t, batches)
        else:
            for leaf in jax.tree.leaves(batches):
                if leaf.shape[0] != H:
                    raise ValueError(
                        f"local_steps={H} needs fresh per-substep batches: "
                        f"every batches leaf must have leading axis H="
                        f"{H} (then n_agents), got leaf shape {leaf.shape}"
                    )

            def body(carry, xs):
                h, b = xs
                p, o = carry
                np_, no_, m_ = substep(p, o, t * H + h, b)
                return (np_, no_), m_

            (new_params, new_opt), mets = jax.lax.scan(
                body, (state.params, state.opt_state),
                (jnp.arange(H), batches)
            )
            mets = {k: v.mean(axis=0) for k, v in mets.items()}

        # ---- mix (the Mixer interaction step — once per round) -------
        gkey = jax.random.fold_in(key, 7)
        with phase_scope("mix"):
            new_params, new_comm = mixer.mix(
                new_params, key=gkey, step=t, comm=state.comm)

        metrics = {**mets, "lr": lr, **mixer_metrics}
        if extended_metrics:
            # observe-only per-agent health; nothing here feeds back
            # into the returned state (bit-identity pinned in tests)
            per_agent = consensus_per_agent(new_params)
            metrics["consensus_agent"] = per_agent
            metrics["consensus_gamma"] = per_agent.mean()
            masks = (faultlib.fault_masks(fault_spec, t, n)
                     if fault_spec is not None else None)
            if masks is not None:
                f32sum = lambda m: m.sum().astype(jnp.float32)
                metrics["fault_drop_count"] = f32sum(~masks["alive"])
                metrics["fault_straggler_count"] = f32sum(masks["straggler"])
                metrics["fault_byzantine_count"] = f32sum(
                    masks["byzantine"] & masks["alive"])
            if payload_bytes is not None:
                # measured traffic: only agents that actually broadcast
                # this round put payload on the wire — the staleness
                # stagger, drops, and stragglers all reduce it (the
                # same refresh predicate CompressedGraphMixer applies)
                if fault_spec is not None or cfg.staleness > 0:
                    alive = (masks["alive"] if masks is not None
                             else jnp.ones((n,), bool))
                    straggler = (masks["straggler"] if masks is not None
                                 else jnp.zeros((n,), bool))
                    if cfg.staleness > 0:
                        sched_mask = ((t.astype(jnp.int32)
                                       + jnp.arange(n, dtype=jnp.int32))
                                      % (cfg.staleness + 1)) == 0
                    else:
                        sched_mask = jnp.ones((n,), bool)
                    n_bcast = (sched_mask & alive & ~straggler
                               ).sum().astype(jnp.float32)
                else:
                    n_bcast = jnp.float32(n)
                metrics["gossip_wire_bytes"] = n_bcast * jnp.float32(
                    payload_bytes)
        return HDOState(params=new_params, opt_state=new_opt, step=t + 1,
                        comm=new_comm), metrics

    if donate:
        return jax.jit(step, donate_argnums=(0,))
    return step


def consensus_distance(params: PyTree) -> jnp.ndarray:
    """Gamma_t = (1/n) sum_i ||X_i - mu||^2 (the paper's potential)."""
    def gamma(x):
        mu = x.mean(axis=0, keepdims=True)
        return jnp.sum((x.astype(jnp.float32) - mu.astype(jnp.float32)) ** 2) / x.shape[0]

    return sum(jax.tree.leaves(jax.tree.map(gamma, params)))


def consensus_per_agent(params: PyTree) -> jnp.ndarray:
    """Per-agent consensus distance: the (n,) vector of
    ||X_i - mu||^2 whose mean is ``consensus_distance`` — the
    extended-metrics health view (which agent is drifting)."""
    def gamma_i(x):
        xf = x.astype(jnp.float32)
        mu = xf.mean(axis=0, keepdims=True)
        return ((xf - mu) ** 2).reshape(x.shape[0], -1).sum(axis=-1)

    return sum(jax.tree.leaves(jax.tree.map(gamma_i, params)))
