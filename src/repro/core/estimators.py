"""Gradient estimators for HDO agents.

First-order: plain backprop (``jax.value_and_grad``).
Zeroth-order (paper Appendix "Estimator types"):
  * ``biased_1pt``   — (F(x+nu u) - F(x)) / nu * u          (Def. 2)
  * ``biased_2pt``   — (F(x+nu u) - F(x-nu u)) / (2 nu) * u
  * ``multi_rv``     — ``rv``-sample average of biased_2pt (the paper's
                        "number of random vectors" knob, Fig. 1/6)
  * ``fwd_grad``     — unbiased forward-mode (u . grad F) u, Baydin et
                        al. 2022, computed with ``jax.jvp`` (one forward
                        pass, no backprop) — exactly the paper's
                        "Unbiased Zeroth-order" estimator.

All ZO estimators touch the loss function only through forward
evaluations (or JVPs), never ``jax.grad``.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ZO_ESTIMATORS

PyTree = Any
LossFn = Callable[[PyTree], jnp.ndarray]  # params -> scalar loss

ZO_KINDS = ZO_ESTIMATORS  # canonical list lives with the config knob


def tree_normal(key, tree: PyTree) -> PyTree:
    """Standard-normal pytree with the same structure/shapes as ``tree``."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten(
        [jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype) for k, l in zip(keys, leaves)]
    )


def tree_axpy(a, x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(lambda xi, yi: (a * xi.astype(jnp.float32) + yi.astype(jnp.float32)).astype(yi.dtype), x, y)


def tree_scale(a, x: PyTree) -> PyTree:
    return jax.tree.map(lambda xi: (a * xi.astype(jnp.float32)).astype(xi.dtype), x)


def tree_zeros_like(x: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, x)


def fo_estimate(loss_fn: LossFn, params: PyTree) -> Tuple[jnp.ndarray, PyTree]:
    """First-order: (loss, grad)."""
    return jax.value_and_grad(loss_fn)(params)


def zo_estimate(
    loss_fn: LossFn,
    params: PyTree,
    key,
    *,
    kind: str = "multi_rv",
    rv: int = 4,
    nu: float = 1e-4,
    rv_actual=None,
) -> Tuple[jnp.ndarray, PyTree]:
    """Zeroth-order estimate: (loss_at_x_or_primal, grad_estimate).

    ``rv_actual`` (optional, may be traced) enables ragged-``rv``
    heterogeneous cohorts: the scan still runs the static ``rv`` draws
    (a uniform program across a vmapped group), but draws ``r >=
    rv_actual`` contribute zero and the average is over ``rv_actual``.
    Ignored by the single-draw kinds (``biased_1pt`` / ``biased_2pt``).
    """
    if kind == "fwd_grad":
        return _fwd_grad(loss_fn, params, key, rv, rv_actual=rv_actual)
    if kind == "biased_1pt":
        return _finite_diff(loss_fn, params, key, 1, nu, two_point=False)
    if kind == "biased_2pt":
        return _finite_diff(loss_fn, params, key, 1, nu, two_point=True)
    if kind == "multi_rv":
        return _finite_diff(loss_fn, params, key, rv, nu, two_point=True,
                            rv_actual=rv_actual)
    raise ValueError(kind)


def _finite_diff(loss_fn, params, key, rv, nu, *, two_point, rv_actual=None):
    loss0 = loss_fn(params)

    def body(acc, r):
        u = tree_normal(jax.random.fold_in(key, r), params)
        lp = loss_fn(tree_axpy(nu, u, params))
        if two_point:
            lm = loss_fn(tree_axpy(-nu, u, params))
            coeff = (lp - lm) / (2.0 * nu)
        else:
            coeff = (lp - loss0) / nu
        if rv_actual is not None:
            coeff = jnp.where(r < rv_actual, coeff, 0.0)
        acc = jax.tree.map(
            lambda a, ui: a + coeff * ui.astype(jnp.float32), acc, u
        )
        return acc, None

    acc, _ = jax.lax.scan(body, tree_zeros_like(jax.tree.map(lambda x: x.astype(jnp.float32), params)), jnp.arange(rv))
    denom = rv if rv_actual is None else jnp.asarray(rv_actual, jnp.float32)
    g = jax.tree.map(lambda a, p: (a / denom).astype(p.dtype), acc, params)
    return loss0, g


def _fwd_grad(loss_fn, params, key, rv, *, rv_actual=None):
    def body(acc, r):
        u = tree_normal(jax.random.fold_in(key, r), params)
        primal, jvp = jax.jvp(loss_fn, (params,), (u,))
        if rv_actual is not None:
            jvp = jnp.where(r < rv_actual, jvp, 0.0)
        acc = jax.tree.map(lambda a, ui: a + jvp * ui.astype(jnp.float32), acc, u)
        return acc, primal

    acc, primals = jax.lax.scan(
        body,
        tree_zeros_like(jax.tree.map(lambda x: x.astype(jnp.float32), params)),
        jnp.arange(rv),
    )
    denom = rv if rv_actual is None else jnp.asarray(rv_actual, jnp.float32)
    g = jax.tree.map(lambda a, p: (a / denom).astype(p.dtype), acc, params)
    return primals[0], g
