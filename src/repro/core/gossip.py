"""Pairwise gossip averaging (the paper's interaction step).

Implementations (selected by ``HDOConfig.gossip``):
  * ``dense``       — paper-faithful: a fresh uniformly-random disjoint
                      matching is sampled *inside* the jitted step
                      (``jax.random.permutation``); partner models are
                      exchanged with a gather along the agent axis.
  * ``rr_static``   — round-robin tournament schedule (n-1 static
                      matchings, selected by step index): the TPU-native
                      derandomization whose matchings are known at trace
                      time (enables ``ppermute`` lowering under
                      shard_map; see launch/dryrun perf variants).
  * ``all_reduce``  — full population mean every step (the classic
                      data-parallel baseline the paper compares against).
  * ``none``        — no communication (mono-agent / debugging).

All variants preserve the population mean exactly (load-balancing view
of Lemma 2).

This module holds the matching/averaging *primitives*; the training
step no longer string-dispatches over them — ``build_hdo_step``
consumes a ``repro.topology.mixer.Mixer`` built from ``HDOConfig``,
which wraps these primitives (and adds weighted graph-topology mixing
with spectral diagnostics).  ``gossip_step`` below is retained as the
direct functional entry point.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def sample_matching(key, n: int) -> jnp.ndarray:
    """Uniformly-random disjoint pairing as an involution array.

    Returns p with p[p[i]] == i; if n is odd one agent is left alone
    (p[i] == i).
    """
    perm = jax.random.permutation(key, n)
    half = n // 2
    evens = perm[:half]
    odds = perm[half : 2 * half]
    p = jnp.arange(n)
    p = p.at[evens].set(odds)
    p = p.at[odds].set(evens)
    return p


def round_robin_schedule(n: int) -> np.ndarray:
    """(n-1, n) partner table via the circle method (n even).

    Round r pairs every agent with a distinct partner; over n-1 rounds
    every pair meets exactly once.
    """
    assert n % 2 == 0 and n >= 2
    rounds = []
    circle = list(range(1, n))
    for r in range(n - 1):
        p = np.zeros(n, dtype=np.int32)
        ring = [0] + circle
        for i in range(n // 2):
            a, b = ring[i], ring[n - 1 - i]
            p[a], p[b] = b, a
        rounds.append(p)
        circle = circle[1:] + circle[:1]
    return np.stack(rounds)


def mix_pairwise(params: PyTree, partner: jnp.ndarray) -> PyTree:
    """X_i <- (X_i + X_{p(i)}) / 2 along the leading agent axis."""
    def mix(x):
        return ((x + jnp.take(x, partner, axis=0)) * 0.5).astype(x.dtype)

    return jax.tree.map(mix, params)


def mix_all_reduce(params: PyTree) -> PyTree:
    def mix(x):
        return jnp.broadcast_to(x.mean(axis=0, keepdims=True), x.shape).astype(x.dtype)

    return jax.tree.map(mix, params)


def gossip_step(params: PyTree, *, mode: str, key, step, n: int, schedule=None) -> PyTree:
    if mode == "none" or n == 1:
        return params
    if mode == "all_reduce":
        return mix_all_reduce(params)
    if mode == "dense":
        return mix_pairwise(params, sample_matching(key, n))
    if mode == "rr_static":
        # lax.switch over the n-1 tournament rounds: each branch's
        # partner table is a COMPILE-TIME constant, so the exchange can
        # lower to a point-to-point permute instead of an all-gather.
        sched = np.asarray(schedule if schedule is not None else round_robin_schedule(n))
        branches = [
            (lambda p, _r=r: mix_pairwise(p, jnp.asarray(sched[_r])))
            for r in range(len(sched))
        ]
        return jax.lax.switch(step % (n - 1), branches, params)
    raise ValueError(mode)
