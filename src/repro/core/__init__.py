"""HDO core — the paper's contribution as a composable JAX module."""
from repro.core.estimators import fo_estimate, tree_normal, zo_estimate
from repro.core.flatzo import flat_zo_estimate
from repro.core.gossip import (
    gossip_step,
    mix_all_reduce,
    mix_pairwise,
    round_robin_schedule,
    sample_matching,
)
from repro.core.hdo import (
    HDOState,
    build_estimate_phase,
    build_hdo_step,
    consensus_distance,
    init_state,
    tree_stack_broadcast,
    zo_mask,
)
from repro.core.localupdate import LocalUpdate, make_local_update
from repro.core.plane import (
    LeafSpec,
    PlaneManifest,
    build_manifest,
    manifest_hash,
    pack,
    unpack,
    unpack_stacked,
)
from repro.core.population import KindGroup, Population, resolve_population
from repro.core.schedules import constant, warmup_cosine

__all__ = [
    "fo_estimate",
    "zo_estimate",
    "flat_zo_estimate",
    "tree_normal",
    "gossip_step",
    "mix_all_reduce",
    "mix_pairwise",
    "round_robin_schedule",
    "sample_matching",
    "HDOState",
    "build_estimate_phase",
    "build_hdo_step",
    "LocalUpdate",
    "make_local_update",
    "consensus_distance",
    "init_state",
    "tree_stack_broadcast",
    "zo_mask",
    "LeafSpec",
    "PlaneManifest",
    "build_manifest",
    "manifest_hash",
    "pack",
    "unpack",
    "unpack_stacked",
    "KindGroup",
    "Population",
    "resolve_population",
    "constant",
    "warmup_cosine",
]
