"""ParamPlane: the persistent block-aligned flat parameter layout.

The paper's Algorithm 1 is a sequence of whole-vector O(d) operations
on x_i in R^d — perturb, combine, clip, update, mix.  The pytree layout
re-derives that flat view per call (``ravel_pytree`` in ``flatzo``,
per-leaf dispatch in ``LocalUpdate`` and the Mixers) and pays per-leaf
kernel launches plus a small-leaf jnp fallback.  This module makes the
flat view *persistent*:

  * ``build_manifest(params)`` derives a static **leaf manifest** from
    the model pytree — per leaf: name, plane offset, element count,
    BLOCK-aligned padded extent, shape, dtype.  It only needs shapes
    and dtypes, so it works on ``jax.eval_shape`` structs too.
  * ``pack`` / ``unpack`` convert between the pytree and one contiguous
    padded ``(dim,)`` buffer (the *plane*).  With
    ``HDOConfig.param_layout="plane"``, ``HDOState.params`` holds one
    plane row per agent — a single ``(n_agents, dim)`` leaf — so every
    tree-generic phase (mixers, select masks, checkpointing, pspecs)
    automatically issues O(#agents) kernel dispatches instead of
    O(#agents * #leaves), and every element rides the kernels because
    the plane is BLOCK-aligned by construction.
  * ``rng_tables`` gives the per-block (delta, nvalid) tables that keep
    the plane ZO kernels on the *compact* counter stream: position j of
    leaf L draws ``counter_normal(seed, leaf_compact_offset + j, r)``
    exactly like the tree-layout fused engine's ravel of the same
    pytree, so plane-vs-tree stays bit-identical; pad lanes are masked.
  * ``manifest_hash`` is the versioned fingerprint checkpoints carry so
    a ``--resume`` across a layout or model-shape change fails loudly
    instead of as a shape mismatch deep in restore.

Pads are invariant-zero: ``pack`` writes zeros, the masked kernels
write zeros (combine/tangent) or pass x through (perturb), the
elementwise update maps zero grads + zero momentum to zero, and mixing
is convex — so pads never leak into the compact lanes.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.zo_combine import BLOCK

PyTree = Any

# bump when the manifest layout/semantics change: hashes from older
# versions never collide with newer ones, so stale checkpoints are
# rejected by the hash check rather than misread
MANIFEST_VERSION = 1


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """One pytree leaf's slot in the plane (all static metadata)."""
    name: str                  # jax.tree_util.keystr path
    offset: int                # start in the plane (multiple of BLOCK)
    size: int                  # element count of the leaf
    extent: int                # BLOCK-aligned padded length (>= size)
    shape: Tuple[int, ...]
    dtype: str                 # canonical dtype name, e.g. "float32"


@dataclasses.dataclass(frozen=True)
class PlaneManifest:
    """Static layout of a model pytree inside one contiguous plane."""
    leaves: Tuple[LeafSpec, ...]
    dim: int                   # padded plane length (multiple of BLOCK)
    size: int                  # total compact element count (sum of sizes)
    dtype: str                 # plane buffer dtype
    treedef: Any               # jax.tree_util.PyTreeDef of the model

    @property
    def n_blocks(self) -> int:
        return self.dim // BLOCK


def build_manifest(params: PyTree) -> PlaneManifest:
    """Derive the static leaf manifest from a model pytree.

    Only shapes/dtypes are read, so ``params`` may be real arrays or
    ``jax.eval_shape`` / ``jax.ShapeDtypeStruct`` leaves.  The plane
    dtype is the common leaf dtype when uniform, else ``float32``
    (mixed-dtype models promote; the bit-identity guarantees of the
    plane layout hold for uniform-dtype models).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    if not flat:
        raise ValueError("cannot build a plane manifest from an empty pytree")
    specs = []
    offset = 0
    dtypes = set()
    for path, leaf in flat:
        shape = tuple(int(s) for s in leaf.shape)
        dt = jnp.dtype(leaf.dtype)
        if not jnp.issubdtype(dt, jnp.floating):
            raise ValueError(
                f"plane layout needs floating-point leaves, got {dt} at "
                f"{jax.tree_util.keystr(path)}"
            )
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        extent = size + ((-size) % BLOCK)
        specs.append(LeafSpec(
            name=jax.tree_util.keystr(path), offset=offset, size=size,
            extent=extent, shape=shape, dtype=dt.name,
        ))
        dtypes.add(dt.name)
        offset += extent
    plane_dtype = dtypes.pop() if len(dtypes) == 1 else "float32"
    return PlaneManifest(
        leaves=tuple(specs),
        dim=offset,
        size=sum(s.size for s in specs),
        dtype=plane_dtype,
        treedef=jax.tree_util.tree_structure(params),
    )


def pack(manifest: PlaneManifest, tree: PyTree) -> jnp.ndarray:
    """Pytree -> (dim,) plane buffer (pads written as zeros)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if len(leaves) != len(manifest.leaves):
        raise ValueError(
            f"pytree has {len(leaves)} leaves, manifest has "
            f"{len(manifest.leaves)} — was the manifest built from a "
            "different model?"
        )
    dtype = jnp.dtype(manifest.dtype)
    parts = []
    for spec, leaf in zip(manifest.leaves, leaves):
        if tuple(leaf.shape) != spec.shape:
            raise ValueError(
                f"leaf {spec.name} has shape {tuple(leaf.shape)}, manifest "
                f"says {spec.shape} — was the manifest built from a "
                "different model?"
            )
        v = jnp.asarray(leaf).reshape(-1).astype(dtype)
        if spec.extent > spec.size:
            v = jnp.concatenate([v, jnp.zeros((spec.extent - spec.size,), dtype)])
        parts.append(v)
    return jnp.concatenate(parts)


def unpack(manifest: PlaneManifest, plane: jnp.ndarray) -> PyTree:
    """(dim,) plane buffer -> pytree (per-leaf dtype restored).

    This is the *only* place the plane layout unravels — the
    model-apply boundary (loss / jvp evaluation).  Slices are static,
    so XLA fuses them into the consumer.
    """
    leaves = [
        plane[spec.offset:spec.offset + spec.size]
        .reshape(spec.shape).astype(jnp.dtype(spec.dtype))
        for spec in manifest.leaves
    ]
    return jax.tree_util.tree_unflatten(manifest.treedef, leaves)


def unpack_stacked(manifest: PlaneManifest, planes: jnp.ndarray) -> PyTree:
    """(n, dim) stacked planes -> pytree with leading agent axis."""
    n = planes.shape[0]
    leaves = [
        planes[:, spec.offset:spec.offset + spec.size]
        .reshape((n,) + spec.shape).astype(jnp.dtype(spec.dtype))
        for spec in manifest.leaves
    ]
    return jax.tree_util.tree_unflatten(manifest.treedef, leaves)


def manifest_hash(manifest: PlaneManifest) -> str:
    """Versioned 16-hex fingerprint of the layout (checkpoint guard)."""
    payload = {
        "version": MANIFEST_VERSION,
        "block": BLOCK,
        "dtype": manifest.dtype,
        "leaves": [
            [s.name, s.offset, s.size, s.extent, list(s.shape), s.dtype]
            for s in manifest.leaves
        ],
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@functools.lru_cache(maxsize=None)
def _rng_tables_cached(leaf_geom: Tuple[Tuple[int, int, int], ...]):
    delta, nvalid = [], []
    compact = 0
    for offset, size, extent in leaf_geom:
        for b in range(extent // BLOCK):
            # plane position offset+b*BLOCK+lane draws the counter at
            # compact+b*BLOCK+lane: delta is constant per block because
            # extents are BLOCK multiples
            delta.append(offset - compact)
            nvalid.append(int(np.clip(size - b * BLOCK, 0, BLOCK)))
        compact += size
    return (np.asarray(delta, np.int32), np.asarray(nvalid, np.int32))


def rng_tables(manifest: PlaneManifest):
    """Per-block (delta, nvalid) int32 tables for the plane ZO kernels.

    ``counter_index(plane_idx) = plane_idx - delta[block]`` maps every
    valid lane onto the *compact* counter stream — the exact indices the
    tree-layout fused engine uses on ``ravel_pytree`` of the same model
    — and ``nvalid[block]`` masks the pad lanes (combine/tangent write
    zeros there; perturb passes x through).
    """
    return _rng_tables_cached(
        tuple((s.offset, s.size, s.extent) for s in manifest.leaves)
    )


def rng_tables_sharded(manifest: PlaneManifest, n_shards: int):
    """Stacked per-shard ``(n_shards, n_blocks/n_shards)`` RNG tables.

    When the plane's dim axis is FSDP-sharded into ``n_shards``
    contiguous BLOCK-aligned chunks, shard ``s`` holds plane positions
    ``[s*dim_local, (s+1)*dim_local)`` at *local* indices; shifting
    delta by the shard offset keeps the kernels drawing the GLOBAL
    compact counter stream from local positions::

        counter = local_idx - delta'[b] = global_idx - delta[block]

    so sharded perturb/combine are bit-identical to slices of the
    unsharded pass.  Select a shard's row at runtime with
    ``lax.dynamic_slice`` on the model-axis index.
    """
    delta, nvalid = rng_tables(manifest)
    if n_shards < 1 or manifest.n_blocks % n_shards != 0:
        raise ValueError(
            f"plane has {manifest.n_blocks} BLOCKs; model-axis sharding "
            f"needs n_blocks % n_shards == 0 (got n_shards={n_shards})")
    b_local = manifest.n_blocks // n_shards
    dim_local = manifest.dim // n_shards
    shift = np.arange(n_shards, dtype=np.int64)[:, None] * dim_local
    delta_s = (delta.reshape(n_shards, b_local).astype(np.int64) - shift)
    return delta_s.astype(np.int32), nvalid.reshape(n_shards, b_local)


def dispatch_counts(manifest: PlaneManifest, n_agents: int) -> dict:
    """Analytic per-phase kernel dispatch counts, plane vs tree layout.

    The tree layout launches one kernel per (agent, leaf) in the mix
    phase and routes sub-BLOCK leaves to the jnp fallback in the update
    phase; the plane is one leaf, so every phase is O(#agents) and the
    fallback set is empty by construction (used by both the small-leaf
    regime test and ``benchmarks/kernel_bench.py``'s BENCH_plane).
    """
    large = [s for s in manifest.leaves if s.size >= BLOCK]
    small = [s for s in manifest.leaves if s.size < BLOCK]
    return {
        "n_leaves": len(manifest.leaves),
        "plane": {
            "update_kernel_calls": n_agents,
            "mix_kernel_calls": n_agents,
            "update_fallback_leaves": 0,
        },
        "tree": {
            "update_kernel_calls": n_agents * len(large),
            "mix_kernel_calls": n_agents * len(manifest.leaves),
            "update_fallback_leaves": len(small),
        },
    }
