"""End-to-end driver: train an HDO population, then serve it through
the continuous-batching engine with per-agent ensemble routing.

Trains a reduced Mamba2 with an HDO population for a few hundred steps
on a synthetic LM stream, then serves an offered-load stream of
generation requests (Poisson-ish arrival spacing) through
``repro.serve``: requests are routed round-robin across cohort members
(``population="ensemble"``), admitted into the fixed slot pool as
arrivals come due, and evicted at token granularity.

  PYTHONPATH=src python examples/serve_batched.py [--train-steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, init_state
from repro.data import synthetic
from repro.models import build_model
from repro.serve import (
    Engine,
    EngineConfig,
    Request,
    Scheduler,
    percentile,
    population_params,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--offered-rps", type=float, default=20.0)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("mamba2-780m"), dtype="float32")
    model = build_model(cfg)
    sample = synthetic.lm_token_stream(cfg.vocab_size, seed=0)

    # ---- train with HDO (2 FO + 2 ZO agents) ---------------------------
    hcfg = HDOConfig(n_agents=4, n_zeroth=2, estimator_zo="fwd_grad", rv=4,
                     gossip="dense", lr=0.02, momentum=0.9, warmup_steps=10,
                     cosine_steps=args.train_steps)
    step = jax.jit(build_hdo_step(model.loss, hcfg))
    state = init_state(model.init(jax.random.PRNGKey(0)), hcfg)
    rng = np.random.default_rng(1)
    t0 = time.time()
    for t in range(args.train_steps):
        toks = sample(rng, 4 * 8, 65).reshape(4, 8, 65)
        batches = {"tokens": jnp.asarray(toks[..., :-1]), "labels": jnp.asarray(toks[..., 1:])}
        state, metrics = step(state, batches)
        if t % 50 == 0 or t == args.train_steps - 1:
            print(f"train step {t:4d} loss={float(metrics['loss_mean']):.4f} "
                  f"({time.time()-t0:.0f}s)")

    # ---- serve the population as an ensemble ---------------------------
    # the cohort IS an ensemble: keep the stacked (n_agents, ...) params
    # and route each request to one member inside the shared slot pool
    stacked = population_params(state.params, mode="ensemble")
    prompt_len, total = 16, 16 + args.gen
    engine = Engine(model, stacked, ensemble=True,
                    config=EngineConfig(n_slots=args.n_slots, cache_seq=total,
                                        max_total=total, chunk=8))
    sched = Scheduler(engine)
    prompts = sample(rng, args.requests, prompt_len)
    spacing = rng.exponential(1.0 / args.offered_rps, args.requests)
    arrivals = np.cumsum(spacing)
    for i in range(args.requests):
        sched.submit(Request(request_id=i, prompt=prompts[i],
                             max_gen=args.gen, agent=i % hcfg.n_agents,
                             arrival_s=float(arrivals[i])))
    t0 = time.time()
    results = sched.run()
    dt = time.time() - t0
    gen_total = sum(r.gen_tokens for r in results)
    print(f"\nserved {args.requests} requests x {args.gen} new tokens "
          f"across {hcfg.n_agents} cohort members in {dt:.2f}s "
          f"({gen_total/dt:.0f} tok/s at ~{args.offered_rps:g} req/s offered)")
    print(f"latency p50={percentile([r.latency_ms for r in results], 50):.0f}ms "
          f"p99={percentile([r.latency_ms for r in results], 99):.0f}ms "
          f"queue p99={percentile([r.queue_ms for r in results], 99):.0f}ms")

    # the synthetic stream is a sparse Markov chain — a trained model's
    # greedy continuations should stay inside each token's 4-successor set
    first = next(r for r in results if r.request_id == 0)
    print(f"sample continuation (agent {first.agent}):",
          first.tokens[prompt_len : prompt_len + 12].tolist())


if __name__ == "__main__":
    main()
