"""End-to-end driver: serve a small model with batched requests.

Trains a reduced Mamba2 with an HDO population for a few hundred steps
on a synthetic LM stream, then serves batched generation requests from
the population-mean model through the KV/SSM-cache decode path.

  PYTHONPATH=src python examples/serve_batched.py [--train-steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, init_state
from repro.data import synthetic
from repro.launch.serve import generate
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--batch-requests", type=int, default=16)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("mamba2-780m"), dtype="float32")
    model = build_model(cfg)
    sample = synthetic.lm_token_stream(cfg.vocab_size, seed=0)

    # ---- train with HDO (2 FO + 2 ZO agents) ---------------------------
    hcfg = HDOConfig(n_agents=4, n_zeroth=2, estimator_zo="fwd_grad", rv=4,
                     gossip="dense", lr=0.02, momentum=0.9, warmup_steps=10,
                     cosine_steps=args.train_steps)
    step = jax.jit(build_hdo_step(model.loss, hcfg))
    state = init_state(model.init(jax.random.PRNGKey(0)), hcfg)
    rng = np.random.default_rng(1)
    t0 = time.time()
    for t in range(args.train_steps):
        toks = sample(rng, 4 * 8, 65).reshape(4, 8, 65)
        batches = {"tokens": jnp.asarray(toks[..., :-1]), "labels": jnp.asarray(toks[..., 1:])}
        state, metrics = step(state, batches)
        if t % 50 == 0 or t == args.train_steps - 1:
            print(f"train step {t:4d} loss={float(metrics['loss_mean']):.4f} "
                  f"({time.time()-t0:.0f}s)")

    params = jax.tree.map(lambda x: x[0], state.params)  # any agent (consensus)

    # ---- serve batched requests ----------------------------------------
    prompts = jnp.asarray(sample(rng, args.batch_requests, 16))
    t0 = time.time()
    out = generate(model, params, prompts, 16 + args.gen, args.gen)
    dt = time.time() - t0
    print(f"\nserved {args.batch_requests} requests x {args.gen} new tokens "
          f"in {dt:.2f}s ({args.batch_requests*args.gen/dt:.0f} tok/s)")

    # the synthetic stream is a sparse Markov chain — a trained model's
    # greedy continuations should stay inside each token's 4-successor set
    table_sample = synthetic.lm_token_stream(cfg.vocab_size, seed=0)
    print("sample continuation:", np.asarray(out[0, 16:16+12]).tolist())


if __name__ == "__main__":
    main()
