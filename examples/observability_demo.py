"""Observability demo: reconstruct a run's story from its logs alone.

Two cohorts train the Brackets (Dyck-1) task on a ring — one with
dense gossip payloads, one with top-k compression + error feedback.
Each run streams through the structured metrics pipeline
(``repro.obs``): a JSONL sink gets the run manifest, per-round extended
metrics (per-agent loss / consensus vectors, measured wire bytes), and
fenced per-phase timing samples.

The analysis half then reads ONLY the two JSONL artifacts — no access
to the training processes — and renders:

  * measured vs predicted Gamma contraction: the per-round consensus
    ratio ``Gamma_{t+1}/Gamma_t`` against the spectral model's
    ``gossip_gamma_contraction`` (effective slem^2) from the same log,
  * the wire-traffic story (``wire_mib_total``: compression cuts the
    cumulative bytes ~50x for the same round count),
  * the phase-time breakdown (estimate / update / mix shares of the
    fenced round) per cohort.

  PYTHONPATH=src python examples/observability_demo.py \
      [--steps 60] [--out-dir /tmp/obs_demo]
"""
import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HDOConfig
from repro.configs.paper_tasks import brackets_transformer
from repro.core import build_hdo_step, init_state
from repro.core import plane as planelib
from repro.data import brackets
from repro.models import build_model
from repro.obs import JSONLSink, MetricsLogger, run_manifest, validate_jsonl
from repro.obs import timing as obstiming

N_AGENTS = 8


def train_cohort(name, over, *, steps, out_dir, model, params0, d, toks, labs):
    """One instrumented run; returns the JSONL artifact path."""
    hcfg = HDOConfig(n_agents=N_AGENTS, n_zeroth=4, estimator_zo="fwd_grad",
                     rv=8, gossip="graph", topology="ring", lr=0.05,
                     momentum=0.8, warmup_steps=10, cosine_steps=steps,
                     nu=1e-4, seed=0, **over)
    step = jax.jit(build_hdo_step(model.loss, hcfg, param_dim=d,
                                  extended_metrics=True))
    fns = obstiming.build_phase_fns(model.loss, hcfg, param_dim=d)
    timer = obstiming.PhaseTimer(fns, obstiming.analytic_phase_bytes(hcfg, d))
    samples = frozenset(obstiming.default_sample_rounds(steps))

    path = os.path.join(out_dir, f"{name}.jsonl")
    logger = MetricsLogger([JSONLSink(path)])
    logger.start_run(run_manifest(
        hcfg, manifest_hash=planelib.manifest_hash(
            planelib.build_manifest(params0)),
        cohort=name, steps=steps))

    state = init_state(params0, hcfg)
    rng = np.random.default_rng(1)
    for t in range(steps):
        idx = rng.integers(0, len(toks), size=(N_AGENTS, 32))
        b = {"tokens": jnp.asarray(toks[idx]), "labels": jnp.asarray(labs[idx])}
        if t in samples:
            logger.log_timing(t, timer.measure(state, b, fused_fn=step))
        state, metrics = step(state, b)
        logger.log_round(t, metrics)
    logger.finish({"rounds": steps})
    return path


def analyze(name, path):
    """The post-hoc half: everything below comes from the artifact."""
    problems = validate_jsonl(path)
    assert not problems, problems
    recs = [json.loads(l) for l in open(path)]
    manifest = recs[0]
    mets = [r for r in recs if r["record"] == "metrics"]
    timings = [r for r in recs if r["record"] == "phase_timing"]

    # measured contraction: geometric mean of Gamma_{t+1}/Gamma_t over
    # the rounds where consensus is resolvable above float noise
    gammas = np.array([m["consensus_gamma"] for m in mets])
    ratios = [b / a for a, b in zip(gammas[5:-1], gammas[6:]) if a > 1e-12]
    measured = float(np.exp(np.mean(np.log(ratios)))) if ratios else float("nan")
    predicted = mets[-1].get("gossip_gamma_contraction", float("nan"))
    wire_mib = mets[-1]["wire_mib_total"]

    print(f"\n== {name} (config {manifest['config_hash']}, "
          f"{manifest['backend']}/{manifest['device_kind']}) ==")
    print(f"  Gamma contraction  measured {measured:.4f}   "
          f"predicted (eff. slem^2) {predicted:.4f}")
    print(f"  cumulative wire    {wire_mib:.2f} MiB over {len(mets)} rounds")
    if timings:
        steady = [t for t in timings
                  if "phase_compile_ms_estimate" not in t] or timings
        tot = np.mean([t["phase_ms_total"] for t in steady])
        print(f"  fenced round       {tot:.1f} ms  (" + "  ".join(
            f"{ph} {np.mean([t[f'phase_ms_{ph}'] for t in steady]) / tot:.0%}"
            for ph in ("estimate", "update", "mix")) + ")")
        fused = np.mean([t["step_ms_fused"] for t in steady])
        print(f"  fused round        {fused:.1f} ms  "
              f"(phase sum within {abs(tot - fused) / fused:.1%})")
    return wire_mib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--out-dir", default="/tmp/obs_demo")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    d = planelib.build_manifest(params0).size
    toks, labs = brackets.make_dataset(n_samples=4096, seq_len=17, seed=0)

    cohorts = [
        ("dense_ring", dict()),
        ("topk_1pct_ef", dict(compression="topk",
                              compress_k=max(1, d // 100))),
    ]
    paths = {}
    for name, over in cohorts:
        print(f"# training {name} ({args.steps} rounds)...")
        paths[name] = train_cohort(name, over, steps=args.steps,
                                   out_dir=args.out_dir, model=model,
                                   params0=params0, d=d, toks=toks, labs=labs)

    wire = {name: analyze(name, path) for name, path in paths.items()}
    if wire["topk_1pct_ef"] > 0:
        print(f"\ncompression wire saving: "
              f"{wire['dense_ring'] / wire['topk_1pct_ef']:.1f}x "
              f"fewer MiB on the wire for the same {args.steps} rounds")


if __name__ == "__main__":
    main()
