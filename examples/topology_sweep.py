"""Gossip-topology sweep on the Brackets (Dyck-1) task: how the
communication graph's spectral gap shapes consensus and convergence
for a fixed hybrid population.

  PYTHONPATH=src python examples/topology_sweep.py [--steps 120]

For each topology the script prints the predicted per-round Gamma
contraction (1 - spectral-gap derived, from ``repro.topology``) next
to the measured consensus distance and validation loss — the paper's
Figure-7 consensus story, opened up along the topology axis.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import topology as topolib
from repro.configs.base import HDOConfig
from repro.configs.paper_tasks import brackets_transformer
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.data import brackets
from repro.models import build_model

N_AGENTS = 8

SWEEP = [
    ("dense", None),          # paper baseline: random pairing
    ("all_reduce", None),     # full averaging (lambda_2 = 0)
    ("graph", "ring"),
    ("graph", "torus"),
    ("graph", "hypercube"),
    ("graph", "erdos_renyi"),
    ("graph", "tv_round_robin"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    toks, labs = brackets.make_dataset(n_samples=4096, seq_len=17, seed=0)
    toks_v, labs_v = brackets.make_dataset(n_samples=512, seq_len=17, seed=7)
    eval_batch = {"tokens": jnp.asarray(toks_v), "labels": jnp.asarray(labs_v)}

    print(f"{'gossip':>22s} {'pred_contr':>10s} {'gamma':>10s} {'val_loss':>9s}")
    for gossip_mode, topo_name in SWEEP:
        hcfg = HDOConfig(n_agents=N_AGENTS, n_zeroth=4, estimator_zo="fwd_grad",
                         rv=8, gossip=gossip_mode,
                         topology=topo_name or "ring", topology_p=0.5,
                         lr=0.05, momentum=0.8, warmup_steps=10,
                         cosine_steps=args.steps, nu=1e-4, seed=0)
        step = jax.jit(build_hdo_step(model.loss, hcfg))
        state = init_state(model.init(jax.random.PRNGKey(0)), hcfg)
        rng = np.random.default_rng(1)
        for t in range(args.steps):
            idx = rng.integers(0, len(toks), size=(N_AGENTS, 32))
            state, metrics = step(state, {"tokens": jnp.asarray(toks[idx]),
                                          "labels": jnp.asarray(labs[idx])})
        mu = jax.tree.map(lambda x: x.mean(0), state.params)
        val = float(model.loss(mu, eval_batch))
        gamma = float(consensus_distance(state.params))
        if "gossip_gamma_contraction" in metrics:
            pred = f"{float(metrics['gossip_gamma_contraction']):10.4f}"
        else:
            pred = f"{'-':>10s}"
        name = gossip_mode if topo_name is None else f"{gossip_mode}/{topo_name}"
        print(f"{name:>22s} {pred} {gamma:10.2e} {val:9.4f}")


if __name__ == "__main__":
    main()
