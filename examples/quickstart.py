"""Quickstart: hybrid decentralized optimization in ~40 lines.

A population of 8 agents (5 zeroth-order + 3 first-order) jointly fits
a logistic-regression model — the paper's convex setting (Fig 2) — and
demonstrates that the hybrid population converges and reaches consensus.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.data import synthetic

# 1. a task: 10-class classification on 64-dim synthetic "MNIST"
task = synthetic.PrototypeClassification(d=64, n_classes=10, noise=0.8, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=1)[:, 0]
    return jnp.mean(lse - gold)


params0 = {"w": jnp.zeros((64, 10)), "b": jnp.zeros((10,))}

# 2. the HDO population: 5 ZO agents (forward-only) + 3 FO agents
cfg = HDOConfig(n_agents=8, n_zeroth=5, estimator_zo="fwd_grad", rv=8,
                gossip="dense", lr=0.05, momentum=0.0, warmup_steps=0,
                use_cosine=False)
step = jax.jit(build_hdo_step(loss_fn, cfg, param_dim=64 * 10 + 10))
state = init_state(params0, cfg)

# 3. train: each agent sees only its own shard of data
rng = np.random.default_rng(0)
for t in range(200):
    xs, ys = zip(*[task.sample(rng, 16) for _ in range(cfg.n_agents)])
    batches = {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}
    state, metrics = step(state, batches)
    if t % 40 == 0 or t == 199:
        print(f"step {t:4d}  loss={float(metrics['loss_mean']):.4f}  "
              f"consensus_gamma={float(consensus_distance(state.params)):.2e}")

# 4. evaluate the population-mean model
xe, ye = task.eval_set(2048)
mu = jax.tree.map(lambda x: x.mean(0), state.params)
acc = float(jnp.mean(jnp.argmax(jnp.asarray(xe) @ mu["w"] + mu["b"], -1) == jnp.asarray(ye)))
print(f"final accuracy of the mean model: {acc:.3f}")
assert acc > 0.8
