"""Communication/computation trade-off sweep on the Brackets (Dyck-1)
task: what ``HDOConfig.local_steps`` (H estimate+update iterations per
gossip round — periodic averaging) and the pluggable local optimizer
(``optimizer="sgd"/"adamw"``) do to convergence per *gossip round* and
per *estimator pass*.

  PYTHONPATH=src python examples/local_steps_sweep.py [--rounds 40]

Every regime trains the same 8-agent hybrid population (4 ZO + 4 FO)
for the same number of *estimator passes* (rounds x H is held fixed),
so the column to watch is val_loss vs gossip_rounds: H=4 reaches a
comparable loss with 4x fewer interaction rounds — the Omidvar et al. /
Sahu et al. communication-overhead story — while the consensus
distance Gamma grows with H (the agents drift for H substeps before
each mix).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HDOConfig
from repro.configs.paper_tasks import brackets_transformer
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.data import brackets
from repro.models import build_model

N_AGENTS = 8
N_ZO = 4

# (name, optimizer, H) — rounds are scaled by 1/H so every regime spends
# the same number of estimator passes
REGIMES = [
    ("sgd_H1", "sgd", 1),
    ("sgd_H2", "sgd", 2),
    ("sgd_H4", "sgd", 4),
    ("adamw_H1", "adamw", 1),
    ("adamw_H4", "adamw", 4),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40,
                    help="gossip rounds for the H=1 baseline (H>1 regimes "
                         "run rounds/H rounds = the same estimator passes)")
    ap.add_argument("--clip-norm", type=float, default=0.0)
    args = ap.parse_args()

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    toks, labs = brackets.make_dataset(n_samples=4096, seq_len=17, seed=0)
    toks_v, labs_v = brackets.make_dataset(n_samples=512, seq_len=17, seed=7)
    eval_batch = {"tokens": jnp.asarray(toks_v), "labels": jnp.asarray(labs_v)}

    print(f"{'regime':>10s} {'gossip_rounds':>13s} {'est_passes':>10s} "
          f"{'val_loss':>9s} {'gamma':>10s}")
    for name, optimizer, H in REGIMES:
        rounds = max(1, args.rounds // H)
        hcfg = HDOConfig(n_agents=N_AGENTS, n_zeroth=N_ZO,
                         estimator_zo="multi_rv", rv=4, nu=1e-3,
                         gossip="dense", lr=0.05, momentum=0.8,
                         optimizer=optimizer, local_steps=H,
                         clip_norm=args.clip_norm,
                         warmup_steps=5, cosine_steps=rounds, seed=0)
        step = jax.jit(build_hdo_step(model.loss, hcfg))
        state = init_state(model.init(jax.random.PRNGKey(0)), hcfg)
        rng = np.random.default_rng(1)
        for t in range(rounds):
            # H>1 rounds take fresh per-substep batches: every leaf
            # carries a leading (H, n_agents, ...) axis
            shape = (N_AGENTS, 32) if H == 1 else (H, N_AGENTS, 32)
            idx = rng.integers(0, len(toks), size=shape)
            state, metrics = step(state, {"tokens": jnp.asarray(toks[idx]),
                                          "labels": jnp.asarray(labs[idx])})
        mu = jax.tree.map(lambda x: x.mean(0), state.params)
        val = float(model.loss(mu, eval_batch))
        gamma = float(consensus_distance(state.params))
        print(f"{name:>10s} {rounds:>13d} {rounds * H:>10d} "
              f"{val:>9.4f} {gamma:>10.2e}")


if __name__ == "__main__":
    main()
