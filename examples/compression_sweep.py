"""Compressed-gossip sweep on the Brackets (Dyck-1) task: what payload
compression, error feedback, staleness, and injected faults do to
consensus and convergence for a fixed hybrid population on a ring.

  PYTHONPATH=src python examples/compression_sweep.py [--steps 120]

Each regime prints its bytes-on-wire per agent per round next to the
effective contraction the spectral model predicts
(``effective_slem(W, delta, staleness)^2``) and the measured consensus
distance / validation loss — the communication-efficiency story: top-k
at 1% of coordinates cuts the wire bytes by ~50x while error feedback
keeps the population converging, and the no-EF ablation shows the
compressor bias the residual stream is there to absorb. The fault rows
stress the same run under replayable drop/straggler injection
(``HDOConfig.fault_*``).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HDOConfig
from repro.configs.paper_tasks import brackets_transformer
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.core import plane as planelib
from repro.data import brackets
from repro.models import build_model
from repro.topology import compress as compresslib

N_AGENTS = 8

# (name, config overrides) — every regime rides gossip="graph"/ring
SWEEP = [
    ("dense_payload", dict()),
    ("topk_10pct", dict(compression="topk")),          # k filled in below
    ("topk_1pct", dict(compression="topk")),
    ("topk_1pct_noEF", dict(compression="topk", error_feedback=False)),
    ("qsgd_4bit", dict(compression="qsgd", compress_bits=4)),
    ("qsgd_4bit_stale2", dict(compression="qsgd", compress_bits=4,
                              staleness=2)),
    ("topk_1pct_faults", dict(compression="topk", fault_drop_rate=0.1,
                              fault_straggler_rate=0.1, fault_seed=7)),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    d = planelib.build_manifest(params0).size  # compact parameter count
    toks, labs = brackets.make_dataset(n_samples=4096, seq_len=17, seed=0)
    toks_v, labs_v = brackets.make_dataset(n_samples=512, seq_len=17, seed=7)
    eval_batch = {"tokens": jnp.asarray(toks_v), "labels": jnp.asarray(labs_v)}

    print(f"{'regime':>18s} {'wire_KiB':>8s} {'eff_contr':>9s} "
          f"{'gamma':>10s} {'val_loss':>9s}")
    for name, over in SWEEP:
        over = dict(over)
        if over.get("compression") == "topk":
            over["compress_k"] = max(1, d // (10 if "10pct" in name else 100))
        hcfg = HDOConfig(n_agents=N_AGENTS, n_zeroth=4,
                         estimator_zo="fwd_grad", rv=8, gossip="graph",
                         topology="ring", lr=0.05, momentum=0.8,
                         warmup_steps=10, cosine_steps=args.steps,
                         nu=1e-4, seed=0, **over)
        # param_dim feeds the compressor's delta into the spectral
        # diagnostics (without it the effective contraction reports the
        # raw graph slem)
        step = jax.jit(build_hdo_step(model.loss, hcfg, param_dim=d))
        state = init_state(params0, hcfg)
        rng = np.random.default_rng(1)
        for t in range(args.steps):
            idx = rng.integers(0, len(toks), size=(N_AGENTS, 32))
            state, metrics = step(state, {"tokens": jnp.asarray(toks[idx]),
                                          "labels": jnp.asarray(labs[idx])})
        mu = jax.tree.map(lambda x: x.mean(0), state.params)
        val = float(model.loss(mu, eval_batch))
        gamma = float(consensus_distance(state.params))
        if hcfg.compression == "none":
            wire = 4 * d
        else:
            comp = compresslib.Compressor(hcfg.compression,
                                          k=hcfg.compress_k,
                                          bits=hcfg.compress_bits)
            wire = comp.bytes_on_wire(d)
        eff = float(metrics.get("gossip_effective_lambda2",
                                metrics["gossip_lambda2"])) ** 2
        print(f"{name:>18s} {wire / 1024:>8.1f} {eff:>9.4f} "
              f"{gamma:>10.2e} {val:>9.4f}")


if __name__ == "__main__":
    main()
