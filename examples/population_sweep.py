"""Population-composition sweep (paper Figs 2/3/7): how the FO/ZO split
changes convergence and consensus on a fixed 16-agent budget.

  PYTHONPATH=src python examples/population_sweep.py [--steps 150]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.data import synthetic

D, CLASSES, N = 64, 10, 16


def loss_fn(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=1)[:, 0]
    return jnp.mean(lse - gold)


def init_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (D, 32)) / np.sqrt(D), "b1": jnp.zeros((32,)),
        "w2": jax.random.normal(k2, (32, CLASSES)) / np.sqrt(32), "b2": jnp.zeros((CLASSES,)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    task = synthetic.PrototypeClassification(d=D, n_classes=CLASSES, noise=0.6, seed=0)
    xe, ye = task.eval_set(2048)
    eval_batch = {"x": jnp.asarray(xe), "y": jnp.asarray(ye)}

    print(f"{'population':>14s} {'val_loss':>9s} {'val_acc':>8s} {'gamma':>10s} {'loss_std':>9s}")
    for n_zo in (0, 4, 8, 12, 16):
        cfg = HDOConfig(n_agents=N, n_zeroth=n_zo, estimator_zo="fwd_grad", rv=8,
                        gossip="dense", lr=0.05, momentum=0.0, warmup_steps=0,
                        use_cosine=False)
        step = jax.jit(build_hdo_step(loss_fn, cfg))
        state = init_state(init_params(jax.random.PRNGKey(0)), cfg)
        rng = np.random.default_rng(1)
        for t in range(args.steps):
            xs, ys = zip(*[task.sample(rng, 16) for _ in range(N)])
            batches = {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}
            state, metrics = step(state, batches)
        mu = jax.tree.map(lambda x: x.mean(0), state.params)
        val = float(loss_fn(mu, eval_batch))
        h = jax.nn.relu(eval_batch["x"] @ mu["w1"] + mu["b1"])
        acc = float(jnp.mean(jnp.argmax(h @ mu["w2"] + mu["b2"], -1) == eval_batch["y"]))
        print(f"{N-n_zo:>2d} FO +{n_zo:>3d} ZO {val:9.4f} {acc:8.3f} "
              f"{float(consensus_distance(state.params)):10.2e} "
              f"{float(metrics['loss_std']):9.4f}")


if __name__ == "__main__":
    main()
