"""Paper Figure 4 reproduction: a Transformer trained on the Brackets
(Dyck-1) dataset by a hybrid FO/ZO population, vs mono-type populations.

  PYTHONPATH=src python examples/brackets_transformer.py [--steps 120]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HDOConfig
from repro.configs.paper_tasks import brackets_transformer
from repro.core import build_hdo_step, init_state
from repro.data import brackets
from repro.models import build_model


def run_population(name, n_agents, n_zo, model, toks, labs, eval_batch, steps, seed=0,
                   curves=None):
    hcfg = HDOConfig(n_agents=n_agents, n_zeroth=n_zo, estimator_zo="fwd_grad",
                     rv=16, gossip="dense" if n_agents > 1 else "none",
                     lr=0.05, momentum=0.8, warmup_steps=10, cosine_steps=steps,
                     nu=1e-4, seed=seed)
    step = jax.jit(build_hdo_step(model.loss, hcfg))
    state = init_state(model.init(jax.random.PRNGKey(seed)), hcfg)
    eval_loss = jax.jit(lambda s: model.loss(jax.tree.map(lambda x: x.mean(0), s.params), eval_batch))
    rng = np.random.default_rng(seed + 1)
    curve = []
    for t in range(steps):
        idx = rng.integers(0, len(toks), size=(n_agents, 32))
        state, _ = step(state, {"tokens": jnp.asarray(toks[idx]), "labels": jnp.asarray(labs[idx])})
        if t % 10 == 0 or t == steps - 1:
            curve.append((t, float(eval_loss(state))))
    print(f"{name:12s} " + " ".join(f"{v:.3f}" for _, v in curve))
    if curves is not None:
        curves[name] = curve
    return curve[-1][1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    toks, labs = brackets.make_dataset(n_samples=4096, seq_len=17, seed=0)
    toks_v, labs_v = brackets.make_dataset(n_samples=512, seq_len=17, seed=7)
    eval_batch = {"tokens": jnp.asarray(toks_v), "labels": jnp.asarray(labs_v)}

    print("validation loss every 10 steps:")
    finals, curves = {}, {}
    for name, n, n0 in [("1 FO", 1, 0), ("1 ZO", 1, 1), ("4 FO", 4, 0),
                        ("8 ZO", 8, 8), ("2FO+8ZO", 10, 8)]:
        finals[name] = run_population(name, n, n0, model, toks, labs, eval_batch,
                                      args.steps, curves=curves)

    print("\nfinal validation loss:")
    for k, v in sorted(finals.items(), key=lambda kv: kv[1]):
        print(f"  {k:10s} {v:.4f}")
    # robust sanity: every population must have improved on its start
    for name, curve in curves.items():
        assert curve[-1][1] < curve[0][1] + 1e-3, (name, curve[0][1], curve[-1][1])
    # the paper's orderings (hybrid < mono-ZO, more FO < fewer FO) emerge
    # with enough steps (paper: T=1000); print the observation either way
    if finals["2FO+8ZO"] < finals["8 ZO"] and finals["4 FO"] < finals["1 FO"]:
        print("\npaper orderings reproduced (hybrid < mono-ZO; 4FO < 1FO)")
    else:
        print(f"\nordering not yet separated at {args.steps} steps "
              "(paper uses T=1000); rerun with --steps 400")


if __name__ == "__main__":
    main()
