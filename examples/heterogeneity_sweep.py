"""Noise-heterogeneity sweep on the Brackets (Dyck-1) task: what a
*heterogeneous* ZO cohort — the paper's central setting — does to
convergence and consensus, opened up along the per-agent axes that
``core/population.py`` resolves (sigmas / rvs / lrs / mixed estimator
kinds).

  PYTHONPATH=src python examples/heterogeneity_sweep.py [--steps 60]

Each regime trains the same 8-agent hybrid population (4 ZO + 4 FO,
``dispatch="split"`` so every kind group computes only its own
estimator) and prints the final validation loss, the consensus
distance, and the per-group gradient-estimate variance metrics
(``grad_var_zo_<kind>`` / ``grad_var_fo``) the heterogeneous step logs
— the high-sigma "byzantine-ish" agent shows up directly as an
inflated ``grad_var_zo_multi_rv``, and down-weighting its lr restores
most of the uniform regime's loss.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HDOConfig
from repro.configs.paper_tasks import brackets_transformer
from repro.core import build_hdo_step, consensus_distance, init_state
from repro.data import brackets
from repro.models import build_model

N_AGENTS = 8
N_ZO = 4

# (name, per-agent overrides) — None entries fall back to the scalar
# knobs, i.e. the homogeneous baseline
REGIMES = [
    ("uniform", {}),
    ("one_high_sigma", {"sigmas": (0.3, 1e-3, 1e-3, 1e-3)}),
    ("high_sigma_lr_down", {
        "sigmas": (0.3, 1e-3, 1e-3, 1e-3),
        "lrs": (0.005,) + (0.05,) * (N_AGENTS - 1),
    }),
    ("mixed_kinds", {
        "estimators_zo": ("fwd_grad", "fwd_grad", "multi_rv", "multi_rv"),
    }),
    ("ragged_rv", {"rvs": (16, 8, 2, 1)}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--zo-impl", default="tree", choices=["tree", "fused"])
    args = ap.parse_args()

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    toks, labs = brackets.make_dataset(n_samples=4096, seq_len=17, seed=0)
    toks_v, labs_v = brackets.make_dataset(n_samples=512, seq_len=17, seed=7)
    eval_batch = {"tokens": jnp.asarray(toks_v), "labels": jnp.asarray(labs_v)}

    print(f"{'regime':>20s} {'val_loss':>9s} {'gamma':>10s}  grad_var per group")
    for name, overrides in REGIMES:
        hcfg = HDOConfig(n_agents=N_AGENTS, n_zeroth=N_ZO,
                         estimator_zo="multi_rv", rv=4, nu=1e-3,
                         zo_impl=args.zo_impl, dispatch="split",
                         gossip="dense", lr=0.05, momentum=0.8,
                         warmup_steps=10, cosine_steps=args.steps, seed=0,
                         **overrides)
        step = jax.jit(build_hdo_step(model.loss, hcfg))
        state = init_state(model.init(jax.random.PRNGKey(0)), hcfg)
        rng = np.random.default_rng(1)
        for t in range(args.steps):
            idx = rng.integers(0, len(toks), size=(N_AGENTS, 32))
            state, metrics = step(state, {"tokens": jnp.asarray(toks[idx]),
                                          "labels": jnp.asarray(labs[idx])})
        mu = jax.tree.map(lambda x: x.mean(0), state.params)
        val = float(model.loss(mu, eval_batch))
        gamma = float(consensus_distance(state.params))
        gvars = "  ".join(
            f"{k.removeprefix('grad_var_')}={float(v):.2e}"
            for k, v in sorted(metrics.items()) if k.startswith("grad_var")
        )
        print(f"{name:>20s} {val:9.4f} {gamma:10.2e}  {gvars or '- (homogeneous)'}")


if __name__ == "__main__":
    main()
