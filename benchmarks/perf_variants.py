"""§Perf hillclimb driver: runs labeled dry-run variants for the three
chosen (arch x shape) pairs and appends to results/perf_variants.jsonl.

Pairs (see EXPERIMENTS.md §Perf):
  A qwen1.5-0.5b x train_4k  — most representative of the paper's technique
  B llama4-maverick x train_4k — most collective-bound
  C gemma2-9b x long_500k    — worst roofline fraction
"""
from __future__ import annotations

import argparse

from benchmarks.dryrun_matrix import run_combo

VARIANTS = [
    # (label, arch, shape, extra dryrun args)
    ("A0_baseline", "qwen1.5-0.5b", "train_4k", []),
    ("A1_split", "qwen1.5-0.5b", "train_4k", ["--dispatch", "split"]),
    ("A2_split_rr", "qwen1.5-0.5b", "train_4k",
     ["--dispatch", "split", "--gossip", "rr_static"]),
    ("A3_split_rr_remat", "qwen1.5-0.5b", "train_4k",
     ["--dispatch", "split", "--gossip", "rr_static", "--attn-remat"]),
    ("B0_baseline", "llama4-maverick-400b-a17b", "train_4k", []),
    ("B1_moe_constraint", "llama4-maverick-400b-a17b", "train_4k",
     ["--moe-constraint"]),
    ("B2_moe_bf16mom", "llama4-maverick-400b-a17b", "train_4k",
     ["--moe-constraint", "--momentum-dtype", "bfloat16"]),
    ("B3_moe_bf16mom_remat", "llama4-maverick-400b-a17b", "train_4k",
     ["--moe-constraint", "--momentum-dtype", "bfloat16", "--attn-remat"]),
    ("C0_baseline", "gemma2-9b", "long_500k", []),
    ("C1_window_slice", "gemma2-9b", "long_500k", ["--window-slice"]),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/perf_variants.jsonl")
    ap.add_argument("--only", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    for label, arch, shape, extra in VARIANTS:
        if args.only and not label.startswith(tuple(args.only.split(","))):
            continue
        r = run_combo(arch, shape, multi_pod=args.multi_pod, gossip="dense",
                      rv=2, timeout=2400, out=args.out,
                      extra_args=extra + ["--label", label])
        ok = "ERR" if "error" in r else "ok"
        if ok == "ok":
            print(f"{label:24s} flops/dev={r['flops_per_device']:.3e} "
                  f"bytes/dev={r['bytes_per_device']:.3e} "
                  f"coll/dev={r['coll_bytes_per_device']:.3e} "
                  f"peak={r['memory'].get('peak_memory_in_bytes', 0)/1e9:.2f}GB "
                  f"bottleneck={r['bottleneck']}", flush=True)
        else:
            print(f"{label:24s} ERROR {str(r.get('error'))[:200]}", flush=True)


if __name__ == "__main__":
    main()
