"""Render the roofline table (EXPERIMENTS.md §Roofline) from the
dry-run results JSONL.

  PYTHONPATH=src python -m benchmarks.roofline \
      --in results/dryrun_baseline.jsonl [--markdown]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List


def load(path: str) -> List[Dict]:
    out = []
    for line in open(path):
        try:
            out.append(json.loads(line))
        except Exception:
            pass
    return out


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def render(rows: List[Dict], markdown: bool = True, multi_pod=False) -> str:
    # every t_* / bandwidth / peak-mem figure is PER SHARD (one device's
    # slice of the mesh; "shards" shows how many the estimate divides
    # the round over) — whole-population numbers are shards x per-shard
    hdr = ["arch", "shape", "shards", "t_comp", "t_mem", "t_coll",
           "bottleneck", "useful", "peak_mem/dev", "note"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for r in rows:
        if r.get("multi_pod") != multi_pod:
            continue
        chips = r.get("chips", "-")
        if "error" in r:
            row = [r["arch"], r["shape"], chips, "-", "-", "-", "-", "-", "-",
                   "ERROR"]
        elif "skipped" in r:
            row = [r["arch"], r["shape"], chips, "-", "-", "-", "-", "-", "-",
                   "skipped (full attention; DESIGN.md §4)"]
        else:
            mem = r.get("memory", {}).get("peak_memory_in_bytes")
            useful = r.get("useful_ratio")
            row = [
                r["arch"], r["shape"], chips,
                fmt_s(r.get("t_compute_s")), fmt_s(r.get("t_memory_s")),
                fmt_s(r.get("t_collective_s")), r.get("bottleneck", "-"),
                f"{useful:.2f}" if useful else "-",
                f"{mem/1e9:.2f}GB" if mem else "-",
                "",
            ]
        lines.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun_baseline.jsonl")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    rows = load(args.inp)
    # keep last entry per (arch, shape, mesh)
    last = {}
    for r in rows:
        last[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    print(render(list(last.values()), multi_pod=args.multi_pod))


if __name__ == "__main__":
    main()
