"""One benchmark per paper figure (CPU-reduced sizes; see DESIGN.md §7).

Each function prints ``name,us_per_call,derived`` CSV lines and returns
a dict of curves for further analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs.base import HDOConfig
from repro.data import brackets, synthetic
from repro.models import build_model

BASE = dict(lr=0.05, momentum=0.0, warmup_steps=0, use_cosine=False, nu=1e-3)


def _cls_batches(task, n_agents, bsz):
    def fn(rng):
        xs, ys = [], []
        for _ in range(n_agents):
            x, y = task.sample(rng, bsz)
            xs.append(x)
            ys.append(y)
        return {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}

    return fn


def fig1_rv_count(steps: int = 120) -> Dict:
    """Fig 1/6: number of random vectors vs convergence (biased vs
    unbiased forward-gradient estimators), MLP on synthetic MNIST."""
    task = synthetic.PrototypeClassification(d=64, n_classes=10, noise=0.6, seed=0)
    init, loss = common.mlp_model(64, 32, 10)
    xe, ye = task.eval_set(1024)
    eval_batch = {"x": jnp.asarray(xe), "y": jnp.asarray(ye)}
    out = {}
    for name, kind, rv in [
        ("biased_rv1", "multi_rv", 1),
        ("biased_rv8", "multi_rv", 8),
        ("biased_rv32", "multi_rv", 32),
        ("unbiased_rv8", "fwd_grad", 8),
    ]:
        hcfg = HDOConfig(n_agents=4, n_zeroth=4, estimator_zo=kind, rv=rv,
                         gossip="dense", **{**BASE, "lr": 0.02})
        res = common.run_population(
            loss, init(jax.random.PRNGKey(0)), hcfg,
            _cls_batches(task, 4, 32), steps=steps,
            eval_fn=common.eval_mean_model(loss, eval_batch))
        print(common.csv_line(f"fig1_{name}", res["us_per_call"], round(res["final"], 4)))
        out[name] = res["curve"]
    return out


def fig2_convex_populations(steps: int = 60) -> Dict:
    """Fig 2: logistic regression, mono vs hybrid populations
    (paper: 24 FO / 256 ZO / hybrid; reduced 4 FO / 24 ZO / hybrid)."""
    task = synthetic.PrototypeClassification(d=64, n_classes=10, noise=0.8, seed=1)
    init, loss = common.linear_softmax_model(64, 10)
    xe, ye = task.eval_set(1024)
    eval_batch = {"x": jnp.asarray(xe), "y": jnp.asarray(ye)}
    out = {}
    pops = [
        ("1fo", 1, 0), ("4fo", 4, 0), ("24zo", 24, 24), ("4fo_24zo", 28, 24),
    ]
    for name, n, n0 in pops:
        hcfg = HDOConfig(n_agents=n, n_zeroth=n0, estimator_zo="multi_rv", rv=8,
                         gossip="dense" if n > 1 else "none", **{**BASE, "lr": 0.02})
        res = common.run_population(
            loss, init(jax.random.PRNGKey(0)), hcfg,
            _cls_batches(task, n, 2), steps=steps,
            eval_fn=common.eval_mean_model(loss, eval_batch))
        print(common.csv_line(f"fig2_{name}", res["us_per_call"], round(res["final"], 4)))
        out[name] = res["curve"]
    return out


def fig3_nonconvex_hybrid(steps: int = 120) -> Dict:
    """Fig 3 (ResNet-18/CIFAR in the paper; reduced: MLP on synthetic
    images): 1 ZO / 1 FO / 5 ZO / 1 FO + 5 ZO."""
    task = synthetic.PrototypeImages(hw=8, channels=3, n_classes=10, noise=0.5, seed=2)
    d = 8 * 8 * 3
    init, loss = common.mlp_model(d, 64, 10)
    xe, ye = task.eval_set(1024)
    eval_batch = {"x": jnp.asarray(xe.reshape(-1, d)), "y": jnp.asarray(ye)}

    def batches(n):
        def fn(rng):
            xs, ys = [], []
            for _ in range(n):
                x, y = task.sample(rng, 16)
                xs.append(x.reshape(-1, d))
                ys.append(y)
            return {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}

        return fn

    out = {}
    for name, n, n0 in [("1zo", 1, 1), ("1fo", 1, 0), ("5zo", 5, 5), ("1fo_5zo", 6, 5)]:
        hcfg = HDOConfig(n_agents=n, n_zeroth=n0, estimator_zo="fwd_grad", rv=8,
                         gossip="dense" if n > 1 else "none", **{**BASE, "lr": 0.02})
        res = common.run_population(loss, init(jax.random.PRNGKey(0)), hcfg,
                                    batches(n), steps=steps,
                                    eval_fn=common.eval_mean_model(loss, eval_batch))
        print(common.csv_line(f"fig3_{name}", res["us_per_call"], round(res["final"], 4)))
        out[name] = res["curve"]
    return out


def fig4_brackets_transformer(steps: int = 160) -> Dict:
    """Fig 4: Transformer on the Brackets (Dyck) dataset; populations
    1 ZO / 1 FO / 4 FO / 16 ZO / 4 FO + 16 ZO (reduced sizes)."""
    from repro.configs.paper_tasks import brackets_transformer

    cfg = dataclasses.replace(brackets_transformer(), dtype="float32")
    model = build_model(cfg)
    toks, labs = brackets.make_dataset(n_samples=2048, seq_len=17, seed=0)
    toks_v, labs_v = brackets.make_dataset(n_samples=512, seq_len=17, seed=99)
    eval_batch = {"tokens": jnp.asarray(toks_v), "labels": jnp.asarray(labs_v)}

    def batches(n):
        def fn(rng):
            idx = rng.integers(0, len(toks), size=(n, 32))
            return {"tokens": jnp.asarray(toks[idx]), "labels": jnp.asarray(labs[idx])}

        return fn

    out = {}
    for name, n, n0 in [("1zo", 1, 1), ("1fo", 1, 0), ("4fo", 4, 0),
                        ("8zo", 8, 8), ("2fo_8zo", 10, 8)]:
        hcfg = HDOConfig(n_agents=n, n_zeroth=n0, estimator_zo="fwd_grad", rv=16,
                         gossip="dense" if n > 1 else "none",
                         lr=0.05, momentum=0.8, warmup_steps=10,
                         cosine_steps=steps, use_cosine=True, nu=1e-4)
        res = common.run_population(model.loss, model.init(jax.random.PRNGKey(0)),
                                    hcfg, batches(n), steps=steps,
                                    eval_fn=common.eval_mean_model(model.loss, eval_batch))
        print(common.csv_line(f"fig4_{name}", res["us_per_call"], round(res["final"], 4)))
        out[name] = res["curve"]
    return out


def fig5_lr_impact(steps: int = 400) -> Dict:
    """Fig 5: learning-rate impact on the stochastic noise floor
    (regression, 1 FO + 15 ZO reduced from 3 FO + 90 ZO)."""
    task = synthetic.PrototypeClassification(d=64, n_classes=10, noise=0.8, seed=3)
    init, loss = common.linear_softmax_model(64, 10)
    xe, ye = task.eval_set(1024)
    eval_batch = {"x": jnp.asarray(xe), "y": jnp.asarray(ye)}
    out = {}
    for lr in (0.005, 0.02, 0.1, 0.5):
        hcfg = HDOConfig(n_agents=16, n_zeroth=15, estimator_zo="multi_rv", rv=8,
                         gossip="dense", **{**BASE, "lr": lr})
        res = common.run_population(loss, init(jax.random.PRNGKey(0)), hcfg,
                                    _cls_batches(task, 16, 2), steps=steps,
                                    eval_fn=common.eval_mean_model(loss, eval_batch))
        print(common.csv_line(f"fig5_lr{lr}", res["us_per_call"], round(res["final"], 4)))
        out[str(lr)] = res["curve"]
    return out


def speedup_vs_population(steps: int = 400, tau: float = 0.25) -> Dict:
    """Theorem 1 "Speedup" paragraph: parallel-time-to-threshold should
    shrink ~linearly (up to log factors) in the population size n.

    Measures steps until the mean-model validation loss < tau for
    hybrid populations of growing n (half FO / half ZO)."""
    task = synthetic.PrototypeClassification(d=64, n_classes=10, noise=1.2, seed=5)
    init, loss = common.linear_softmax_model(64, 10)
    xe, ye = task.eval_set(1024)
    eval_batch = {"x": jnp.asarray(xe), "y": jnp.asarray(ye)}
    out = {}
    base_steps = None
    for n in (2, 4, 8, 16):
        hcfg = HDOConfig(n_agents=n, n_zeroth=n // 2, estimator_zo="fwd_grad",
                         rv=8, gossip="dense", **{**BASE, "lr": 0.02})
        res = common.run_population(
            loss, init(jax.random.PRNGKey(0)), hcfg,
            _cls_batches(task, n, 2), steps=steps, eval_every=5,
            eval_fn=common.eval_mean_model(loss, eval_batch))
        hit = next((t for t, v in res["curve"] if v < tau), steps)
        if base_steps is None:
            base_steps = hit
        speedup = base_steps / max(hit, 1)
        print(common.csv_line(f"speedup_n{n}", res["us_per_call"],
                              f"steps_to_{tau}={hit};speedup_vs_n2={speedup:.2f}"))
        out[n] = hit
    return out


def fig7_consensus(steps: int = 120) -> Dict:
    """Fig 7: loss std across nodes -> 0 for varying ZO counts (16 nodes)."""
    task = synthetic.PrototypeClassification(d=64, n_classes=10, noise=0.6, seed=4)
    init, loss = common.mlp_model(64, 32, 10)
    out = {}
    for name, n0 in [("16fo", 0), ("8zo_8fo", 8), ("16zo", 16)]:
        hcfg = HDOConfig(n_agents=16, n_zeroth=n0, estimator_zo="fwd_grad", rv=8,
                         gossip="dense", **{**BASE, "lr": 0.05})
        res = common.run_population(loss, init(jax.random.PRNGKey(0)), hcfg,
                                    _cls_batches(task, 16, 16), steps=steps)
        final_std = res["std_curve"][-1][1]
        print(common.csv_line(f"fig7_{name}", res["us_per_call"],
                              f"loss_std={final_std:.4f};gamma={res['gamma']:.2e}"))
        out[name] = res["std_curve"]
    return out
