"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints ``name,us_per_call,derived`` CSV lines (derived =
the experiment's headline number, e.g. final validation loss).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HDOConfig
from repro.core import build_hdo_step, consensus_distance, init_state


def run_population(
    loss_fn: Callable,
    params0,
    hcfg: HDOConfig,
    batch_fn: Callable[[np.random.Generator], Dict],
    *,
    steps: int,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 10,
    seed: int = 0,
    param_dim: Optional[int] = None,
) -> Dict:
    """Runs HDO for `steps`; returns loss/metric curves + timing."""
    step_fn = jax.jit(build_hdo_step(loss_fn, hcfg, param_dim=param_dim))
    state = init_state(params0, hcfg)
    rng = np.random.default_rng(seed + 1)
    curve: List[Tuple[int, float]] = []
    std_curve: List[Tuple[int, float]] = []
    t_start = time.time()
    n_calls = 0
    for t in range(steps):
        batches = batch_fn(rng)
        state, metrics = step_fn(state, batches)
        n_calls += 1
        if t % eval_every == 0 or t == steps - 1:
            if eval_fn is not None:
                val = float(eval_fn(state))
            else:
                val = float(metrics["loss_mean"])
            curve.append((t, val))
            std_curve.append((t, float(metrics["loss_std"])))
    wall = time.time() - t_start
    return {
        "curve": curve,
        "std_curve": std_curve,
        "final": curve[-1][1],
        "us_per_call": wall / max(n_calls, 1) * 1e6,
        "gamma": float(consensus_distance(state.params)),
        "state": state,
    }


def eval_mean_model(loss_fn, eval_batch):
    """Evaluates the population-mean model (paper: mu_t) on held-out data."""

    def ev(state):
        mu = jax.tree.map(lambda x: x.mean(0), state.params)
        return loss_fn(mu, eval_batch)

    return jax.jit(ev)


def csv_line(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ---------------------------------------------------------------------------
# simple models used by the paper's small-scale experiments
# ---------------------------------------------------------------------------


def linear_softmax_model(d: int, n_classes: int):
    """Logistic regression (the paper's convex case, Fig 2)."""

    def init(key):
        return {"w": jnp.zeros((d, n_classes)), "b": jnp.zeros((n_classes,))}

    def loss(params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=1)[:, 0]
        return jnp.mean(lse - gold)

    return init, loss


def mlp_model(d: int, hidden: int, n_classes: int):
    """2-hidden-layer MLP (paper Fig 6 ablation)."""

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        s = 1.0 / np.sqrt(d)
        return {
            "w1": jax.random.normal(k1, (d, hidden)) * s,
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, hidden)) / np.sqrt(hidden),
            "b2": jnp.zeros((hidden,)),
            "w3": jax.random.normal(k3, (hidden, n_classes)) / np.sqrt(hidden),
            "b3": jnp.zeros((n_classes,)),
        }

    def loss(params, batch):
        h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
        h = jax.nn.relu(h @ params["w2"] + params["b2"])
        logits = h @ params["w3"] + params["b3"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=1)[:, 0]
        return jnp.mean(lse - gold)

    return init, loss


def accuracy_fn(apply_logits):
    def acc(params, batch):
        logits = apply_logits(params, batch)
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

    return acc
