"""Serving benchmark: continuous-batching scan engine vs the static
FIFO per-token loop, under an offered-load arrival schedule.

  PYTHONPATH=src python -m benchmarks.serve_bench --json

Both paths serve the same seeded request set (``--requests`` prompts,
greedy decode, smoke-scale model in float32 so the streams are
bit-comparable) at each offered load (requests/s; the last point is a
burst — everything arrives at t=0 — which is the steady-state
saturation measurement):

* **scan engine** — ``repro.serve.Engine`` + ``Scheduler``: slot-pool
  caches, chunked ``lax.scan`` decode (no host round-trip per token),
  token-granular eviction, wall-clock arrivals.
* **loop baseline** — static FIFO batches: wait for arrivals, take up
  to ``n_slots`` due requests, drive one per-token jitted-step loop to
  completion, repeat.  No admission mid-batch: a finished sequence's
  lane idles until the whole batch drains (the cost continuous
  batching removes).

Both are warmed before timing (compile excluded).  ``tokens_per_s`` is
offered-load batch throughput (generated tokens / makespan);
``decode_tokens_per_s`` is the steady-state decode rate (generated
tokens / summed decode wall time) — the number the acceptance gate
compares (CI asserts scan > loop at the burst point).

Off-accelerator the absolute numbers are structural (XLA:CPU), but the
dispatch-overhead gap the engine removes is real on every backend.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _build(arch: str, seed: int):
    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _arrivals(n: int, rps: float):
    return [0.0 if rps <= 0 else i / rps for i in range(n)]


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


class _ChunkCollector:
    """Minimal logger shim: keeps the scheduler's per-chunk engine
    metrics so decode busy-time can be summed from the same
    attribution the serve_request records use."""

    enabled = True

    def __init__(self):
        self.chunks = []

    def log_round(self, step, metrics):
        self.chunks.append(metrics)

    def log_request(self, payload):
        pass


def make_engine(model, params, prompts, *, gen, n_slots, chunk):
    """One warmed engine reused across every offered-load point (the
    jit caches live on the instance; rebuilding would re-compile and
    charge it to the first measured request's latency)."""
    from repro.serve import Engine, EngineConfig, Request, Scheduler

    total = prompts.shape[1] + gen
    eng = Engine(model, params,
                 config=EngineConfig(n_slots=n_slots, cache_seq=total,
                                     max_total=total, chunk=chunk))
    sched = Scheduler(eng)
    for i in range(2):  # warm: compiles the chunk + admit programs
        sched.submit(Request(request_id=i, prompt=prompts[i], max_gen=gen))
    sched.run()
    return eng


def bench_engine(eng, prompts, *, gen, rps):
    from repro.serve import Request, Scheduler

    def run(rows, arrive):
        col = _ChunkCollector()
        sched = Scheduler(eng, logger=col)
        for i, row in enumerate(rows):
            sched.submit(Request(request_id=i, prompt=row, max_gen=gen,
                                 arrival_s=arrive[i]))
        t0 = time.perf_counter()
        res = sched.run()
        return res, time.perf_counter() - t0, col.chunks

    res, wall, chunks = run(prompts, _arrivals(len(prompts), rps))
    gen_tok = sum(r.gen_tokens for r in res)
    # decode busy time: each chunk's wall split by its own pf/dc token
    # counts; rate is per decoded token across all concurrent slots
    dec_tok = sum(c["decode_tokens"] for c in chunks)
    dec_s = sum(c["chunk_ms"] * c["decode_tokens"]
                / max(c["prefill_tokens"] + c["decode_tokens"], 1)
                for c in chunks) / 1e3
    return {
        "engine": "scan",
        "offered_rps": rps,
        "completed": len(res),
        "wall_s": round(wall, 4),
        "tokens_per_s": round(gen_tok / wall, 2),
        "decode_tokens_per_s": round(dec_tok / dec_s if dec_s > 0 else 0.0, 2),
        "p50_latency_ms": round(_pct([r.latency_ms for r in res], 50), 2),
        "p99_latency_ms": round(_pct([r.latency_ms for r in res], 99), 2),
        "queue_p99_ms": round(_pct([r.queue_ms for r in res], 99), 2),
    }


def bench_loop(model, params, prompts, *, gen, n_slots, rps):
    """Static FIFO batches of the per-token loop (one jitted step per
    token, batch shape fixed at n_slots via padding, pre-warmed)."""
    n, plen = prompts.shape
    total = plen + gen
    step = jax.jit(model.serve_step)

    def decode_batch(rows):  # rows: (n_slots, plen) — padded
        cache = model.init_cache(n_slots, total)
        tok = jnp.asarray(rows[:, 0])
        out = [tok]
        t_dec = None
        for t in range(plen + gen - 1):
            logits, cache = step(params, cache, tok, jnp.int32(t))
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            tok = jnp.asarray(rows[:, t + 1]) if t + 1 < plen else nxt
            out.append(tok)
            if t == plen - 1:
                jax.block_until_ready(tok)
                t_dec = time.perf_counter()
        toks = jnp.stack(out, 1)
        jax.block_until_ready(toks)
        return np.asarray(toks), time.perf_counter() - t_dec

    decode_batch(np.tile(prompts[:1], (n_slots, 1)))  # warm
    arrive = _arrivals(n, rps)
    pending = list(range(n))
    lat, dec_s_total, dec_steps_total = [], 0.0, 0
    t0 = time.perf_counter()
    while pending:
        now = time.perf_counter() - t0
        due = [i for i in pending if arrive[i] <= now]
        if not due:
            time.sleep(max(min(arrive[i] for i in pending) - now, 0.0))
            continue
        batch = due[:n_slots]
        pending = [i for i in pending if i not in batch]
        rows = np.zeros((n_slots, plen), np.int32)
        rows[: len(batch)] = prompts[batch]
        _, dec_s = decode_batch(rows)
        done = time.perf_counter() - t0
        dec_s_total += dec_s
        dec_steps_total += len(batch) * (gen - 1)
        lat.extend((done - arrive[i]) * 1e3 for i in batch)
    wall = time.perf_counter() - t0
    return {
        "engine": "loop",
        "offered_rps": rps,
        "completed": n,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(n * gen / wall, 2),
        "decode_tokens_per_s": round(
            dec_steps_total / dec_s_total if dec_s_total > 0 else 0.0, 2),
        "p50_latency_ms": round(_pct(lat, 50), 2),
        "p99_latency_ms": round(_pct(lat, 99), 2),
        "queue_p99_ms": 0.0,  # the loop has no admission queue fence
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--loads", default="2,8,0",
                    help="offered loads in requests/s (0 = burst / "
                         "steady state); >= 3 points")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH")
    args = ap.parse_args()

    cfg, model, params = _build(args.arch, args.seed)
    rng = np.random.default_rng(args.seed + 1)
    prompts = rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len),
                           dtype=np.int32)
    loads = [float(x) for x in args.loads.split(",")]

    eng = make_engine(model, params, prompts, gen=args.gen,
                      n_slots=args.n_slots, chunk=args.chunk)
    entries = []
    print("engine,offered_rps,tokens_per_s,decode_tokens_per_s,"
          "p50_latency_ms,p99_latency_ms")
    for rps in loads:
        for e in (bench_engine(eng, prompts, gen=args.gen, rps=rps),
                  bench_loop(model, params, prompts, gen=args.gen,
                             n_slots=args.n_slots, rps=rps)):
            entries.append(e)
            print(f"{e['engine']},{rps:g},{e['tokens_per_s']},"
                  f"{e['decode_tokens_per_s']},{e['p50_latency_ms']},"
                  f"{e['p99_latency_ms']}")

    # steady state = the burst point (or the highest offered load)
    ss = min(loads) if 0.0 in loads else max(loads)
    scan_ss = next(e for e in entries
                   if e["engine"] == "scan" and e["offered_rps"] == ss)
    loop_ss = next(e for e in entries
                   if e["engine"] == "loop" and e["offered_rps"] == ss)
    speedup = (scan_ss["decode_tokens_per_s"]
               / loop_ss["decode_tokens_per_s"]
               if loop_ss["decode_tokens_per_s"] else float("inf"))
    print(f"# steady-state decode: scan {scan_ss['decode_tokens_per_s']} "
          f"vs loop {loop_ss['decode_tokens_per_s']} tok/s "
          f"({speedup:.2f}x)")

    if args.json:
        blob = {
            "arch": cfg.name,
            "backend": jax.default_backend(),
            "requests": args.requests,
            "prompt_len": args.prompt_len,
            "gen": args.gen,
            "n_slots": args.n_slots,
            "chunk": args.chunk,
            "entries": entries,
            "steady_state_speedup": round(speedup, 4),
        }
        with open(args.json, "w") as f:
            json.dump(blob, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
