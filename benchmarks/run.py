"""Benchmark harness entry point — one benchmark per paper figure plus
kernel microbenchmarks.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig1,fig4,...] [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list: fig1,fig2,fig3,fig4,fig5,fig7,kernels")
    ap.add_argument("--fast", action="store_true", help="fewer steps (CI)")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_figures

    scale = 0.25 if args.fast else 1.0
    jobs = {
        "fig1": lambda: paper_figures.fig1_rv_count(steps=max(20, int(120 * scale))),
        "fig2": lambda: paper_figures.fig2_convex_populations(steps=max(16, int(60 * scale))),
        "fig3": lambda: paper_figures.fig3_nonconvex_hybrid(steps=max(20, int(120 * scale))),
        "fig4": lambda: paper_figures.fig4_brackets_transformer(steps=max(16, int(80 * scale))),
        "fig5": lambda: paper_figures.fig5_lr_impact(steps=max(40, int(400 * scale))),
        "fig7": lambda: paper_figures.fig7_consensus(steps=max(20, int(120 * scale))),
        "speedup": lambda: paper_figures.speedup_vs_population(steps=max(60, int(400 * scale))),
        "kernels": kernel_bench.main,
    }
    only = args.only.split(",") if args.only else list(jobs)

    print("name,us_per_call,derived")
    t0 = time.time()
    for name in only:
        if name not in jobs:
            print(f"# unknown benchmark {name}", file=sys.stderr)
            continue
        t1 = time.time()
        jobs[name]()
        print(f"# {name} done in {time.time()-t1:.1f}s", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
