"""Kernel microbenchmarks: wall time of the jitted Pallas wrappers
(interpret mode on CPU — structural check; real perf is a TPU artifact)
and of their jnp oracles, printed as ``name,us_per_call,derived``.

The ``estimator_*`` section compares a full ZO gradient estimate via
the tree-pytree path (``estimators.zo_estimate``: every Gaussian u_r
materialized) against the fused flat engine (``flatzo``: u_r
regenerated in VMEM) at d >= 1e6 — the ``derived`` column carries the
analytic HBM traffic of the Gaussian draws alone, which is O(rv*d)
for tree and 0 for fused (the candidate evals' traffic is common to
both paths).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.core import estimators, flatzo
from repro.kernels import ops, ref


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def main() -> None:
    d = 1 << 16
    coeffs = jax.random.normal(jax.random.PRNGKey(0), (8,))
    us_k = _time(lambda: ops.zo_combine(coeffs, 7, d))
    us_r = _time(lambda: jax.jit(lambda c: ref.zo_combine_ref(c, 7, d))(coeffs))
    print(csv_line("kernel_zo_combine_interp", us_k, f"ref_us={us_r:.1f}"))

    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    us_k = _time(lambda: ops.zo_perturb(x, 7, 1, 1e-3))
    us_r = _time(lambda: jax.jit(lambda v: ref.zo_perturb_ref(v, 7, 1, 1e-3))(x))
    print(csv_line("kernel_zo_perturb_interp", us_k, f"ref_us={us_r:.1f}"))

    us_k = _time(lambda: ops.zo_perturb_batch(x, 7, 4, 1e-3))
    us_r = _time(lambda: jax.jit(lambda v: ref.zo_perturb_batch_ref(v, 7, 4, 1e-3))(x))
    print(csv_line("kernel_zo_perturb_batch_rv4_interp", us_k, f"ref_us={us_r:.1f}"))

    y = jax.random.normal(jax.random.PRNGKey(2), (d,))
    us_k = _time(lambda: ops.gossip_avg(x, y))
    us_r = _time(lambda: jax.jit(ref.gossip_avg_ref)(x, y))
    print(csv_line("kernel_gossip_avg_interp", us_k, f"ref_us={us_r:.1f}"))

    b, s, h, p, n = 1, 512, 4, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xs = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    us_k = _time(lambda: ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=128), n=2)
    us_r = _time(lambda: jax.jit(ref.ssd_scan_ref)(xs, dt, A, Bm, Cm), n=2)
    print(csv_line("kernel_ssd_scan_interp", us_k, f"ref_us={us_r:.1f}"))

    estimator_bench()


def estimator_bench(d: int = 1 << 20):
    """Full ZO estimate, tree vs fused, at d >= 1e6.

    ``noise_mb`` is the analytic HBM footprint of the Gaussian draws:
    the tree path materializes rv f32 vectors per estimate
    (rv * d * 4 bytes); the fused path regenerates them in VMEM and
    writes none, whatever rv is.
    """
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (d,)) * 0.01}
    target = jax.random.normal(jax.random.PRNGKey(5), (d,)) * 0.01

    def loss_fn(p):
        r = p["w"] - target
        return jnp.dot(r, r) / d

    for rv in (2, 8):
        tree = jax.jit(
            lambda k: estimators.zo_estimate(loss_fn, params, k, kind="multi_rv",
                                             rv=rv, nu=1e-3)[1]
        )
        fused = jax.jit(
            lambda k: flatzo.flat_zo_estimate(loss_fn, params, k, kind="multi_rv",
                                              rv=rv, nu=1e-3)[1]
        )
        key = jax.random.PRNGKey(0)
        us_t = _time(lambda: tree(key), n=2)
        us_f = _time(lambda: fused(key), n=2)
        noise_tree_mb = rv * d * 4 / 1e6
        print(csv_line(f"estimator_tree_d{d}_rv{rv}", us_t,
                       f"noise_mb={noise_tree_mb:.1f}"))
        print(csv_line(f"estimator_fused_d{d}_rv{rv}", us_f, "noise_mb=0.0"))


if __name__ == "__main__":
    main()
