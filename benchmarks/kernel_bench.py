"""Kernel microbenchmarks: wall time of the jitted Pallas wrappers
(interpret mode on CPU — structural check; real perf is a TPU artifact)
and of their jnp oracles, printed as ``name,us_per_call,derived``.

The ``estimator_*`` section compares a full ZO gradient estimate via
the tree-pytree path (``estimators.zo_estimate``: every Gaussian u_r
materialized) against the fused flat engine (``flatzo``: u_r
regenerated in VMEM) for **all four estimator kinds** at d >= 1e6.
``--json`` additionally writes the machine-readable
``BENCH_estimators.json`` (wall time + analytic HBM traffic per entry)
— the artifact CI uploads from the slow lane to seed the perf
trajectory.

The ``gossip_*`` section compares the fused k-neighbor ``gossip_mix``
kernel against chained ``gossip_avg`` calls and the jnp oracle at
d >= 1e6; ``--json`` writes it to ``BENCH_gossip.json`` (uploaded from
the same CI lane).

The ``optim_*`` section compares the fused momentum-SGD apply
(``opt_apply``: momentum update + parameter update in one O(d) pass)
against the tree-path two-op apply (momentum written to HBM, then read
back by the parameter update) per ``momentum_dtype``; ``--json``
writes ``BENCH_optim.json`` alongside the other two artifacts.

The ``plane_*`` section times a FULL jitted HDO round (estimate ->
update -> mix) under ``param_layout="tree"`` vs ``"plane"`` on a
many-small-leaf transformer-like pytree at d ~ 2^20 — the regime the
plane layout targets (per-(agent, leaf) dispatch and the sub-BLOCK jnp
fallback vs O(#agents) dispatches over one contiguous buffer);
``--json`` writes ``BENCH_plane.json`` with the analytic per-phase
dispatch counts (``core.plane.dispatch_counts``) and HBM bytes.

The ``compress_*`` section sweeps the compressed-gossip round
(``compress_mix``: compress -> decompress -> difference-form combine +
error-feedback write-back in one O(d) pass) across compressor settings
at d ~ 2^20, reporting the communication/convergence trade the
subsystem exists to expose: bytes-on-wire per agent per round
(``topology.compress.Compressor.bytes_on_wire``) against the predicted
per-round Gamma contraction under that compressor
(``topology.spectral.effective_slem`` squared) and wall time; ``--json``
writes ``BENCH_compress.json`` (schema in ``benchmarks/README.md``).

The ``shard_*`` section prices the sharded HDO round
(``core/shardround.py``): analytic cross-device wire bytes of the
ppermute-decomposed gossip vs the all-gather alternative per topology
and shard count, plus fenced per-phase wall time of the sharded round
at a few ``agents x model`` mesh shapes on 8 forced host devices;
``--json`` writes ``BENCH_shard.json`` (schema in
``benchmarks/README.md``).
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.configs.base import ZO_ESTIMATORS
from repro.core import estimators, flatzo
from repro.kernels import ops, ref


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def main(json_path: str | None = None) -> None:
    d = 1 << 16
    coeffs = jax.random.normal(jax.random.PRNGKey(0), (8,))
    us_k = _time(lambda: ops.zo_combine(coeffs, 7, d))
    us_r = _time(lambda: jax.jit(lambda c: ref.zo_combine_ref(c, 7, d))(coeffs))
    print(csv_line("kernel_zo_combine_interp", us_k, f"ref_us={us_r:.1f}"))

    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    us_k = _time(lambda: ops.zo_perturb(x, 7, 1, 1e-3))
    us_r = _time(lambda: jax.jit(lambda v: ref.zo_perturb_ref(v, 7, 1, 1e-3))(x))
    print(csv_line("kernel_zo_perturb_interp", us_k, f"ref_us={us_r:.1f}"))

    us_k = _time(lambda: ops.zo_perturb_batch(x, 7, 4, 1e-3))
    us_r = _time(lambda: jax.jit(lambda v: ref.zo_perturb_batch_ref(v, 7, 4, 1e-3))(x))
    print(csv_line("kernel_zo_perturb_batch_rv4_interp", us_k, f"ref_us={us_r:.1f}"))

    y = jax.random.normal(jax.random.PRNGKey(2), (d,))
    us_k = _time(lambda: ops.gossip_avg(x, y))
    us_r = _time(lambda: jax.jit(ref.gossip_avg_ref)(x, y))
    print(csv_line("kernel_gossip_avg_interp", us_k, f"ref_us={us_r:.1f}"))

    b, s, h, p, n = 1, 512, 4, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xs = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    us_k = _time(lambda: ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=128), n=2)
    us_r = _time(lambda: jax.jit(ref.ssd_scan_ref)(xs, dt, A, Bm, Cm), n=2)
    print(csv_line("kernel_ssd_scan_interp", us_k, f"ref_us={us_r:.1f}"))

    estimator_bench(json_path=json_path)
    # the gossip + optim artifacts land next to the estimator one
    side = lambda name: (
        os.path.join(os.path.dirname(json_path) or ".", name)
        if json_path else None
    )
    gossip_bench(json_path=side("BENCH_gossip.json"))
    optim_bench(json_path=side("BENCH_optim.json"))
    plane_bench(json_path=side("BENCH_plane.json"))
    compress_bench(json_path=side("BENCH_compress.json"))
    shard_bench(json_path=side("BENCH_shard.json"))


def gossip_bench(d: int = 1 << 20, json_path: str | None = None):
    """Gossip interaction step at d >= 1e6: the fused k-neighbor
    ``gossip_mix`` kernel vs chained ``gossip_avg`` passes vs the jnp
    oracle, per topology degree.

    Analytic HBM traffic per mixed agent (the gossip step is pure
    memory traffic — these are the roofline terms):
      * ``gossip_mix``   — one read of x + k neighbor reads + one write:
        (k + 2) * d * 4 bytes, regardless of k's chaining.
      * ``chained_avg``  — emulating a k-neighbor combine with binary
        averages costs k passes: each reads two O(d) vectors and writes
        one, 3 * k * d * 4 bytes (and computes the wrong weighting for
        irregular graphs — it is the structural baseline only).
    """
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    entries = []
    for k in (1, 2, 4):
        nbrs = jax.random.normal(jax.random.PRNGKey(1), (k, d))
        w = jnp.full((k,), 1.0 / (k + 1))
        w_self = 1.0 / (k + 1)
        us_mix = _time(lambda: ops.gossip_mix(x, nbrs, w_self, w), n=3)
        us_ref = _time(
            lambda: jax.jit(ref.gossip_mix_ref)(x, nbrs, w_self, w), n=3)

        def chained(x, nbrs):
            out = x
            for s in range(nbrs.shape[0]):
                out = ops.gossip_avg(out, nbrs[s])
            return out

        us_chain = _time(lambda: chained(x, nbrs), n=3)
        rows = [
            ("gossip_mix", us_mix, (k + 2) * d * 4),
            ("chained_avg", us_chain, 3 * k * d * 4),
            ("jnp_ref", us_ref, (k + 2) * d * 4),
        ]
        for impl, us, hbm in rows:
            entries.append({
                "impl": impl, "k": k, "d": d,
                "us_per_call": round(us, 1), "hbm_bytes": hbm,
            })
            print(csv_line(f"gossip_{impl}_k{k}_d{d}", us,
                           f"hbm_mb={hbm / 1e6:.1f}"))
    if json_path:
        payload = {"d": d, "backend": jax.default_backend(),
                   "interpret_mode": jax.default_backend() != "tpu",
                   "entries": entries}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return entries


def compress_bench(d: int = 1 << 20, json_path: str | None = None):
    """The compressed-gossip round at d >= 1e6: ``compress_mix`` (fused
    compress -> decompress -> weighted k-neighbor combine +
    error-feedback residual in one O(d) pass) vs the jnp oracle, per
    compressor setting, on a ring (degree k=2).

    Each entry carries the trade the sweep exists to plot:
      * ``wire_bytes``      — payload bytes one agent puts on the wire
        per round (``Compressor.bytes_on_wire``; dense f32 ``4*d`` for
        the uncompressed baseline).
      * ``delta``           — the compressor's contraction-retention
        factor (top-k: k/d; qsgd: 1/(1+omega)).
      * ``predicted_gamma`` — the per-round consensus contraction
        ``effective_slem(topo, delta)**2`` the spectral model predicts
        (validated against measurement in tests/test_compress.py).
      * ``hbm_bytes``       — analytic kernel traffic: read x + u +
        k neighbor bases, write out + residual: ``(k + 4) * d * 4``
        (payload statistics are O(k) scalars).

    The uncompressed baseline row times the plain ``gossip_mix`` kernel
    (no send basis / residual stream) so the fused path's overhead over
    the PR-6 hot path is visible in the same artifact.
    """
    from repro.topology import compress as compresslib
    from repro.topology import graphs, spectral

    topo = graphs.ring(8)
    k = int(topo.neighbors.shape[1])
    w = jnp.asarray(topo.weights[0], jnp.float32)  # ring: uniform rows
    w_self = float(1.0 - float(jnp.sum(w)))
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    nbrs = jax.random.normal(jax.random.PRNGKey(1), (k, d))
    u = x.astype(jnp.float32)  # zero residual: send basis == params
    seeds = compresslib.payload_seeds(0, 0, k + 1)

    settings = [
        ("none", None),
        ("topk_1pct", compresslib.Compressor("topk", k=max(1, d // 100))),
        ("topk_10pct", compresslib.Compressor("topk", k=max(1, d // 10))),
        ("qsgd_4bit", compresslib.Compressor("qsgd", bits=4)),
        ("qsgd_8bit", compresslib.Compressor("qsgd", bits=8)),
    ]
    entries = []
    for name, comp in settings:
        if comp is None:
            us_k = _time(lambda: ops.gossip_mix(x, nbrs, w_self, w), n=3)
            us_r = _time(
                lambda: jax.jit(ref.gossip_mix_ref)(x, nbrs, w_self, w), n=3)
            wire, delta = 4 * d, 1.0
            hbm = (k + 2) * d * 4
        else:
            rows = jnp.concatenate([u[None, :], nbrs], axis=0)
            thr = comp.thresholds(rows)
            mode, bits = comp.mode, comp.bits
            us_k = _time(lambda: ops.compress_mix(
                x, u, nbrs, w, thr, seeds, mode, bits), n=3)
            jref = jax.jit(functools.partial(
                ref.compress_mix_ref, mode=mode, bits=bits))
            us_r = _time(lambda: jref(x, u, nbrs, w, thr, seeds), n=3)
            wire, delta = comp.bytes_on_wire(d), comp.delta(d)
            hbm = (k + 4) * d * 4
        gamma = spectral.effective_slem(topo, delta=delta) ** 2
        entries.append({
            "setting": name, "d": d, "k_neighbors": k,
            "us_per_call": round(us_k, 1), "ref_us_per_call": round(us_r, 1),
            "wire_bytes": int(wire), "delta": round(float(delta), 6),
            "predicted_gamma": round(float(gamma), 6),
            "hbm_bytes": hbm,
        })
        print(csv_line(f"compress_{name}_d{d}", us_k,
                       f"wire_mb={wire / 1e6:.2f},gamma={gamma:.4f}"))
    if json_path:
        payload = {"d": d, "topology": "ring8", "k_neighbors": k,
                   "backend": jax.default_backend(),
                   "interpret_mode": jax.default_backend() != "tpu",
                   "entries": entries}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return entries


def optim_bench(d: int = 1 << 20, json_path: str | None = None):
    """The local-update apply at d >= 1e6: the fused ``opt_apply``
    kernel vs the tree-path two-op apply vs the jnp oracle, per
    ``momentum_dtype``.

    Analytic HBM traffic per agent apply (``msz`` = momentum element
    width, 4 or 2 bytes; params/grads f32 — the update phase is pure
    memory traffic, like gossip):
      * ``opt_apply``   — one streamed pass: read p, g, m; write p, m:
        ``(12 + 2*msz) * d`` bytes.  The momentum intermediate never
        round-trips.
      * ``tree_apply``  — the momentum pass (read m, g; write m) then
        the parameter pass (read p, m; write p): ``(12 + 3*msz) * d``
        bytes — the stored momentum is re-read by the param update.
        Benched as two SEPARATE jitted calls so the intermediate really
        materializes (under one jit XLA would fuse it into the oracle).
      * ``jnp_ref``     — same analytic traffic as ``opt_apply`` (XLA
        may or may not fuse the two lines; the kernel guarantees it).
    """
    lr, beta = 0.05, 0.9
    p = jax.random.normal(jax.random.PRNGKey(0), (d,))
    g = jax.random.normal(jax.random.PRNGKey(1), (d,))
    entries = []
    for mdt_name, mdt, msz in (("float32", jnp.float32, 4),
                               ("bfloat16", jnp.bfloat16, 2)):
        m = (jax.random.normal(jax.random.PRNGKey(2), (d,)) * 0.1).astype(mdt)

        # two separately-compiled passes == the momentum round-trip the
        # tree path pays when the two updates don't fuse
        mom_pass = jax.jit(lambda g, m: (
            beta * m.astype(jnp.float32)
            + (1.0 - beta) * g.astype(jnp.float32)).astype(m.dtype))
        param_pass = jax.jit(lambda p, nm: (
            p.astype(jnp.float32) - lr * nm.astype(jnp.float32)
        ).astype(p.dtype))

        def tree_apply(p, g, m):
            nm = mom_pass(g, m)
            return param_pass(p, nm), nm

        us_k = _time(lambda: ops.opt_apply(p, g, m, lr, beta), n=3)
        us_t = _time(lambda: tree_apply(p, g, m), n=3)
        us_r = _time(lambda: jax.jit(ref.opt_apply_ref)(p, g, m, lr, beta), n=3)
        rows = [
            ("opt_apply", us_k, (12 + 2 * msz) * d),
            ("tree_apply", us_t, (12 + 3 * msz) * d),
            ("jnp_ref", us_r, (12 + 2 * msz) * d),
        ]
        for impl, us, hbm in rows:
            entries.append({
                "impl": impl, "momentum_dtype": mdt_name, "d": d,
                "us_per_call": round(us, 1), "hbm_bytes": hbm,
            })
            print(csv_line(f"optim_{impl}_{mdt_name}_d{d}", us,
                           f"hbm_mb={hbm / 1e6:.1f}"))
    if json_path:
        payload = {"d": d, "backend": jax.default_backend(),
                   "interpret_mode": jax.default_backend() != "tpu",
                   "entries": entries}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return entries


def plane_bench(n_agents: int = 4, n_layers: int = 12,
                json_path: str | None = None):
    """One full HDO round (estimate -> update -> mix), tree vs plane
    layout, on a transformer-like pytree with many sub-BLOCK leaves
    (biases, norms) at d ~ 2^20.

    Analytic terms per round (``msz`` = 4, f32 momentum):
      * dispatches — ``core.plane.dispatch_counts``: the tree layout
        pays one mix launch per (agent, leaf) and drops sub-BLOCK
        leaves to the update-phase jnp fallback; the plane is one
        BLOCK-aligned ``(n_agents, dim)`` leaf, so every phase is
        O(#agents) with an empty fallback set.
      * update ``hbm_bytes`` — the fused apply streams
        ``(12 + 2*msz) * d`` per agent (see ``optim_bench``); the tree
        layout pays that only on kernel-routed leaves and the
        unfused two-pass ``(12 + 3*msz)`` on the fallback set.
      * mix ``hbm_bytes`` — ring (k=2) ``gossip_mix``:
        ``(k + 2) * d * 4`` per agent (see ``gossip_bench``).
    """
    from repro.configs.base import HDOConfig
    from repro.core import hdo as hdolib
    from repro.core import plane as planelib

    key = jax.random.PRNGKey(0)
    blocks = []
    for i in range(n_layers):
        k1, k2, key = jax.random.split(key, 3)
        blocks.append({
            "w": jax.random.normal(k1, (256, 256)) * 0.02,
            "b": jnp.zeros((256,)),
            "ln": jnp.ones((256,)),
        })
    k1, key = jax.random.split(key)
    params = {
        "emb": jax.random.normal(k1, (1024, 256)) * 0.02,
        "blocks": blocks,
        "head": jnp.zeros((256,)),
    }
    man = planelib.build_manifest(params)

    def loss_fn(p, batch):
        acc = jnp.float32(0.0)
        for leaf in jax.tree_util.tree_leaves(p):
            acc = acc + jnp.sum(leaf.astype(jnp.float32) ** 2)
        return acc / man.size + 0.0 * jnp.sum(batch["x"])

    batches = {"x": jnp.zeros((n_agents, 1))}
    entries = []
    for layout in ("tree", "plane"):
        cfg = HDOConfig(
            n_agents=n_agents, n_zeroth=n_agents // 2,
            estimator_zo="multi_rv", rv=2, zo_impl="fused",
            gossip="graph", topology="ring", lr=0.01, momentum=0.9,
            nu=1e-3, warmup_steps=0, use_cosine=False,
            param_layout=layout,
        )
        step = jax.jit(hdolib.build_hdo_step(
            loss_fn, cfg, param_dim=man.size, params_template=params))
        state = hdolib.init_state(params, cfg)
        us = _time(lambda: step(state, batches)[0].params, n=2)
        # fenced per-phase split of the same round (repro.obs.timing:
        # three separately-jitted calls, bit-identical to the fused
        # step) — locates the layouts' cost difference by phase
        from repro.obs import timing as obstiming

        fns = obstiming.build_phase_fns(
            loss_fn, cfg, param_dim=man.size, params_template=params)
        timing = obstiming.PhaseTimer(fns, reps=2).measure(state, batches)
        phase_ms = {ph: round(timing[f"phase_ms_{ph}"], 3)
                    for ph in ("estimate", "update", "mix")}
        counts = planelib.dispatch_counts(man, n_agents)[layout]
        d_eff = man.dim if layout == "plane" else man.size
        large = sum(s.size for s in man.leaves if s.size >= 8192)
        small = man.size - large
        if layout == "plane":
            update_hbm = (12 + 2 * 4) * n_agents * man.dim
        else:
            update_hbm = n_agents * ((12 + 2 * 4) * large + (12 + 3 * 4) * small)
        mix_hbm = (2 + 2) * n_agents * d_eff * 4
        entries.append({
            "layout": layout, "dim": d_eff, "n_agents": n_agents,
            "us_per_step": round(us, 1), "dispatch": counts,
            "phase_ms": phase_ms,
            "update_hbm_bytes": update_hbm, "mix_hbm_bytes": mix_hbm,
        })
        print(csv_line(f"plane_round_{layout}_d{d_eff}", us,
                       f"mix_calls={counts['mix_kernel_calls']}"))
    if json_path:
        payload = {
            "n_agents": n_agents, "n_leaves": len(man.leaves),
            "compact_size": man.size, "plane_dim": man.dim,
            "backend": jax.default_backend(),
            "interpret_mode": jax.default_backend() != "tpu",
            "entries": entries,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return entries


def estimator_bench(d: int = 1 << 20, rv: int = 8, json_path: str | None = None):
    """Full ZO estimate, tree vs fused, every estimator kind, at d >= 1e6.

    Analytic HBM traffic per estimate (beyond the candidate/JVP evals
    both paths pay identically):
      * ``noise_bytes``   — Gaussian draws materialized to HBM.  Tree:
        rv_eff f32 vectors (``tree_normal``).  Fused: 0 for the
        finite-difference kinds (regenerated in VMEM); for ``fwd_grad``
        each tangent is written once because ``jax.jvp`` must consume
        it — still generated kernel-side in a single O(d) pass.
      * ``combine_bytes`` — estimate assembly.  Tree: the O(d) f32
        accumulator is read+written once per draw.  Fused:
        ``zo_combine`` regenerates every u_r in VMEM and performs one
        O(d) write of g.
    """
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (d,)) * 0.01}
    target = jax.random.normal(jax.random.PRNGKey(5), (d,)) * 0.01

    def loss_fn(p):
        r = p["w"] - target
        return jnp.dot(r, r) / d

    entries = []
    key = jax.random.PRNGKey(0)
    for kind in ZO_ESTIMATORS:
        rv_eff = rv if kind in ("multi_rv", "fwd_grad") else 1
        tree = jax.jit(
            lambda k, _kind=kind: estimators.zo_estimate(
                loss_fn, params, k, kind=_kind, rv=rv, nu=1e-3)[1]
        )
        fused = jax.jit(
            lambda k, _kind=kind: flatzo.flat_zo_estimate(
                loss_fn, params, k, kind=_kind, rv=rv, nu=1e-3)[1]
        )
        us_t = _time(lambda: tree(key), n=2)
        us_f = _time(lambda: fused(key), n=2)
        for impl, us in (("tree", us_t), ("fused", us_f)):
            noise = rv_eff * d * 4 if (impl == "tree" or kind == "fwd_grad") else 0
            combine = 2 * rv_eff * d * 4 if impl == "tree" else d * 4
            entries.append({
                "kind": kind, "impl": impl, "d": d, "rv": rv_eff,
                "us_per_call": round(us, 1),
                "noise_bytes": noise, "combine_bytes": combine,
            })
            print(csv_line(f"estimator_{impl}_{kind}_d{d}_rv{rv_eff}", us,
                           f"noise_mb={noise / 1e6:.1f}"))
    if json_path:
        payload = {"d": d, "backend": jax.default_backend(),
                   "interpret_mode": jax.default_backend() != "tpu",
                   "entries": entries}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return entries


def shard_bench(n: int = 8, d: int = 1 << 20, json_path: str | None = None):
    """The sharded HDO round (core/shardround.py) over the
    ``agents x model`` mesh: analytic cross-device wire traffic of the
    ppermute-decomposed gossip, plus fenced per-phase wall time at a
    few mesh shapes.

    ``wire`` entries are device-free (``topology.shardmix`` plan):
    the round-decomposed ppermute schedule moves
    ``n_edges * n_local * d * 4`` bytes per mix — for a k-regular
    graph fully split (one agent per shard) that is ``k * n * d * 4``
    regardless of the shard count A (scales with neighbor degree),
    while the all-gather alternative moves ``(A - 1) * n * d * 4``
    (scales with A).  Both figures are carried so the perf trajectory
    can assert the ratio.

    ``phases`` entries time the sharded round at shapes
    ``(A, M) in {(8,1), (4,1), (4,2)}`` in a subprocess with 8 forced
    host devices (one process hosting every shard — a structural
    number like the interpret-mode kernels, not TPU perf); the
    attached analytic HBM bytes are PER SHARD
    (``obs.timing.analytic_phase_bytes(..., n_shards=A*M)``).
    """
    from repro.topology import shardmix
    from repro.topology.graphs import make_topology

    wire = []
    for name in ("ring", "torus", "hypercube", "erdos_renyi"):
        kw = {"p": 0.5, "seed": 3} if name == "erdos_renyi" else {}
        topo = make_topology(name, n, **kw)
        for A in (2, 4, 8):
            if n % A:
                continue
            plan = shardmix.plan_shard_mix(topo, A)
            pb = plan.ppermute_bytes(d)
            ab = plan.allgather_bytes(d)
            if name != "erdos_renyi" and A == n:
                # fully split, k-regular: the degree-vs-population claim
                # is exact, not approximate
                assert pb == topo.k * n * d * 4, (name, pb)
                assert ab == (A - 1) * n * d * 4, (name, ab)
            wire.append({
                "topology": name, "k": int(topo.k), "shards": A,
                "n_local": n // A, "rounds": plan.n_rounds,
                "edges": plan.n_edges,
                "ppermute_bytes": pb, "allgather_bytes": ab,
            })
            print(csv_line(
                f"shard_wire_{name}_A{A}", 0.0,
                f"ppermute_mb={pb / 1e6:.1f} allgather_mb={ab / 1e6:.1f}"))

    # per-phase wall time needs 8 devices; force host devices in a
    # fresh interpreter (XLA_FLAGS is read once at jax import)
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os, json
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                                   + os.environ.get("XLA_FLAGS", ""))
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import HDOConfig
        from repro.core import init_state
        from repro.core import plane as planelib
        from repro.launch.mesh import make_hdo_mesh
        from repro.obs import timing as obstiming

        k = jax.random.PRNGKey(7)
        ks = jax.random.split(k, 3)
        params = {
            "emb": jax.random.normal(ks[0], (96, 90)) * 0.1,
            "blk": {"w": jax.random.normal(ks[1], (40, 40)) * 0.1,
                    "b": jnp.zeros((40,)), "ln": jnp.ones((40,))},
            "head": jax.random.normal(ks[2], (90,)) * 0.1,
        }
        D = planelib.build_manifest(params).size

        def loss_fn(p, batch):
            w = jnp.concatenate([l.reshape(-1)
                                 for l in jax.tree_util.tree_leaves(p)])
            return jnp.mean((batch["X"] @ w - batch["y"]) ** 2)

        cfg = HDOConfig(n_agents=8, n_zeroth=4, lr=0.05, rv=2,
                        topology="ring", gossip="graph",
                        param_layout="plane", zo_impl="fused")
        X = jax.random.normal(jax.random.PRNGKey(3), (8, 4, D)) / np.sqrt(D)
        batches = {"X": X, "y": X @ jnp.zeros((D,))}
        state = init_state(params, cfg)
        entries = []
        for (A, M) in ((8, 1), (4, 1), (4, 2)):
            mesh = make_hdo_mesh(8, M, agent_shards=A)
            fns = obstiming.build_phase_fns(
                loss_fn, cfg, param_dim=D, params_template=params,
                shard=True, mesh=mesh, population_axes=("agents",),
                model_axes=("model",))
            timer = obstiming.PhaseTimer(
                fns, obstiming.analytic_phase_bytes(cfg, D, n_shards=A * M),
                reps=2)
            timer.measure(state, batches)  # compile pass
            t = timer.measure(state, batches)
            entries.append({"mesh": [A, M],
                            "metrics": {k: round(float(v), 4)
                                        for k, v in t.items()}})
        print("SHARD_PHASES_JSON " + json.dumps({"d": D, "entries": entries}))
    """)
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=600, env=env)
    phases = None
    for line in proc.stdout.splitlines():
        if line.startswith("SHARD_PHASES_JSON "):
            phases = json.loads(line[len("SHARD_PHASES_JSON "):])
    if phases is None:
        print(csv_line("shard_phases_skipped", 0.0,
                       f"rc={proc.returncode}"))
    else:
        for e in phases["entries"]:
            m = e["metrics"]
            print(csv_line(
                f"shard_round_A{e['mesh'][0]}_M{e['mesh'][1]}",
                sum(m.get(f"phase_ms_{p}", 0.0)
                    for p in ("estimate", "update", "mix")) * 1e3,
                f"mix_ms={m.get('phase_ms_mix', 0.0):.3f}"))
    if json_path:
        payload = {"n": n, "d": d, "backend": jax.default_backend(),
                   "wire": wire, "phases": phases}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return wire, phases


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_estimators.json", default=None,
                    metavar="PATH",
                    help="write the estimator entries to PATH (default "
                         "BENCH_estimators.json); the gossip and optim "
                         "entries go to BENCH_gossip.json / BENCH_optim.json "
                         "alongside it")
    args = ap.parse_args()
    main(json_path=args.json)
