"""Kernel microbenchmarks: wall time of the jitted Pallas wrappers
(interpret mode on CPU — structural check; real perf is a TPU artifact)
and of their jnp oracles, printed as ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.kernels import ops, ref


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def main() -> None:
    d = 1 << 16
    coeffs = jax.random.normal(jax.random.PRNGKey(0), (8,))
    us_k = _time(lambda: ops.zo_combine(coeffs, 7, d))
    us_r = _time(lambda: jax.jit(lambda c: ref.zo_combine_ref(c, 7, d))(coeffs))
    print(csv_line("kernel_zo_combine_interp", us_k, f"ref_us={us_r:.1f}"))

    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    us_k = _time(lambda: ops.zo_perturb(x, 7, 1, 1e-3))
    us_r = _time(lambda: jax.jit(lambda v: ref.zo_perturb_ref(v, 7, 1, 1e-3))(x))
    print(csv_line("kernel_zo_perturb_interp", us_k, f"ref_us={us_r:.1f}"))

    y = jax.random.normal(jax.random.PRNGKey(2), (d,))
    us_k = _time(lambda: ops.gossip_avg(x, y))
    us_r = _time(lambda: jax.jit(ref.gossip_avg_ref)(x, y))
    print(csv_line("kernel_gossip_avg_interp", us_k, f"ref_us={us_r:.1f}"))

    b, s, h, p, n = 1, 512, 4, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xs = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    us_k = _time(lambda: ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=128), n=2)
    us_r = _time(lambda: jax.jit(ref.ssd_scan_ref)(xs, dt, A, Bm, Cm), n=2)
    print(csv_line("kernel_ssd_scan_interp", us_k, f"ref_us={us_r:.1f}"))


if __name__ == "__main__":
    main()
