"""Run the full (architecture x input-shape x mesh) dry-run matrix.

Each combination runs in a fresh subprocess (XLA device count locks at
first jax init) and appends a JSON line to the results file.

  PYTHONPATH=src python -m benchmarks.dryrun_matrix \
      --out results/dryrun_baseline.jsonl [--multi-pod] [--archs a,b] \
      [--shapes train_4k,...] [--gossip dense] [--timeout 1800]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "qwen1.5-0.5b", "whisper-base", "pixtral-12b", "qwen1.5-4b", "gemma2-9b",
    "llama4-maverick-400b-a17b", "mamba2-780m", "zamba2-2.7b", "yi-9b",
    "qwen2-moe-a2.7b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_combo(arch: str, shape: str, *, multi_pod: bool, gossip: str, rv: int,
              timeout: int, out: str, extra_args=()) -> dict:
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--gossip", gossip, "--rv", str(rv),
        "--out", out,
    ]
    if multi_pod:
        cmd.append("--multi-pod")
    cmd += list(extra_args)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout, env=env)
        if proc.returncode == 0:
            line = [l for l in proc.stdout.splitlines() if l.startswith("{")][-1]
            return json.loads(line)
        report = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                  "error": proc.stderr.strip().splitlines()[-8:], "wall_s": time.time() - t0}
    except subprocess.TimeoutExpired:
        report = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                  "error": "timeout", "wall_s": time.time() - t0}
    with open(out, "a") as f:
        f.write(json.dumps(report) + "\n")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun_baseline.jsonl")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--gossip", default="dense")
    ap.add_argument("--rv", type=int, default=2)
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if "error" not in r:
                    done.add((r["arch"], r["shape"], r.get("multi_pod", False)))
            except Exception:
                pass

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for multi_pod in meshes:
        for arch in args.archs.split(","):
            for shape in args.shapes.split(","):
                key = (arch, shape, multi_pod)
                if key in done:
                    print(f"skip (done): {key}", flush=True)
                    continue
                t0 = time.time()
                r = run_combo(arch, shape, multi_pod=multi_pod, gossip=args.gossip,
                              rv=args.rv, timeout=args.timeout, out=args.out)
                status = ("SKIP" if "skipped" in r else
                          ("ERR " if "error" in r else "ok  "))
                print(f"{status} {arch:28s} {shape:12s} multi_pod={multi_pod} "
                      f"({time.time()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
