#!/usr/bin/env python
"""Extract and execute the README quickstart snippet (the fenced python
block between the ``quickstart-snippet`` markers).

  PYTHONPATH=src python docs/run_readme_snippet.py [README.md]

Run by the CI docs lane so the snippet in the README is a tested
program, not prose; ``tests/test_docs.py`` compile-checks it in tier-1
without paying the execution cost.
"""
from __future__ import annotations

import re
import sys

BEGIN = "<!-- quickstart-snippet:begin -->"
END = "<!-- quickstart-snippet:end -->"


def extract(path: str = "README.md") -> str:
    with open(path) as f:
        text = f.read()
    start, end = text.find(BEGIN), text.find(END)
    if start < 0 or end < 0 or end < start:
        raise SystemExit(f"{path}: quickstart-snippet markers not found")
    section = text[start + len(BEGIN):end]
    m = re.search(r"```python\n(.*?)```", section, re.DOTALL)
    if m is None:
        raise SystemExit(f"{path}: no fenced python block inside the markers")
    return m.group(1)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "README.md"
    code = extract(path)
    print(f"# executing {len(code.splitlines())}-line snippet from {path}",
          flush=True)
    exec(compile(code, f"{path}:quickstart-snippet", "exec"), {"__name__": "__main__"})
    print("# snippet OK")


if __name__ == "__main__":
    main()
