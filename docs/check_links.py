#!/usr/bin/env python
"""Relative-link checker for the repo's markdown files.

  python docs/check_links.py [root]

Walks every ``*.md`` under the root (default: the repo root, i.e. the
parent of this file's directory), extracts inline markdown links, and
verifies that each *relative* target exists on disk (anchors stripped).
``http(s):``/``mailto:`` links are skipped — the docs lane runs
offline.  Exit 1 with one line per broken link.
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache", "node_modules"}


def iter_md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for fn in filenames:
            if fn.endswith(".md"):
                yield os.path.join(dirpath, fn)


def check(root: str) -> list[str]:
    errors = []
    for path in sorted(iter_md_files(root)):
        text = open(path, encoding="utf-8").read()
        # fenced code blocks routinely contain `foo(bar)` pseudo-links
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
        for target in LINK_RE.findall(text):
            if re.match(r"^[a-zA-Z][a-zA-Z0-9+.-]*:", target):  # URL scheme
                continue
            if target.startswith("#"):  # in-page anchor
                continue
            rel = target.split("#", 1)[0]
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                errors.append(f"{os.path.relpath(path, root)}: broken link -> {target}")
    return errors


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    errors = check(root)
    for e in errors:
        print(e, file=sys.stderr)
    n = sum(1 for _ in iter_md_files(root))
    print(f"checked {n} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
